"""Distributed retrieval: the cuckoo filter sharded across a device mesh,
with queries resolved by the shard_map lookup (pod-scale retrieval path).

Spawns its own device count — run directly, not under the test process:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_lookup.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.core import build_forest, build_index, lookup_batch  # noqa: E402
from repro.core import hashing                # noqa: E402
from repro.core.distributed import (shard_filter_tables,  # noqa: E402
                                    sharded_lookup)
from repro.data import hospital_corpus       # noqa: E402


def main():
    corpus = hospital_corpus(num_trees=200)
    forest = build_forest(corpus.trees)
    index = build_index(forest, num_buckets=2048)
    t = index.filter.tables()

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    fps, heads = shard_filter_tables(mesh, "model",
                                     jnp.asarray(t.fingerprints),
                                     jnp.asarray(t.heads))
    print(f"filter sharded over {mesh.shape['model']} shards x "
          f"{index.filter.num_buckets // mesh.shape['model']} buckets")

    names = forest.entity_names[:96] + ["Missing Unit X"]
    h = jnp.asarray(hashing.hash_entities(names))
    got = sharded_lookup(mesh, "model", fps, heads, h)
    ref = lookup_batch(jnp.asarray(t.fingerprints), jnp.asarray(t.heads), h)
    assert np.array_equal(np.asarray(got.hit), np.asarray(ref.hit))
    assert np.array_equal(np.asarray(got.head), np.asarray(ref.head))
    print(f"sharded lookup == replicated lookup on {len(names)} queries "
          f"({int(np.asarray(got.hit).sum())} hits)")


if __name__ == "__main__":
    main()
