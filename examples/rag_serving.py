"""End-to-end driver (the paper's kind: serving): CFT-RAG answering batched
requests with a small LM generator — query -> NER -> cuckoo-filter retrieval
-> context -> prompt -> prefill+decode.

    PYTHONPATH=src python examples/rag_serving.py [--device-lookup]
"""
import argparse
import time

import jax

from repro.configs import get_arch
from repro.data import HashTokenizer, hospital_corpus
from repro.models import init_params
from repro.serving import RAGPipeline, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device-lookup", action="store_true",
                    help="route retrieval through the Pallas cuckoo kernel")
    ap.add_argument("--trees", type=int, default=150)
    ap.add_argument("--queries", type=int, default=6)
    args = ap.parse_args()

    cfg = get_arch("paper-cftrag").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = hospital_corpus(num_trees=args.trees, num_queries=args.queries)
    engine = ServeEngine(cfg, params, cache_size=256, batch_size=2)
    rag = RAGPipeline(corpus, engine, tokenizer=HashTokenizer(cfg.vocab),
                      use_device_lookup=args.device_lookup)

    print(f"index: {rag.forest.num_entities} entities, filter load "
          f"{rag.index.filter.load_factor:.4f}, "
          f"device_lookup={args.device_lookup}\n")
    for q in corpus.queries[: args.queries]:
        t0 = time.perf_counter()
        ans = rag.answer(q, max_new_tokens=8)
        dt = time.perf_counter() - t0
        print(f"Q: {q[:84]}...")
        print(f"   entities: {ans.entities[:3]}{'...' if len(ans.entities) > 3 else ''}")
        print(f"   context:  {ans.context.splitlines()[0][:84]}...")
        print(f"   answer tokens: {ans.output_ids}  ({dt*1e3:.0f} ms)\n")

    acc = rag.retrieval_accuracy(corpus.queries[: args.queries],
                                 corpus.query_entities[: args.queries])
    print(f"retrieval accuracy proxy vs naive BFS: {acc:.4f}")


if __name__ == "__main__":
    main()
