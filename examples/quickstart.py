"""Quickstart: build a CFT-RAG index over a synthetic hospital corpus and
retrieve hierarchical context for a query — comparing all four retrievers
from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import (BloomTRAG, BloomTRAG2, CFTRAG, NaiveTRAG,
                        build_forest, build_index)
from repro.data import hospital_corpus, recognize_entities
from repro.data.ner import build_gazetteer


def main():
    corpus = hospital_corpus(num_trees=100, num_queries=4)
    forest = build_forest(corpus.trees)
    print(f"forest: {forest.num_trees} trees, {forest.num_nodes} nodes, "
          f"{forest.num_entities} entities")

    index = build_index(forest, num_buckets=1024)
    print(f"cuckoo filter: {index.filter.num_buckets} buckets, "
          f"load factor {index.filter.load_factor:.4f}")

    retrievers = {
        "naive T-RAG": NaiveTRAG(forest),
        "BF T-RAG": BloomTRAG(forest),
        "BF2 T-RAG": BloomTRAG2(forest),
        "CF T-RAG (ours)": CFTRAG(index),
    }

    query = corpus.queries[0]
    gaz = build_gazetteer(forest.entity_names)
    entities = recognize_entities(query, gaz)
    print(f"\nquery: {query[:100]}...")
    print(f"entities: {entities}")

    for name, r in retrievers.items():
        t0 = time.perf_counter()
        for _ in range(20):
            locs = [r.locate(e) for e in entities]
        dt = (time.perf_counter() - t0) / 20
        n_locs = sum(len(l) for l in locs)
        print(f"  {name:18s} {dt*1e3:9.3f} ms/query   {n_locs} locations")

    cf = retrievers["CF T-RAG (ours)"]
    ctx = cf.retrieve(entities)
    print("\ncontext (paper Algorithm 3 + template):")
    print(cf.render(ctx))


if __name__ == "__main__":
    main()
