"""Train the RAG generator end to end on RAG-formatted text (CPU scale):
a few hundred steps of the reduced qwen2-class model with checkpointing,
preemption safety, and resume — the same TrainLoop the pod run uses.

    PYTHONPATH=src python examples/train_generator.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import build_forest, build_index, CFTRAG
from repro.data import (HashTokenizer, PackedBatches, TextDataset,
                        hospital_corpus)
from repro.models import init_params
from repro.training import (AdamWConfig, LoopConfig, TrainLoop, adamw_init,
                            make_train_step)


def rag_formatted_documents(corpus, retriever):
    """Augment each document with retrieved hierarchy context — training
    matches the serving distribution (context + text)."""
    docs = []
    for doc, ents in zip(corpus.documents, corpus.query_entities):
        ctx = retriever.render(retriever.retrieve(ents[:2]))
        docs.append(f"{ctx}\n{doc}")
    return docs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/cftrag_generator_ckpt")
    args = ap.parse_args()

    cfg = get_arch("qwen2-0.5b").smoke()
    corpus = hospital_corpus(num_trees=60, num_queries=64)
    forest = build_forest(corpus.trees)
    retriever = CFTRAG(build_index(forest))
    docs = rag_formatted_documents(corpus, retriever)

    tok = HashTokenizer(cfg.vocab)
    pb = PackedBatches(TextDataset(docs, tok), batch_size=args.batch,
                       seq_len=args.seq)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))

    def batches():
        for b in pb:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=50, log_every=10),
        step_fn, params, adamw_init(params), batches(), pipeline=pb)
    metrics = loop.run()
    print(f"\ndone at step {loop.step}: loss {float(metrics['loss']):.4f} "
          f"(resume any time: rerun with the same --ckpt-dir)")


if __name__ == "__main__":
    main()
