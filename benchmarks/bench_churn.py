"""Churn workload — incremental bank maintenance vs rebuild-from-scratch.

The paper's case for the cuckoo filter over Bloom variants is dynamic
updates; this benchmark measures that claim at bank scale.  A randomized
interleaving of per-tree entity inserts and deletes (with routed query
sweeps between batches) is applied two ways:

* **incremental** — ``MaintenanceEngine`` queues each batch as a
  ``BankDelta`` and applies it in place (vectorized deletes, ``bulk_place``
  inserts, scalar eviction fallback, threshold-triggered compaction);
* **rebuild** — the baseline the static bank forces today: after every
  batch, a full ``build_bank_from_rows`` over the surviving rows.

Both replicas replay the *same* op sequence, and the final incrementally
maintained bank is asserted equivalent to a from-scratch build (every live
row hits, node lists identical) before any timing is reported.

``python -m benchmarks.bench_churn [--smoke|--fast] [--json PATH]`` — the
CI smoke job writes ``BENCH_bank.json`` from here so the maintenance perf
trajectory is recorded per commit.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import MaintenanceEngine, build_bank, build_bank_from_rows
from repro.core import hashing

from .common import parse_bench_args, synthetic_forest, write_json


def _op_sequence(bank, hashes, ops: int, batch: int, seed: int):
    """Batches of (kind, tree, hash, eid, nodes) ops over the bank's rows.

    Deletes target live rows, inserts re-add dead ones; one batch never
    touches the same (tree, entity) twice, so the incremental and rebuild
    replicas see identical well-defined state after every batch.
    """
    rng = np.random.default_rng(seed)
    all_rows = {}
    for r in range(bank.num_rows):
        key = (int(bank.row_tree[r]), int(bank.row_entity[r]))
        all_rows[key] = bank.walk_row(r)
    live = dict(all_rows)
    floor = max(8, len(all_rows) // 4)
    batches: List[List[tuple]] = []
    remaining = ops
    while remaining > 0:
        this, touched = [], set()
        for _ in range(min(batch, remaining)):
            dead = [k for k in all_rows if k not in live and
                    k not in touched]
            do_delete = (len(live) > floor and
                         (not dead or rng.random() < 0.5))
            if do_delete:
                cands = [k for k in live if k not in touched]
                if not cands:
                    break
                k = cands[int(rng.integers(len(cands)))]
                this.append(("del", k[0], int(hashes[k[1]]), k[1], None))
                del live[k]
            else:
                if not dead:
                    break
                k = dead[int(rng.integers(len(dead)))]
                this.append(("ins", k[0], int(hashes[k[1]]), k[1],
                             all_rows[k]))
                live[k] = all_rows[k]
            touched.add(k)
        if not this:
            break
        remaining -= len(this)
        batches.append(this)
    return batches, live


def _live_arrays(live: Dict, hashes: np.ndarray, num_trees: int):
    ks = sorted(live)
    rt = np.asarray([k[0] for k in ks], np.int32)
    re_ = np.asarray([k[1] for k in ks], np.int32)
    rh = hashes[re_].astype(np.uint32)
    lens = np.asarray([len(live[k]) for k in ks], np.int32)
    off = np.zeros(len(ks) + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    nodes = (np.concatenate([np.asarray(live[k], np.int32) for k in ks])
             if ks else np.zeros(0, np.int32))
    return ks, rt, re_, rh, off, nodes


def run(tree_counts: Sequence[int] = (16, 64),
        entities_per_tree: int = 48, ops: int = 1024, batch: int = 64,
        queries_per_batch: int = 64, seed: int = 0) -> List[Dict]:
    rows = []
    for T in tree_counts:
        forest = synthetic_forest(T, entities_per_tree)
        hashes = hashing.hash_entities(forest.entity_names)
        bank = build_bank(forest)
        batches, live = _op_sequence(bank, hashes, ops, batch, seed)
        n_ops = sum(len(b) for b in batches)

        # ---- incremental replica
        inc = build_bank(forest)
        eng = MaintenanceEngine(inc, seed=seed)
        qrng = np.random.default_rng(seed + 1)
        t_inc = t_query = 0.0
        for ops_b in batches:
            t0 = time.perf_counter()
            for kind, tree, h, eid, nodes in ops_b:
                if kind == "del":
                    eng.queue_delete(tree, h)
                else:
                    eng.queue_insert(tree, h, nodes, entity_id=eid)
            eng.maintain()                     # idle window: apply + compact
            t_inc += time.perf_counter() - t0
            # interleaved routed query sweep (host path, both replicas
            # would answer identically — timed once here)
            t0 = time.perf_counter()
            pick = qrng.integers(0, inc.num_rows, size=queries_per_batch)
            for r in pick:
                t = int(inc.row_tree[int(r)])
                inc.lookup(t, int(hashes[int(inc.row_entity[int(r)])]))
            t_query += time.perf_counter() - t0

        # ---- rebuild-from-scratch baseline (same sequence)
        reb_live = {}
        for r in range(bank.num_rows):
            key = (int(bank.row_tree[r]), int(bank.row_entity[r]))
            reb_live[key] = bank.walk_row(r)
        t_reb = 0.0
        for ops_b in batches:
            t0 = time.perf_counter()
            for kind, tree, h, eid, nodes in ops_b:
                key = (tree, eid)
                if kind == "del":
                    reb_live.pop(key, None)
                else:
                    reb_live[key] = nodes
            _, rt, re_, rh, off, nd = _live_arrays(reb_live, hashes, T)
            rebuilt = build_bank_from_rows(T, rt, re_, rh, off, nd)
            t_reb += time.perf_counter() - t0

        # ---- equivalence gate: the incrementally maintained bank answers
        # exactly like a from-scratch bulk build.  No false negatives:
        # every live key's exact hash is stored in its tree.  Identical
        # answers: the routed lookup returns the same node list from both
        # (a rare fingerprint collision aliases both banks identically).
        ks, rt, re_, rh, off, nd = _live_arrays(live, hashes, T)
        fresh = build_bank_from_rows(T, rt, re_, rh, off, nd)
        rows_i, _ = inc.find_exact(rt, rh)
        rows_f, _ = fresh.find_exact(rt, rh)
        equal = (len(live) == int(inc.num_items.sum())
                 and bool((rows_i >= 0).all())
                 and bool((rows_f >= 0).all()))
        for j, k in enumerate(ks):
            h = int(rh[j])
            hi, ri, _ = inc.lookup(k[0], h)
            hf, rf, _ = fresh.lookup(k[0], h)
            if not (hi and hf and
                    inc.walk_row(ri) == fresh.walk_row(rf)):
                equal = False
                break

        rows.append(dict(
            trees=T, start_rows=bank.num_rows, ops=n_ops,
            live_rows=len(live),
            inc_us_per_op=t_inc / n_ops * 1e6,
            rebuild_us_per_op=t_reb / n_ops * 1e6,
            speedup=t_reb / t_inc if t_inc else 0.0,
            query_us=t_query / max(1, len(batches) * queries_per_batch)
            * 1e6,
            expansions=eng.stats["expansions"],
            compactions=eng.stats["compactions"],
            equal=equal,
            final_buckets_inc=inc.total_buckets,
            final_buckets_rebuild=rebuilt.total_buckets,
        ))
    return rows


def print_rows(rows: List[Dict]) -> None:
    print("churn: incremental maintenance vs full rebuild "
          "(paper: cuckoo = dynamic updates)")
    print(f"{'trees':>6s} {'ops':>6s} {'live':>6s} {'inc_us/op':>10s} "
          f"{'reb_us/op':>10s} {'speedup':>8s} {'cmpct':>6s} "
          f"{'equal':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['ops']:6d} {r['live_rows']:6d} "
              f"{r['inc_us_per_op']:10.1f} {r['rebuild_us_per_op']:10.1f} "
              f"{r['speedup']:8.1f} {r['compactions']:6d} "
              f"{str(r['equal']):>6s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_churn")
    smoke = "--smoke" in flags
    fast = smoke or "--fast" in flags
    kw = (dict(tree_counts=(16,), entities_per_tree=48, ops=256, batch=32)
          if smoke else
          dict(tree_counts=(16, 64), entities_per_tree=48, ops=1024)
          if fast else
          dict(tree_counts=(16, 64, 256), entities_per_tree=48, ops=4096))
    rows = run(**kw)
    if any(r["speedup"] <= 1.0 for r in rows):
        rows = run(**kw)        # one retry: absorb CI scheduler noise
    print_rows(rows)
    for r in rows:
        assert r["equal"], "incremental bank diverged from fresh build"
        assert r["speedup"] > 1.0, (
            f"incremental maintenance must beat full rebuild per-op "
            f"(got {r['speedup']:.2f}x at T={r['trees']})")
    if json_path:
        from . import bench_bank
        bank_rows = bench_bank.run(
            tree_counts=(1, 4) if smoke else (1, 8, 64),
            entities_per_tree=8 if smoke else 48,
            batch_per_tree=16 if smoke else 64,
            repeats=1 if smoke else 3)
        write_json(json_path, {"churn": rows, "bank": bank_rows})


if __name__ == "__main__":
    main()
