"""End-to-end RAG serving latency: retrieval vs generation split, CPU-scale
(the paper's system context: retrieval must not bottleneck the LLM)."""
from __future__ import annotations

import time

import jax

from repro.configs import get_arch
from repro.data import HashTokenizer, hospital_corpus
from repro.models import init_params
from repro.serving import RAGPipeline, ServeEngine


def run(num_trees: int = 200, queries: int = 8, max_new: int = 8):
    cfg = get_arch("paper-cftrag").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = hospital_corpus(num_trees=num_trees, num_queries=queries)
    engine = ServeEngine(cfg, params, cache_size=256, batch_size=1)
    rag = RAGPipeline(corpus, engine, tokenizer=HashTokenizer(cfg.vocab))

    rag.answer(corpus.queries[0], max_new_tokens=max_new)   # warm compile
    rows = []
    for q in corpus.queries[:queries]:
        t0 = time.perf_counter()
        ans = rag.retrieve(q)
        t_ret = time.perf_counter() - t0
        t0 = time.perf_counter()
        rag.answer(q, max_new_tokens=max_new)
        t_total = time.perf_counter() - t0
        rows.append({"retrieval_ms": t_ret * 1e3,
                     "generation_ms": (t_total - t_ret) * 1e3,
                     "entities": len(ans.entities)})
    return rows


def main():
    rows = run()
    print("serving: per-query retrieval vs generation (CPU smoke model)")
    print(f"{'q':>3s} {'retrieval_ms':>13s} {'generation_ms':>14s} "
          f"{'entities':>9s}")
    for i, r in enumerate(rows):
        print(f"{i:3d} {r['retrieval_ms']:13.2f} {r['generation_ms']:14.1f} "
              f"{r['entities']:9d}")
    ret = sum(r["retrieval_ms"] for r in rows) / len(rows)
    gen = sum(r["generation_ms"] for r in rows) / len(rows)
    print(f"mean: retrieval {ret:.2f} ms vs generation {gen:.1f} ms "
          f"({100*ret/(ret+gen):.2f}% of latency)")


if __name__ == "__main__":
    main()
