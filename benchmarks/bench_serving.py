"""End-to-end RAG serving latency: retrieval vs generation split, CPU-scale
(the paper's system context: retrieval must not bottleneck the LLM).

``run_bank_sweep`` is the many-tree view the ROADMAP asks for: retrieval
fraction vs T through the bank-routed pipeline, with the per-op maintenance
cost (incremental vs rebuild, from ``bench_churn``) in the same table — one
place to read both what serving a bank of T trees costs and what keeping it
fresh costs."""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax

from repro.configs import get_arch
from repro.data import HashTokenizer, hospital_corpus
from repro.models import init_params
from repro.serving import RAGPipeline, ServeEngine

from .common import timed_call


def run(num_trees: int = 200, queries: int = 8, max_new: int = 8):
    cfg = get_arch("paper-cftrag").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = hospital_corpus(num_trees=num_trees, num_queries=queries)
    engine = ServeEngine(cfg, params, cache_size=256, batch_size=1)
    rag = RAGPipeline(corpus, engine, tokenizer=HashTokenizer(cfg.vocab))

    rag.answer(corpus.queries[0], max_new_tokens=max_new)   # warm compile
    rows = []
    for q in corpus.queries[:queries]:
        ans, t_ret = timed_call(lambda: rag.retrieve(q))
        _, t_total = timed_call(
            lambda: rag.answer(q, max_new_tokens=max_new))
        rows.append({"retrieval_ms": t_ret * 1e3,
                     "generation_ms": (t_total - t_ret) * 1e3,
                     "entities": len(ans.entities)})
    return rows


def run_bank_sweep(tree_counts: Sequence[int] = (8, 32, 128),
                   queries: int = 4, max_new: int = 8,
                   churn_ops: int = 256) -> List[Dict]:
    """Retrieval fraction vs T (bank-routed pipeline) + maintenance cost.

    Retrieval goes through ``use_bank=True`` (device bank lookup, global
    fan-out) so the cost scales with T the way the paper's many-tree claim
    is about; the maintenance columns come from ``bench_churn`` at the
    same T, putting serving cost and upkeep cost side by side.
    """
    from . import bench_churn
    cfg = get_arch("paper-cftrag").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    for T in tree_counts:
        corpus = hospital_corpus(num_trees=T, num_queries=queries)
        engine = ServeEngine(cfg, params, cache_size=256, batch_size=1)
        rag = RAGPipeline(corpus, engine, tokenizer=HashTokenizer(cfg.vocab),
                          use_bank=True)
        rag.answer(corpus.queries[0], max_new_tokens=max_new)  # warm compile
        t_ret = t_gen = 0.0
        for q in corpus.queries[:queries]:
            _, r = timed_call(lambda: rag.retrieve(q))
            # answer() re-runs retrieve inside; subtract this query's cost
            _, t = timed_call(
                lambda: rag.answer(q, max_new_tokens=max_new))
            t_ret += r
            t_gen += max(t - r, 0.0)
        churn = bench_churn.run(tree_counts=(T,), entities_per_tree=24,
                                ops=churn_ops, batch=32)[0]
        ret_ms = t_ret / queries * 1e3
        gen_ms = max(t_gen / queries * 1e3, 1e-6)
        rows.append(dict(
            trees=T, retrieval_ms=ret_ms, generation_ms=gen_ms,
            retrieval_fraction=ret_ms / (ret_ms + gen_ms),
            maint_inc_us_per_op=churn["inc_us_per_op"],
            maint_rebuild_us_per_op=churn["rebuild_us_per_op"],
            maint_speedup=churn["speedup"],
            maint_equal=churn["equal"],
        ))
    return rows


def print_bank_sweep(rows: List[Dict]) -> None:
    print("serving vs #trees: retrieval fraction + bank upkeep cost")
    print(f"{'trees':>6s} {'ret_ms':>8s} {'gen_ms':>8s} {'ret_frac':>9s} "
          f"{'inc_us/op':>10s} {'reb_us/op':>10s} {'maint_x':>8s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['retrieval_ms']:8.2f} "
              f"{r['generation_ms']:8.1f} {r['retrieval_fraction']:9.3f} "
              f"{r['maint_inc_us_per_op']:10.1f} "
              f"{r['maint_rebuild_us_per_op']:10.1f} "
              f"{r['maint_speedup']:8.1f}")


def main():
    rows = run()
    print("serving: per-query retrieval vs generation (CPU smoke model)")
    print(f"{'q':>3s} {'retrieval_ms':>13s} {'generation_ms':>14s} "
          f"{'entities':>9s}")
    for i, r in enumerate(rows):
        print(f"{i:3d} {r['retrieval_ms']:13.2f} {r['generation_ms']:14.1f} "
              f"{r['entities']:9d}")
    ret = sum(r["retrieval_ms"] for r in rows) / len(rows)
    gen = sum(r["generation_ms"] for r in rows) / len(rows)
    print(f"mean: retrieval {ret:.2f} ms vs generation {gen:.1f} ms "
          f"({100*ret/(ret+gen):.2f}% of latency)")
    print()
    print_bank_sweep(run_bank_sweep())


if __name__ == "__main__":
    main()
