"""Kernel benchmarks: the gated fused-vs-unfused retrieval sweep plus the
per-kernel oracle-error microbenchmarks.

The headline metric is ``fused_speedup`` — wall time of the unfused jitted
``retrieve_device`` chain (arena probe -> bump -> CSR gather -> hierarchy
walks, each materializing its (B,)-shaped intermediates) divided by the
single-pass :mod:`repro.kernels.fused_retrieve` launch, on skewed deep
forests at T in {16, 64, 256} and hit rates {0.1, 0.9}.  Dimensionless and
measured within one process, so the committed baseline gates CI runners
(``benchmarks/check_regression.py``); every timed pair is preceded by a
bit-identity assert, so a fast-but-wrong kernel can never post a win.

Raw per-batch times ride along unngated; the oracle-error micro rows
(``micro``) keep the numerical columns the old print-only bench reported.
"""
from __future__ import annotations

import sys

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import parse_bench_args, write_json

BATCH = 512
SWEEP_TREES = (16, 64, 256)
HIT_RATES = (0.1, 0.9)


def skewed_forest(num_trees: int, seed: int = 0):
    """Skewed deep forest: most trees are small and flat, every 7th is a
    hub with a deep random-parent tail — the adversarial layout for the
    fused kernel's ragged routing + hierarchy walks."""
    from repro.core import build_forest
    rng = np.random.default_rng(seed)
    trees = []
    for t in range(num_trees):
        names = [f"e{t}_{i}" for i in range(4)]
        edges = [(f"r{t}", n) for n in names]
        if t % 7 == 0:                      # hub tree: deep + skewed
            for j in range(40):
                parent = names[int(rng.integers(len(names)))]
                child = f"e{t}_h{j}"
                edges.append((parent, child))
                names.append(child)
        trees.append(edges)
    return build_forest(trees), trees


def _queries(forest, trees, num_trees: int, hit_rate: float, seed: int):
    from repro.core import hashing
    rng = np.random.default_rng(seed)
    per_tree = [[c for _, c in edges] for edges in trees]
    qt = rng.integers(num_trees, size=BATCH).astype(np.int32)
    qh = np.empty(BATCH, np.uint32)
    hit = rng.random(BATCH) < hit_rate
    for i in range(BATCH):
        if hit[i]:
            ents = per_tree[qt[i]]
            qh[i] = hashing.entity_hash(ents[int(rng.integers(len(ents)))])
        else:
            qh[i] = rng.integers(1, 2 ** 32)
    return jnp.asarray(qh), jnp.asarray(qt)


def fused_rows(iters: int, seed: int = 0):
    """The gated sweep: assert bit-identity, then time both paths."""
    from repro.core import CFTDeviceState, build_index, retrieve_device
    from repro.kernels.fused_retrieve import fused_retrieve_state_auto

    rows = []
    for num_trees in SWEEP_TREES:
        forest, trees = skewed_forest(num_trees, seed=seed)
        # size for a realistic ~0.7 load over 4-slot buckets: an
        # arena padded to the next power of two past E/3 rows
        idx = build_index(forest, num_buckets=1 << int(np.ceil(
            np.log2(max(64, forest.num_entities // 3)))))
        state = CFTDeviceState.from_index(idx)
        unfused = jax.jit(retrieve_device, static_argnames=("max_locs", "n"))
        for hr in HIT_RATES:
            qh, qt = _queries(forest, trees, num_trees, hr, seed + 1)
            ref = jax.block_until_ready(unfused(state, qh, qt))
            got = fused_retrieve_state_auto(state, qh, qt)
            assert got is not None, "fused path unavailable on this host"
            jax.block_until_ready(got)
            for f in ("hit", "locations", "up", "down", "temperature"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, f)),
                    np.asarray(getattr(got, f)),
                    err_msg=f"fused != unfused on {f} "
                            f"(T={num_trees}, hit_rate={hr})")
            t_un, t_fu = _interleaved_best(
                lambda: jax.block_until_ready(unfused(state, qh, qt)),
                lambda: jax.block_until_ready(
                    fused_retrieve_state_auto(state, qh, qt)),
                iters)
            rows.append(dict(trees=num_trees, batch=BATCH, hit_rate=hr,
                             unfused_ms=t_un * 1e3, fused_ms=t_fu * 1e3,
                             fused_speedup=t_un / t_fu))
    return rows


def _interleaved_best(fn_a, fn_b, rounds: int):
    """Best-of-N with A/B interleaved per round, so a noisy scheduling
    window on a shared host degrades both sides instead of biasing the
    ratio toward whichever ran in the quiet window."""
    fn_a(), fn_b()                                 # absorb compiles
    best_a = best_b = float("inf")
    for _ in range(max(1, rounds)):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def micro_rows():
    """Per-kernel oracle deltas (the old print-only bench): wall time on
    CPU is interpret-mode and not meaningful for the TPU target, so the
    derived column reports max |err| vs the jnp oracle (1 = exact)."""
    rng = np.random.default_rng(0)
    rows = []

    # cuckoo lookup: exactness + table footprint
    from repro.core import build_forest, build_index
    from repro.core import hashing
    from repro.kernels.cuckoo_lookup import cuckoo_lookup, cuckoo_lookup_ref
    forest = build_forest([[(f"r{t}", f"e{t}_{i}") for i in range(8)]
                           for t in range(80)])
    idx = build_index(forest, num_buckets=1024)
    t = idx.filter.tables()
    fps, heads = jnp.asarray(t.fingerprints), jnp.asarray(t.heads)
    h = jnp.asarray(hashing.hash_entities(
        [forest.entity_names[i % forest.num_entities] for i in range(256)]))
    ref = cuckoo_lookup_ref(fps, heads, h)
    ker = cuckoo_lookup(fps, heads, h, interpret=True)
    exact = int(np.array_equal(np.asarray(ref.head), np.asarray(ker.head)))
    vmem_kib = t.fingerprints.size * 4 * 2 / 1024
    rows.append(dict(name="cuckoo_lookup/exact", work=vmem_kib,
                     derived=float(exact)))

    # flash attention: fwd error at a training-relevant tile
    from repro.kernels.flash_attention import attention_ref, flash_attention
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 128)), jnp.bfloat16)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, True, None, True).astype(jnp.float32)
        - attention_ref(q, k, v, causal=True).astype(jnp.float32))))
    flops = 4 * 1 * 8 * 512 * 512 * 128 / 2
    rows.append(dict(name="flash_attention/bf16_err", work=flops / 1e6,
                     derived=err))

    # decode attention: GQA-grouped split-KV
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    qd = jnp.asarray(rng.normal(size=(4, 8, 128)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(4, 2, 2048, 128)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(4, 2, 2048, 128)), jnp.float32)
    lens = jnp.asarray([2048, 1500, 700, 1], jnp.int32)
    errd = float(jnp.max(jnp.abs(
        decode_attention(qd, kd, vd, lens, interpret=True)
        - decode_attention_ref(qd, kd, vd, lens))))
    rows.append(dict(name="decode_attention/f32_err",
                     work=4 * 8 * 2048 * 128 * 4 / 1e6, derived=errd))

    # linear scan: strong-decay regime
    from repro.kernels.linear_scan import linear_scan, linear_scan_ref
    qs = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    ks = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    gs = jnp.asarray(-np.abs(rng.normal(size=(1, 4, 256, 64))) * 5.0,
                     jnp.float32)
    ok, sk = linear_scan(qs, ks, vs, gs, None, inclusive=False,
                         interpret=True)
    orf, srf = linear_scan_ref(qs, ks, vs, gs, None, inclusive=False)
    errs = float(jnp.max(jnp.abs(ok - orf)))
    rows.append(dict(name="linear_scan/strong_decay_err",
                     work=256 * 64 * 64 * 4 / 1e6, derived=errs))
    return rows


def main(argv=None) -> int:
    from repro.obs import get_registry
    flags, json_path = parse_bench_args(
        sys.argv[1:] if argv is None else argv, "bench_kernels")
    iters = 4 if "--fast" in flags else (12 if "--smoke" in flags else 24)

    rows = fused_rows(iters)
    print("fused retrieval sweep (skewed forests, B=512, bit-identity "
          "asserted before timing)")
    print(f"  {'T':>4s} {'hit':>4s} {'unfused_ms':>11s} "
          f"{'fused_ms':>9s} {'speedup':>8s}")
    for r in rows:
        print(f"  {r['trees']:4d} {r['hit_rate']:4.1f} "
              f"{r['unfused_ms']:11.3f} {r['fused_ms']:9.3f} "
              f"{r['fused_speedup']:7.2f}x")

    micro = micro_rows()
    print("kernel microbenchmarks (derived = max|err| vs oracle, 1=exact)")
    for r in micro:
        print(f"  {r['name']:34s} work~{r['work']:10.1f}  "
              f"derived {r['derived']:.3e}")

    write_json(json_path, {"rows": rows, "micro": micro,
                           "obs": get_registry().snapshot()})
    return 0


if __name__ == "__main__":
    sys.exit(main())
