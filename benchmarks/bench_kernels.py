"""Kernel micro-benchmarks: per-kernel work estimates + oracle-vs-kernel
numerical deltas (wall time on CPU is interpret-mode and not meaningful for
the TPU target; the derived column reports max |err| vs the jnp oracle)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def run():
    rng = np.random.default_rng(0)
    rows = []

    # cuckoo lookup: exactness + table footprint
    from repro.core import build_forest, build_index
    from repro.core import hashing
    from repro.kernels.cuckoo_lookup import cuckoo_lookup, cuckoo_lookup_ref
    forest = build_forest([[(f"r{t}", f"e{t}_{i}") for i in range(8)]
                           for t in range(80)])
    idx = build_index(forest, num_buckets=1024)
    t = idx.filter.tables()
    fps, heads = jnp.asarray(t.fingerprints), jnp.asarray(t.heads)
    h = jnp.asarray(hashing.hash_entities(
        [forest.entity_names[i % forest.num_entities] for i in range(256)]))
    ref = cuckoo_lookup_ref(fps, heads, h)
    ker = cuckoo_lookup(fps, heads, h, interpret=True)
    exact = int(np.array_equal(np.asarray(ref.head), np.asarray(ker.head)))
    vmem_kib = t.fingerprints.size * 4 * 2 / 1024
    rows.append(("cuckoo_lookup/exact", vmem_kib, float(exact)))

    # flash attention: fwd error at a training-relevant tile
    from repro.kernels.flash_attention import attention_ref, flash_attention
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 128)), jnp.bfloat16)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, True, None, True).astype(jnp.float32)
        - attention_ref(q, k, v, causal=True).astype(jnp.float32))))
    flops = 4 * 1 * 8 * 512 * 512 * 128 / 2
    rows.append(("flash_attention/bf16_err", flops / 1e6, err))

    # decode attention: GQA-grouped split-KV
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    qd = jnp.asarray(rng.normal(size=(4, 8, 128)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(4, 2, 2048, 128)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(4, 2, 2048, 128)), jnp.float32)
    lens = jnp.asarray([2048, 1500, 700, 1], jnp.int32)
    errd = float(jnp.max(jnp.abs(
        decode_attention(qd, kd, vd, lens, interpret=True)
        - decode_attention_ref(qd, kd, vd, lens))))
    rows.append(("decode_attention/f32_err", 4 * 8 * 2048 * 128 * 4 / 1e6,
                 errd))

    # linear scan: strong-decay regime
    from repro.kernels.linear_scan import linear_scan, linear_scan_ref
    qs = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    ks = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    gs = jnp.asarray(-np.abs(rng.normal(size=(1, 4, 256, 64))) * 5.0,
                     jnp.float32)
    ok, sk = linear_scan(qs, ks, vs, gs, None, inclusive=False,
                         interpret=True)
    orf, srf = linear_scan_ref(qs, ks, vs, gs, None, inclusive=False)
    errs = float(jnp.max(jnp.abs(ok - orf)))
    rows.append(("linear_scan/strong_decay_err", 256 * 64 * 64 * 4 / 1e6,
                 errs))
    return rows


def main():
    print("kernel microbenchmarks (derived = max|err| vs oracle, or 1=exact)")
    for name, work, derived in run():
        print(f"  {name:34s} work~{work:10.1f}  derived {derived:.3e}")


if __name__ == "__main__":
    main()
