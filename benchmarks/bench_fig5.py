"""Paper Figure 5 (ablation): per-round retrieval time with and without the
temperature-sorting design; repeated query rounds exploit locality."""
from __future__ import annotations

import random
import time

from repro.core import CFTRAG, build_forest, build_index
from repro.data import hospital_corpus


def run(num_trees: int = 300, rounds: int = 8, hot_entities: int = 24,
        queries_per_round: int = 200, seed: int = 11):
    corpus = hospital_corpus(num_trees=num_trees, num_queries=4, seed=seed)
    forest = build_forest(corpus.trees)
    rng = random.Random(seed)
    hot = rng.sample(forest.entity_names, hot_entities)

    rows = []
    for sorted_mode in (False, True):
        index = build_index(forest, num_buckets=1024, seed=0xBEEF)
        r = CFTRAG(index, sort_every=1 if sorted_mode else 0)
        rng2 = random.Random(seed + 1)
        for rnd in range(rounds):
            # zipf-ish locality: the same hot set dominates every round
            batch = [rng2.choice(hot) for _ in range(queries_per_round)]
            p0 = index.filter.probes
            t0 = time.perf_counter()
            r.retrieve(batch, n=1)
            dt = time.perf_counter() - t0
            rows.append({"sorted": sorted_mode, "round": rnd + 1,
                         "time_s": dt,
                         "probes": index.filter.probes - p0})
    return rows


def main():
    print("fig5: per-round retrieval, temperature sort on/off "
          "(paper Figure 5 ablation; probes = slot comparisons)")
    rows = run()
    print(f"{'round':>6s} {'unsorted_probes':>16s} {'sorted_probes':>14s} "
          f"{'gain':>6s} {'unsorted_s':>11s} {'sorted_s':>9s}")
    for rnd in range(1, 9):
        u = next(r for r in rows if not r["sorted"] and r["round"] == rnd)
        s = next(r for r in rows if r["sorted"] and r["round"] == rnd)
        print(f"{rnd:6d} {u['probes']:16d} {s['probes']:14d} "
              f"{u['probes']/s['probes']:6.2f} {u['time_s']:11.6f} "
              f"{s['time_s']:9.6f}")


if __name__ == "__main__":
    main()
