"""Paper Table 1: retrieval time of each algorithm vs number of trees
(50 / 300 / 600), 5 entities per query."""
from __future__ import annotations

from .common import ALGOS, accuracy_proxy, build_retrievers, time_retrieval


def run(tree_counts=(50, 300, 600), entities_per_query: int = 5,
        num_queries: int = 20):
    rows = []
    for n in tree_counts:
        corpus, forest, rets = build_retrievers(num_trees=n)
        queries = [q[:entities_per_query] for q in
                   corpus.query_entities[:num_queries]]
        naive = rets["naive"]
        for algo in ALGOS:
            t = time_retrieval(rets[algo], queries)
            acc = accuracy_proxy(forest, rets[algo], queries, naive)
            rows.append({"trees": n, "algo": algo, "time_s": t,
                         "acc": acc,
                         "speedup_vs_naive": None})
        base = next(r["time_s"] for r in rows
                    if r["trees"] == n and r["algo"] == "naive")
        for r in rows:
            if r["trees"] == n:
                r["speedup_vs_naive"] = base / r["time_s"]
    return rows


def main():
    print("table1: retrieval time vs #trees (paper Table 1)")
    print(f"{'trees':>6s} {'algo':>6s} {'time_s':>12s} {'speedup':>9s} "
          f"{'acc':>6s}")
    for r in run():
        print(f"{r['trees']:6d} {r['algo']:>6s} {r['time_s']:12.6f} "
              f"{r['speedup_vs_naive']:9.1f} {r['acc']:6.3f}")


if __name__ == "__main__":
    main()
