"""Ragged arena vs dense pad-to-max — the hierarchical-skew claim, measured.

Real entity forests are skewed (one hot tree holding many times the
entities of its neighbours); the old dense ``(T, NB, S)`` bank padded
*every* tree to the hot tree's bucket count.  On a skewed forest (one tree
``hot_factor``x larger than the rest) this sweep records, per T:

* **bytes** — ragged arena device bytes (``sum nb_t`` rows) vs what the
  dense pad-to-max layout would pay (``T * max nb_t`` rows), three tables
  each;
* **expansion** — wall-clock of a single-tree ``expand_tree`` (restages
  only the hot tree's arena segment) vs a full-bank restage at doubled
  bucket counts (what the dense layout forced on any overflow);
* **equivalence gate** — host lookup, pure-jnp ragged lookup and the
  row-tiled Pallas arena kernel must answer bit-identically on a mixed
  hit/miss batch before any number is reported.

``python -m benchmarks.bench_ragged [--smoke] [--json BENCH_ragged.json]``
— the CI smoke job records ``BENCH_ragged.json`` next to
``BENCH_bank.json`` / ``BENCH_shard.json``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core import (MaintenanceEngine, build_bank, build_forest,
                        lookup_batch_ragged)
from repro.core import hashing

from .common import best_time, parse_bench_args, write_json


def skewed_forest(num_trees: int, entities_per_tree: int,
                  hot_factor: int = 16, hot_tree: int = 0):
    """One-root trees where ``hot_tree`` holds ``hot_factor``x the
    entities of every other tree — the hierarchical-skew shape."""
    sizes = [entities_per_tree * (hot_factor if t == hot_tree else 1)
             for t in range(num_trees)]
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(sizes[t])]
         for t in range(num_trees)])


def _equivalence(bank, forest) -> bool:
    """Host vs jnp vs Pallas kernel, bit-identical (hits and misses on
    hit/head; bucket/slot on hits, as everywhere else in the suite)."""
    import jax.numpy as jnp
    from repro.kernels.cuckoo_lookup import cuckoo_lookup_ragged

    hashes = hashing.hash_entities(forest.entity_names)
    tid = np.concatenate([bank.row_tree,
                          np.zeros(32, np.int32)]).astype(np.int32)
    hh = np.concatenate([hashes[bank.row_entity],
                         hashing.hash_entities([f"missing {i}"
                                                for i in range(32)])])
    args = (jnp.asarray(bank.fingerprints), jnp.asarray(bank.heads),
            jnp.asarray(bank.bucket_offsets.astype(np.int32)),
            jnp.asarray(bank.tree_nb), jnp.asarray(tid), jnp.asarray(hh))
    ref = lookup_batch_ragged(*args)
    ker = cuckoo_lookup_ragged(*args, interpret=True)
    m = np.asarray(ref.hit)
    ok = (np.array_equal(np.asarray(ker.hit), m)
          and np.array_equal(np.asarray(ker.head), np.asarray(ref.head))
          and np.array_equal(np.asarray(ker.bucket)[m],
                             np.asarray(ref.bucket)[m])
          and np.array_equal(np.asarray(ker.slot)[m],
                             np.asarray(ref.slot)[m]))
    for r in range(0, bank.num_rows, max(1, bank.num_rows // 256)):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        hit, row, _ = bank.lookup(t, int(hashes[e]))
        j = r                        # batch order == row order for hits
        ok &= hit and bool(m[j]) and int(np.asarray(ref.head)[j]) == row
    return bool(ok)


def run(tree_counts: Sequence[int] = (64, 256),
        entities_per_tree: int = 8, hot_factor: int = 16,
        iters: int = 3, seed: int = 0) -> List[Dict]:
    rows = []
    for t in tree_counts:
        forest = skewed_forest(t, entities_per_tree, hot_factor)
        bank = build_bank(forest)
        slot_bytes = bank.slots * 4 * 3          # fp + temp + heads tables
        dense_rows = t * int(bank.tree_nb.max())
        equal = _equivalence(bank, forest)

        def _expand_hot():
            eng = MaintenanceEngine(build_bank(forest), seed=seed)
            return lambda: eng.expand_tree(0, force=True)

        def _full_restage():
            eng = MaintenanceEngine(build_bank(forest), seed=seed)
            return lambda: eng.expand()

        t_tree = min(best_time(_expand_hot(), 1, warmup=False)
                     for _ in range(iters))
        t_full = min(best_time(_full_restage(), 1, warmup=False)
                     for _ in range(iters))

        rows.append(dict(
            trees=t, hot_factor=hot_factor,
            items=int(bank.num_items.sum()),
            arena_rows=bank.total_buckets, dense_rows=dense_rows,
            ragged_bytes=bank.total_buckets * slot_bytes,
            dense_bytes=dense_rows * slot_bytes,
            bytes_fraction=bank.total_buckets / dense_rows,
            expand_tree_ms=t_tree * 1e3, full_restage_ms=t_full * 1e3,
            expand_speedup=t_full / t_tree if t_tree else 0.0,
            equal=equal,
        ))
    return rows


def print_rows(rows: List[Dict]) -> None:
    print("ragged arena vs dense pad-to-max (skewed forest, "
          "one tree {}x larger)".format(rows[0]["hot_factor"] if rows
                                        else "?"))
    print(f"{'trees':>6s} {'items':>7s} {'arena':>7s} {'dense':>7s} "
          f"{'bytes%':>7s} {'tree_ms':>9s} {'full_ms':>9s} "
          f"{'exp_x':>6s} {'equal':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['items']:7d} {r['arena_rows']:7d} "
              f"{r['dense_rows']:7d} {100 * r['bytes_fraction']:6.1f}% "
              f"{r['expand_tree_ms']:9.3f} {r['full_restage_ms']:9.3f} "
              f"{r['expand_speedup']:6.1f} {str(r['equal']):>6s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_ragged",
                                        flags=("--smoke",))
    # min-of-iters fresh-engine timings per side + retries: the expand
    # latency gate compares sub-millisecond wall clocks, so one scheduler
    # stall must never be able to fail CI
    kw = (dict(tree_counts=(64,), entities_per_tree=6, iters=5)
          if "--smoke" in flags else
          dict(tree_counts=(64, 256), entities_per_tree=8, iters=5))
    rows = run(**kw)
    for _ in range(2):              # retries: absorb CI scheduler noise
        if all(r["expand_speedup"] > 1.0 for r in rows):
            break
        rows = run(**kw)
    print_rows(rows)
    for r in rows:
        assert r["equal"], "ragged lookup diverged from reference"
        # the memory claim: arena bytes well under the dense pad-to-max
        assert r["ragged_bytes"] < 0.5 * r["dense_bytes"], r
        # the latency claim: one hot tree's expand beats a bank restage
        assert r["expand_speedup"] > 1.0, r
    write_json(json_path, {"rows": rows})


if __name__ == "__main__":
    main()
