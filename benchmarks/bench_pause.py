"""Serve-interruption under churn: synchronous vs double-buffered restage.

A serving engine under dynamic updates (the regime Bridge-RAG and the
ROADMAP's promoted item care about) used to eat a full device restage
between query batches every time maintenance changed the bank.  The
double-buffered path splits that pause: ``prepare`` (host maintenance +
staging of only the changed bytes) runs while the *previous* batch is
still in flight on the old state, and ``commit`` splices O(changed-bytes)
into the live arena and swaps atomically.

This bench drives a retrieval serve loop over a skewed forest while a
churn schedule queues inserts/deletes and periodically force-expands the
hot tree, and measures the **exclusive serve-blocked window** each design
imposes between two batches:

* **sync_pause** — the worst maintain + full-restage window (the old
  single-call idle hook cannot serve through it: the bank is
  mid-mutation and the whole device state is being re-staged);
* **db_pause** — the worst commit + swap window of the double-buffered
  path.  Prepare (host maintenance, payload staging, splice compilation
  via ``warm_restage``) runs while a dispatched batch is in flight on
  the old state — that batch's results are consumed, so "serving
  continues through prepare" is exercised, not assumed;
* **pause_reduction** — sync_pause / db_pause (the acceptance gate:
  >= 5x), with the steady per-batch serve time reported alongside.

Everything is **equivalence-gated** before any number is reported: after
the full churn schedule the committed state must be bit-identical to a
from-scratch restage (``CFTDeviceState.from_bank`` replicated;
``stage_sharded_bank`` at the live padding when a mesh is available) on
every table.  Both modes run once untimed first so the timed pass
measures steady-state serving rather than first-touch XLA compiles
(which a live server pays inside prepare, off the serve path — but this
CI host shares two cores between compile and the serve stream).

``python -m benchmarks.bench_pause [--smoke] [--json BENCH_pause.json]``
— CI runs the smoke shape on an 8-device host mesh (so the sharded row is
exercised too) and uploads ``BENCH_pause.json`` next to the other bench
artifacts.
"""
from __future__ import annotations


import time
from typing import Dict, List

import numpy as np

from repro.core import (CFTDeviceState, MaintenanceEngine,
                        ShardedMaintenanceEngine, build_bank, build_forest,
                        commit_restage, retrieve_device,
                        sharded_retrieve_device, stage_sharded_bank,
                        warm_restage)
from repro.core import hashing

from .bench_ragged import skewed_forest
from .common import parse_bench_args, write_json

_STATE_FIELDS = ("fingerprints", "temperature", "heads", "csr_offsets",
                 "csr_nodes")

_REPL_STEP = None     # one jitted replicated step shared across runs, as
#                       a long-lived serving engine would hold it


def _build(num_trees: int, entities_per_tree: int, hot_factor: int,
           seed: int, mesh=None):
    import jax
    global _REPL_STEP
    forest = skewed_forest(num_trees, entities_per_tree, hot_factor)
    bank = build_bank(forest)
    if mesh is not None:
        sbank = bank.shard(int(mesh.shape["model"]))
        eng = ShardedMaintenanceEngine(sbank, seed=seed)
        state = stage_sharded_bank(sbank, forest, mesh, "model")
        restage = lambda: stage_sharded_bank(       # noqa: E731
            eng.sbank, forest, mesh, "model")
        step = sharded_retrieve_device
    else:
        eng = MaintenanceEngine(bank, seed=seed)
        state = CFTDeviceState.from_bank(bank, forest)
        restage = lambda: CFTDeviceState.from_bank(  # noqa: E731
            eng.bank, forest)
        if _REPL_STEP is None:
            _REPL_STEP = jax.jit(retrieve_device)
        step = _REPL_STEP                 # as the serving engine stages it
    eng.mark_staged()
    jax.block_until_ready(state.fingerprints)
    return forest, bank, eng, state, restage, step


def _make_query_batches(forest, bank, batch: int, n: int, seed: int):
    """Pre-built (hashes, trees) batches: stored rows + ~10% misses."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    hashes = hashing.hash_entities(forest.entity_names)
    out = []
    for _ in range(n):
        rows = rng.integers(0, bank.num_rows, size=batch)
        tid = bank.row_tree[rows].astype(np.int32)
        hs = hashes[bank.row_entity[rows]].astype(np.uint32)
        miss = rng.random(batch) < 0.1
        hs = np.where(miss, rng.integers(1, 2 ** 32, batch,
                                         dtype=np.uint64).astype(np.uint32),
                      hs)
        out.append((jnp.asarray(hs), jnp.asarray(tid)))
    return out


def _queue_churn(eng, num_trees: int, rng, inserts: int, deletes: int,
                 serial: List[int], live: List):
    """Queue inserts of fresh keys and deletes of previously inserted
    live ones — every delete resolves, so the delta scatter carries real
    cleared slots (the dead-row fraction still stays far below the
    compaction threshold at bench sizes)."""
    # deletes first draw only from earlier cycles' keys: within one delta
    # deletes apply before inserts, so a same-cycle key would miss
    for _ in range(min(deletes, len(live))):
        t, name = live.pop(int(rng.integers(len(live))))
        eng.queue_delete(t, name)
    for _ in range(inserts):
        t = int(rng.integers(num_trees))
        name = f"churn {serial[0]}"
        eng.queue_insert(t, name, [int(rng.integers(64))])
        live.append((t, name))
        serial[0] += 1


def run_mode(mode: str, *, num_trees: int, entities_per_tree: int,
             hot_factor: int, cycles: int, batches_per_cycle: int,
             batch: int, seed: int, inserts: int = 12, deletes: int = 6,
             mesh=None) -> Dict:
    """One serve loop under churn; returns gap stats + equivalence."""
    import jax
    forest, bank, eng, state, restage, step = _build(
        num_trees, entities_per_tree, hot_factor, seed, mesh)
    queries = _make_query_batches(forest, bank, batch, 8, seed)
    rng = np.random.default_rng(seed + 1)
    serial = [0]
    live: List = []
    hot = 0
    times: List[float] = []
    changed_rows = 0
    plans: Dict[str, int] = {}

    def serve(state, i):
        hs, tid = queries[i % len(queries)]
        out = step(state, hs, tid)
        return state.with_temperature(out.temperature), out

    # warmup: compile the serve step (and one full restage for sync)
    state, out = serve(state, 0)
    jax.block_until_ready(out.hit)

    windows: List[float] = []            # serve-blocked exclusive windows
    for cycle in range(cycles):
        for b in range(batches_per_cycle):
            state, out = serve(state, cycle * batches_per_cycle + b)
            jax.block_until_ready(out.hit)
            times.append(time.perf_counter())
        _queue_churn(eng, num_trees, rng, inserts=inserts,
                     deletes=deletes, serial=serial, live=live)
        # a forced hot-tree expansion every third cycle exercises the
        # segment-splice path; it must follow the absorb inside maintain
        # (geometry changes invalidate a stale-temperature harvest)
        expand = cycle % 3 == 2
        if mode == "sync":
            # the old single-call idle window: host maintenance + full
            # device restage, all of it serve-blocking by construction —
            # no query can run against a bank that is mid-mutation
            t0 = time.perf_counter()
            rep = eng.maintain(state)
            if expand:
                eng.expand_tree(hot, force=True)
            if rep.changed or expand:
                state = restage()
                eng.mark_staged()
                jax.block_until_ready(state.fingerprints)
            windows.append(time.perf_counter() - t0)
        else:
            # double-buffered: a batch is dispatched (async) on the old
            # state *before* prepare — host maintenance, payload staging,
            # splice compilation all run while it is in flight, and its
            # results are consumed afterwards (the equivalence gate below
            # proves serving on the pre-commit state stays exact).  Only
            # the O(changed-bytes) commit + swap blocks serving.
            state2, out2 = serve(state, cycle)
            rep = eng.maintain(state)   # pre-dispatch temps; in-flight
            if expand:                  # bumps harvest next cycle
                eng.expand_tree(hot, force=True)
            plan = (eng.plan_restage() if rep.changed or expand
                    else None)
            if plan is not None:
                warm_restage(state, plan)   # compile off the serve path
            jax.block_until_ready(out2.hit)
            state = state2
            t0 = time.perf_counter()
            if plan is not None:
                plans[plan.kind] = plans.get(plan.kind, 0) + 1
                changed_rows += getattr(plan, "changed_rows", 0)
                state = commit_restage(state, plan, eng, forest)
                jax.block_until_ready(state.fingerprints)
            windows.append(time.perf_counter() - t0)

    # ------------------------------------------------- equivalence gate
    # harvest the straggler bumps of the last in-flight batch first (the
    # first post-commit batch would); then the committed state must match
    # a from-scratch restage bit-for-bit
    eng.absorb(state)
    if mesh is not None:
        ref = stage_sharded_bank(eng.sbank, forest, mesh, "model",
                                 arena_rows=state.arena_rows_per_shard)
        fields = _STATE_FIELDS + ("tree_shard", "tree_offset", "tree_nb")
    else:
        ref = CFTDeviceState.from_bank(eng.bank, forest)
        fields = _STATE_FIELDS + ("bucket_offsets", "tree_nb")
    equal = all(
        np.asarray(getattr(state, f)).shape
        == np.asarray(getattr(ref, f)).shape
        and np.array_equal(np.asarray(getattr(state, f)),
                           np.asarray(getattr(ref, f)))
        for f in fields)

    gaps = np.diff(np.asarray(times))
    return dict(mode=mode, gaps=gaps,
                median_gap_ms=float(np.median(gaps)) * 1e3,
                max_window_ms=float(max(windows)) * 1e3,
                equal=bool(equal), plans=plans,
                staged_rows=changed_rows,
                arena_rows=(eng.sbank.total_buckets if mesh is not None
                            else eng.bank.total_buckets))


def run(num_trees: int = 256, entities_per_tree: int = 64,
        hot_factor: int = 16, cycles: int = 6, batches_per_cycle: int = 8,
        batch: int = 192, seed: int = 0, inserts: int = 32,
        deletes: int = 12, use_mesh: bool = True) -> List[Dict]:
    """Sync-vs-double-buffered rows; a sharded pair rides along when the
    backend exposes >= 2 devices (CI forces an 8-device host mesh)."""
    import jax
    kw = dict(num_trees=num_trees, entities_per_tree=entities_per_tree,
              hot_factor=hot_factor, cycles=cycles,
              batches_per_cycle=batches_per_cycle, batch=batch, seed=seed,
              inserts=inserts, deletes=deletes)
    rows = []
    for layout, mesh in [("replicated", None)] + (
            [("sharded", jax.make_mesh(
                (min(8, jax.device_count()),), ("model",)))]
            if use_mesh and jax.device_count() >= 2 else []):
        # one untimed pass first: the same seeds reproduce the same churn
        # schedule, so every splice geometry's executable is compiled and
        # the timed pass measures steady-state serving.  (A live server
        # compiles cold geometries in the prepare phase too — but this CI
        # host shares its few cores between XLA compile and the serve
        # stream, which would bill the overlap-hidden compile to the gap.)
        run_mode("double_buffered", mesh=mesh, **kw)
        sync = run_mode("sync", mesh=mesh, **kw)
        db = run_mode("double_buffered", mesh=mesh, **kw)
        # the serve-interruption is the exclusive window each design
        # imposes between two batches: sync cannot serve through host
        # maintenance + full restage by construction; double-buffered
        # blocks only for the O(changed-bytes) commit + swap (the run
        # above served a batch during every prepare, equivalence-gated)
        rows.append(dict(layout=layout, trees=num_trees,
                         arena_rows=sync["arena_rows"],
                         serve_ms=sync["median_gap_ms"],
                         sync_max_pause_ms=sync["max_window_ms"],
                         db_max_pause_ms=db["max_window_ms"],
                         pause_reduction=sync["max_window_ms"]
                         / max(db["max_window_ms"], 1e-6),
                         staged_rows=db["staged_rows"],
                         plans=db["plans"],
                         equal=sync["equal"] and db["equal"]))
    return rows


def print_rows(rows: List[Dict]) -> None:
    print("serve-interruption under churn: synchronous restage vs "
          "double-buffered splice commit")
    print(f"{'layout':>10s} {'arena':>7s} {'serve':>8s} "
          f"{'sync_pause':>11s} {'db_pause':>9s} {'pause_x':>8s} "
          f"{'equal':>6s}")
    for r in rows:
        print(f"{r['layout']:>10s} {r['arena_rows']:7d} "
              f"{r['serve_ms']:7.2f}m {r['sync_max_pause_ms']:10.2f}m "
              f"{r['db_max_pause_ms']:8.2f}m "
              f"{r['pause_reduction']:8.1f} {str(r['equal']):>6s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_pause",
                                        flags=("--smoke",))
    kw = (dict(num_trees=192, entities_per_tree=48, cycles=5,
               batches_per_cycle=8, batch=160)
          if "--smoke" in flags else
          dict(num_trees=256, entities_per_tree=64, cycles=6,
               batches_per_cycle=8, batch=192))
    rows = run(**kw)
    # the pause gate compares wall-clock gaps -- retry so a scheduler
    # stall on shared CI hardware can never fail the job on its own
    for _ in range(2):
        if all(r["pause_reduction"] >= 5.0 for r in rows):
            break
        rows = run(**kw)
    print_rows(rows)
    for r in rows:
        assert r["equal"], \
            "post-commit state diverged from from-scratch restage"
        assert r["pause_reduction"] >= 5.0, r
    # embed the observability snapshot (plan kinds, splice rows, compile
    # counts) so a pause_reduction regression carries its causal trail
    from repro.obs import get_registry
    write_json(json_path, {"rows": rows,
                           "obs": get_registry().snapshot()})


if __name__ == "__main__":
    main()
