"""Goodput under injected faults: the chaos benchmark behind the
fault-tolerance claim.

Two identically built continuous-batching runs consume the same Poisson
request stream with the same background churn.  The second runs under a
deterministic :class:`FaultPlan` and must keep serving through every
named fault site:

* **dispatch** — the second launched batch raises before the device
  step: exactly that batch's futures fail (typed ``InjectedFault``), the
  scheduler survives, and every other request serves normally;
* **prepare** — the first in-engine maintenance pass raises before
  touching the bank: the plan quarantines, the breaker backs off, and a
  later cycle recovers via a full restage;
* **commit** / **snapshot-write** — driven synchronously after the
  stream (their ordinals inside a live engine depend on scheduler
  timing): a commit raise rolls back to the still-serving state, and a
  snapshot write crashed before its atomic rename leaves the snapshot
  set intact while the next write lands.

Gates: every submitted future resolves (drain — no hangs), the faulted
run's goodput stays ≥ 70% of fault-free, every *served* request's output
is bit-identical to the fault-free run, and a post-recovery replay of
the full request set matches bit-for-bit between the two sessions
(locations are CSR row ids, stable under churn below the compaction
threshold — same argument as ``bench_async``).

``python -m benchmarks.bench_faults [--smoke] [--json BENCH_faults.json]``
"""
from __future__ import annotations

import contextlib
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import SnapshotWriter, latest_snapshot
from repro.obs import get_registry
from repro.serving import (AsyncServeEngine, FaultPlan, InjectedFault,
                           fault_point, inject)

from .bench_async import (_apply_churn, _build_session, _churn_plan,
                          _request_stream)
from .common import parse_bench_args, write_json


def run_engine(session, arrivals, reqs, churn, *, plan: Optional[FaultPlan],
               latency_budget: float, max_batch: int, min_bucket: int,
               commit_every: int):
    """One open-loop continuous-batching run, optionally under a fault
    plan.  Every future is collected (success or typed failure) after
    the engine drains; returns per-request outputs (None where the
    request's batch was failed by an injected fault)."""
    eng = AsyncServeEngine(session, latency_budget=latency_budget,
                           max_batch=max_batch, min_bucket=min_bucket,
                           commit_every=commit_every, maintenance="thread")
    eng.warmup()
    n = len(reqs)
    futs: List = [None] * n
    ctx = inject(plan) if plan is not None else contextlib.nullcontext()
    with ctx:
        with eng:
            t0 = time.perf_counter()
            for i, (t, h) in enumerate(reqs):
                if i in churn:
                    _apply_churn(session.maint, churn[i])
                t_sched = t0 + arrivals[i]
                now = time.perf_counter()
                if now < t_sched:
                    time.sleep(t_sched - now)
                futs[i] = eng.submit(t, h)
        makespan = time.perf_counter() - t0
    outs: List = [None] * n
    failed = 0
    for i, f in enumerate(futs):
        assert f.done(), f"future {i} left unresolved after drain"
        try:
            r = f.result()
            outs[i] = (r.hit, r.locations, r.up, r.down)
        except Exception:
            failed += 1
    # recovery flush, outside the fault window: applies any quarantined
    # churn via the full-restage path
    session.maintain()
    return outs, failed, makespan, eng


def drive_sync_faults(s_fault, s_clean, snap_dir: str) -> Dict:
    """Deterministically exercise the commit and snapshot-write sites on
    the already-recovered faulted session (mirroring the probe mutations
    into the fault-free session so the replay equivalence stays exact).
    Returns the per-site evidence for the report row."""
    writer = SnapshotWriter(snap_dir, every=1, fault_hook=fault_point)
    s_fault.configure_snapshots(writer)
    plan = FaultPlan({"commit": [0], "snapshot-write": [0]})
    with inject(plan):
        s_fault.maint.queue_insert(0, "fault probe A", [1])
        s_fault.prepare_maintenance()
        commit_faulted = False
        try:
            s_fault.commit_maintenance()
        except InjectedFault:
            commit_faulted = True
        # recovery: the next prepare stages a full restage from the
        # (already mutated) bank; its commit applies — and the snapshot
        # it triggers crashes before the atomic rename
        s_fault.prepare_maintenance()
        committed = s_fault.commit_maintenance()
        snap_crashed = isinstance(writer.last_error, InjectedFault)
        intact_after_crash = latest_snapshot(snap_dir) is None
        # the next commit's snapshot write lands
        s_fault.maint.queue_insert(0, "fault probe B", [1])
        s_fault.maintain()
    for name in ("fault probe A", "fault probe B"):
        s_clean.maint.queue_insert(0, name, [1])
    s_clean.maintain()
    return dict(commit_faulted=commit_faulted, recovered_commit=committed,
                snap_crashed=snap_crashed,
                intact_after_crash=intact_after_crash,
                snapshots_saved=writer.saved,
                snapshot_landed=latest_snapshot(snap_dir) is not None,
                sync_faults=plan.hits())


def replay(session, reqs) -> List[Tuple]:
    """Synchronous post-recovery pass over the full request set."""
    outs = []
    for t, h in reqs:
        r = session.retrieve(t, h)
        outs.append((np.asarray(r.hit), np.asarray(r.locations),
                     np.asarray(r.up), np.asarray(r.down)))
    return outs


def _pairs_equal(a, b) -> bool:
    return all(np.array_equal(x, y) for x, y in zip(a, b))


def run(num_trees: int = 48, entities_per_tree: int = 32,
        hot_factor: int = 8, n_requests: int = 250, rate: float = 800.0,
        seed: int = 0, latency_budget: float = 2e-3, max_batch: int = 32,
        min_bucket: int = 16, commit_every: int = 4,
        churn_every: int = 50, churn_inserts: int = 6,
        churn_deletes: int = 3) -> List[Dict]:
    forest, bank, s_clean = _build_session(num_trees, entities_per_tree,
                                           hot_factor, seed)
    _, _, s_fault = _build_session(num_trees, entities_per_tree,
                                   hot_factor, seed, forest=forest)
    arrivals, reqs = _request_stream(forest, bank, n_requests, rate, seed)
    churn = _churn_plan(n_requests, churn_every, churn_inserts,
                        churn_deletes, seed)
    # max_batch bounds queries (not requests) per batch, so with ~2
    # queries per request a single faulted batch can strand at most
    # ~max_batch/2 requests — the goodput floor holds even if a CI stall
    # bursts the whole stream into few batches
    knobs = dict(latency_budget=latency_budget, max_batch=max_batch,
                 min_bucket=min_bucket, commit_every=commit_every)

    out_c, failed_c, span_c, _ = run_engine(
        s_clean, arrivals, reqs, churn, plan=None, **knobs)
    assert failed_c == 0, "fault-free run dropped requests"

    # in-engine faults whose ordinals are schedule-independent: the
    # second launched batch always exists (> max_batch total queries),
    # and churn guarantees at least one in-engine maintenance attempt
    plan = FaultPlan({"dispatch": [1], "prepare": [0]})
    out_f, failed_f, span_f, eng = run_engine(
        s_fault, arrivals, reqs, churn, plan=plan, **knobs)

    snap_dir = tempfile.mkdtemp(prefix="bench_faults_snap_")
    sync_ev = drive_sync_faults(s_fault, s_clean, snap_dir)

    served = n_requests - failed_f
    clean_goodput = n_requests / max(span_c, 1e-9)
    fault_goodput = served / max(span_f, 1e-9)
    # served outputs bit-identical to the fault-free run despite the
    # quarantine/recovery cycles in between
    equal_served = all(out_f[i] is None or _pairs_equal(out_c[i], out_f[i])
                       for i in range(n_requests))
    # post-recovery equivalence: both sessions answer the full request
    # set identically after the faulted one recovered
    equal_recovered = all(_pairs_equal(a, b) for a, b in
                          zip(replay(s_clean, reqs), replay(s_fault, reqs)))
    row = dict(layout="replicated", trees=num_trees, n_requests=n_requests,
               offered_rps=rate,
               served=served, failed=failed_f,
               clean_goodput_rps=clean_goodput,
               fault_goodput_rps=fault_goodput,
               # clamped at 1: both runs are pacing-dominated, so ratios
               # above 1 are scheduler noise — the gated quantity is only
               # "how much goodput do faults cost"
               goodput_ratio=min(1.0, fault_goodput
                                 / max(clean_goodput, 1e-9)),
               dispatch_faults=plan.hits("dispatch"),
               prepare_faults=plan.hits("prepare"),
               faults_injected=plan.hits() + sync_ev.pop("sync_faults"),
               breaker_state=s_fault.coord.breaker.state,
               equal_served=bool(equal_served),
               equal_recovered=bool(equal_recovered), **sync_ev)
    return [row]


def print_rows(rows: List[Dict]) -> None:
    print("goodput under injected faults: fault-free vs chaos run "
          "(prepare/commit/dispatch/snapshot-write)")
    print(f"{'served':>7s} {'failed':>7s} {'goodput%':>9s} {'faults':>7s} "
          f"{'snaps':>6s} {'eq_srv':>7s} {'eq_rec':>7s}")
    for r in rows:
        print(f"{r['served']:7d} {r['failed']:7d} "
              f"{100 * r['goodput_ratio']:8.1f}% {r['faults_injected']:7d} "
              f"{r['snapshots_saved']:6d} {str(r['equal_served']):>7s} "
              f"{str(r['equal_recovered']):>7s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_faults",
                                        flags=("--smoke",))
    kw = (dict(num_trees=32, entities_per_tree=24, n_requests=150,
               rate=600.0)
          if "--smoke" in flags else
          dict(num_trees=48, entities_per_tree=32, n_requests=300,
               rate=800.0))
    rows = run(**kw)
    # goodput is wall-clock; retry so a shared-CI scheduler stall cannot
    # fail the job on its own (the equivalence and fault-evidence flags
    # are deterministic — a retry just rebuilds the same banks)
    for _ in range(3):
        if all(r["goodput_ratio"] >= 0.7 and r["equal_served"]
               and r["equal_recovered"] for r in rows):
            break
        rows = run(**kw)
    print_rows(rows)
    for r in rows:
        assert r["equal_served"], \
            "a served request diverged from the fault-free run"
        assert r["equal_recovered"], \
            "post-recovery replay diverged between sessions"
        assert r["dispatch_faults"] == 1 and r["prepare_faults"] == 1, r
        assert r["commit_faulted"] and r["recovered_commit"], r
        assert r["snap_crashed"] and r["intact_after_crash"], r
        assert r["snapshot_landed"] and r["snapshots_saved"] >= 1, r
        assert r["failed"] >= 1, "the dispatch fault failed no requests"
        assert r["goodput_ratio"] >= 0.7, r
    write_json(json_path, {"rows": rows, "obs": get_registry().snapshot()})


if __name__ == "__main__":
    main()
