"""Filter-bank benchmarks — the paper's trees-vs-speedup sweep (§4.5).

Two claims, measured over T in {1, 8, 64, 256}:

* build: the vectorized bulk path (batched hashing + grouped empty-slot
  placement across all trees at once) vs. inserting every (tree, entity)
  item through the scalar cuckoo path;
* lookup: the vmapped-over-trees device lookup (one fused (T, B) batch)
  vs. looping the single-filter reference per tree — asserted exact-equal
  before timing, per the reproduction's acceptance bar.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import (build_bank, lookup_batch, lookup_batch_bank,
                        lookup_batch_trees)
from repro.core import hashing

from .common import best_time, synthetic_forest


def _best(fn, repeats: int) -> float:
    return best_time(fn, repeats, warmup=False)


def run(tree_counts: Sequence[int] = (1, 8, 64, 256),
        entities_per_tree: int = 48, batch_per_tree: int = 64,
        repeats: int = 3) -> List[Dict]:
    rows = []
    for T in tree_counts:
        forest = synthetic_forest(T, entities_per_tree)
        t_bulk = _best(lambda: build_bank(forest, bulk=True), repeats)
        t_seq = _best(lambda: build_bank(forest, bulk=False),
                      1 if T >= 64 else repeats)
        bank = build_bank(forest)

        names = [[f"entity {t}_{i % entities_per_tree}" if i % 8 else
                  f"missing {t}_{i}" for i in range(batch_per_tree)]
                 for t in range(T)]
        hb = jnp.stack([jnp.asarray(hashing.hash_entities(ns))
                        for ns in names])                       # (T, B)
        # uniform synthetic forest -> the dense (T, NB, S) view exists
        df, _, dh = bank.dense_tables()
        fps = jnp.asarray(df)
        heads = jnp.asarray(dh)

        # exactness: vmapped bank lookup vs per-tree reference
        got = lookup_batch_trees(fps, heads, hb)
        exact = True
        for t in range(T):
            ref = lookup_batch(fps[t], heads[t], hb[t])
            exact &= bool(jnp.array_equal(got.hit[t], ref.hit))
            exact &= bool(jnp.array_equal(got.head[t], ref.head))

        vmap_j = jnp.asarray(hb)
        lookup_batch_trees(fps, heads, vmap_j).hit.block_until_ready()
        t_vmap = _best(lambda: lookup_batch_trees(
            fps, heads, vmap_j).hit.block_until_ready(), repeats)

        def loop():
            for t in range(T):
                lookup_batch(fps[t], heads[t],
                             vmap_j[t]).hit.block_until_ready()
        loop()
        t_loop = _best(loop, repeats)

        # routed flat batch (the serving shape: (tree_id, hash) pairs)
        tid = jnp.repeat(jnp.arange(T, dtype=jnp.int32), batch_per_tree)
        flat = vmap_j.reshape(-1)
        lookup_batch_bank(fps, heads, tid, flat).hit.block_until_ready()
        t_routed = _best(lambda: lookup_batch_bank(
            fps, heads, tid, flat).hit.block_until_ready(), repeats)

        rows.append(dict(
            trees=T, items=bank.num_rows, num_buckets=bank.num_buckets,
            build_bulk_s=t_bulk, build_seq_s=t_seq,
            build_speedup=t_seq / t_bulk,
            lookup_vmap_s=t_vmap, lookup_loop_s=t_loop,
            lookup_speedup=t_loop / t_vmap if t_vmap else 0.0,
            lookup_routed_s=t_routed,
            vmap_exact=exact,
            evicted=bank.build_stats["evicted"],
        ))
    return rows


def main() -> None:
    rows = run()
    print("bank build + lookup vs #trees "
          "(paper: gap widens with many trees)")
    print(f"{'trees':>6s} {'items':>7s} {'bulk_s':>10s} {'seq_s':>10s} "
          f"{'build_x':>8s} {'vmap_s':>10s} {'loop_s':>10s} {'look_x':>7s} "
          f"{'exact':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['items']:7d} {r['build_bulk_s']:10.5f} "
              f"{r['build_seq_s']:10.5f} {r['build_speedup']:8.1f} "
              f"{r['lookup_vmap_s']:10.5f} {r['lookup_loop_s']:10.5f} "
              f"{r['lookup_speedup']:7.1f} {str(r['vmap_exact']):>6s}")
        assert r["vmap_exact"], "vmapped lookup diverged from reference"


if __name__ == "__main__":
    main()
