"""Paper Table 2: retrieval time vs number of entities per query
(5 / 10 / 20) at 600 trees."""
from __future__ import annotations

from .common import ALGOS, accuracy_proxy, build_retrievers, time_retrieval


def run(entity_counts=(5, 10, 20), num_trees: int = 600,
        num_queries: int = 12):
    corpus, forest, rets = build_retrievers(num_trees=num_trees)
    naive = rets["naive"]
    rows = []
    for k in entity_counts:
        # queries with k entities each (resampled from the corpus vocab)
        import random
        rng = random.Random(k)
        queries = [rng.sample(forest.entity_names, k)
                   for _ in range(num_queries)]
        for algo in ALGOS:
            t = time_retrieval(rets[algo], queries)
            acc = accuracy_proxy(forest, rets[algo], queries, naive)
            rows.append({"entities": k, "algo": algo, "time_s": t,
                         "acc": acc})
        base = next(r["time_s"] for r in rows
                    if r["entities"] == k and r["algo"] == "naive")
        for r in rows:
            if r["entities"] == k:
                r["speedup_vs_naive"] = base / r["time_s"]
    return rows


def main():
    print("table2: retrieval time vs #entities per query, 600 trees "
          "(paper Table 2)")
    print(f"{'ents':>5s} {'algo':>6s} {'time_s':>12s} {'speedup':>9s} "
          f"{'acc':>6s}")
    for r in run():
        print(f"{r['entities']:5d} {r['algo']:>6s} {r['time_s']:12.6f} "
              f"{r['speedup_vs_naive']:9.1f} {r['acc']:6.3f}")


if __name__ == "__main__":
    main()
