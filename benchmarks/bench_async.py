"""Closed-loop tail latency under Poisson arrivals: sync-batch serving vs
the continuous-batching ``AsyncServeEngine``.

The paper's headline claim is retrieval *latency*, but an isolated kernel
time says nothing about what a request sees under load.  This bench
drives the same open-loop request stream — Poisson arrivals of small
``(tree_id, hash)`` query groups over live keys, with background churn
queueing inserts/deletes along the way — through two serving designs
over identically built banks:

* **sync** — the fixed-batch baseline: requests accumulate until a full
  batch of B has *arrived*, the batch serves as one padded step, and
  every maintenance window (``prepare`` + ``commit``) blocks serving
  between batches.  Early arrivals eat the batch fill time; everyone
  eats the maintenance pauses.
* **continuous** — ``AsyncServeEngine``: arrivals coalesce up to a small
  latency budget or a pow2 bucket, maintenance prepares strictly under
  in-flight batches and commits between them under the commit policy.

Reported per mode: p50/p99 request latency against the *scheduled*
arrival time (offered load, not submit jitter) and goodput; the
acceptance gate is ``p99_sync / p99_async >= 2`` — with every request's
retrieval output (hit/locations/up/down) **bit-identical** across the
two modes first.  Outputs depend only on bank membership (locations are
CSR row ids, stable under churn below the compaction threshold, and
temperature never enters them), so the equivalence gate is exact even
though batching schedules and maintenance timing differ.

``python -m benchmarks.bench_async [--smoke] [--json BENCH_async.json]``
— CI runs the smoke shape (8-device host mesh env like the other
benches; the serving session itself is the replicated layout) and
uploads ``BENCH_async.json``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import CFTDeviceState, MaintenanceEngine, build_bank
from repro.core import hashing
from repro.obs import get_registry
from repro.serving import AsyncServeEngine, RetrievalSession

from .bench_ragged import skewed_forest
from .common import parse_bench_args, write_json


def _build_session(num_trees: int, entities_per_tree: int, hot_factor: int,
                   seed: int, forest=None):
    import jax
    forest = forest or skewed_forest(num_trees, entities_per_tree,
                                     hot_factor)
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    session.attach_maintenance(MaintenanceEngine(bank, seed=seed), forest)
    jax.block_until_ready(session.state.fingerprints)
    return forest, bank, session


def _request_stream(forest, bank, n: int, rate: float, seed: int
                    ) -> Tuple[np.ndarray, List[Tuple[List[int], List[int]]]]:
    """Poisson arrival offsets + per-request query groups over live base
    keys only (churned keys are never queried, so both modes' outputs are
    comparable bit-for-bit regardless of when maintenance lands)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    hashes = hashing.hash_entities(forest.entity_names)
    reqs = []
    for _ in range(n):
        k = int(rng.integers(1, 4))
        rows = rng.integers(0, bank.num_rows, size=k)
        reqs.append(([int(bank.row_tree[r]) for r in rows],
                     [int(hashes[bank.row_entity[r]]) for r in rows]))
    return arrivals, reqs


def _churn_plan(n: int, every: int, inserts: int, deletes: int, seed: int):
    """(request index -> queued ops) shared by both modes; deletes only
    touch keys inserted by earlier churn points."""
    rng = np.random.default_rng(seed + 17)
    plan: Dict[int, List[Tuple[str, int, str]]] = {}
    serial = 0
    live: List[Tuple[int, str]] = []
    for at in range(every, n, every):
        ops: List[Tuple[str, int, str]] = []
        for _ in range(deletes):
            if not live:
                break
            t, name = live.pop(int(rng.integers(len(live))))
            ops.append(("delete", t, name))
        for _ in range(inserts):
            t = int(rng.integers(64)) % 8
            name = f"churn {serial}"
            serial += 1
            ops.append(("insert", t, name))
            live.append((t, name))
        plan[at] = ops
    return plan


def _apply_churn(maint, ops) -> None:
    for kind, t, name in ops:
        if kind == "insert":
            maint.queue_insert(t, name, [1])
        else:
            maint.queue_delete(t, name)


def _slice(out, lo: int, hi: int):
    return (np.asarray(out.hit)[lo:hi], np.asarray(out.locations)[lo:hi],
            np.asarray(out.up)[lo:hi], np.asarray(out.down)[lo:hi])


def run_sync(session, arrivals, reqs, churn, *, batch_requests: int,
             pad_to: int, maintain_every: int):
    """Fixed-batch baseline: serve when a full batch has arrived; every
    maintenance window blocks serving.  Returns (latencies, outputs,
    makespan)."""
    latencies = np.zeros(len(reqs))
    outputs: List = [None] * len(reqs)
    # warmup the single sync geometry off the clock
    hh, tid, _ = session.pad_queries([0], [0], pad_to=pad_to)
    np.asarray(session.retrieve_dispatch(hh, tid).hit)
    session.harvest()

    t0 = time.perf_counter()
    i, served_batches = 0, 0
    while i < len(reqs):
        j = min(i + batch_requests, len(reqs))
        for at, ops in churn.items():
            if i <= at < j:
                _apply_churn(session.maint, ops)
        # the batch launches only once its last request has *arrived*
        t_ready = t0 + arrivals[j - 1]
        now = time.perf_counter()
        if now < t_ready:
            time.sleep(t_ready - now)
        tids: List[int] = []
        hhs: List[int] = []
        spans = []
        for r in range(i, j):
            t, h = reqs[r]
            spans.append((len(hhs), len(hhs) + len(h)))
            tids.extend(t)
            hhs.extend(h)
        hh, tid, _ = session.pad_queries(tids, hhs, pad_to=pad_to)
        out = session.retrieve_dispatch(hh, tid)
        res = _slice(out, 0, len(hhs))
        session.harvest()
        done = time.perf_counter()
        for r, (lo, hi) in zip(range(i, j), spans):
            latencies[r] = done - (t0 + arrivals[r])
            outputs[r] = tuple(a[lo:hi] for a in res)
        served_batches += 1
        if served_batches % maintain_every == 0:
            session.maintain()               # blocking: prepare + commit
        i = j
    session.maintain()
    makespan = time.perf_counter() - t0
    return latencies, outputs, makespan


def run_continuous(session, arrivals, reqs, churn, *, latency_budget: float,
                   max_batch: int, min_bucket: int, commit_every: int):
    """AsyncServeEngine: open-loop submitter paced by the arrival
    schedule; completion stamped by a done-callback on the scheduler
    thread."""
    # "thread" maintenance: the prepare pass (host maintenance + payload
    # staging + splice warm-compile) runs on the worker thread — XLA
    # compiles release the GIL, so it genuinely overlaps serving.  Inline
    # mode would put those hundreds of ms on the scheduler thread and
    # stall every launch behind them.
    eng = AsyncServeEngine(session, latency_budget=latency_budget,
                           max_batch=max_batch, min_bucket=min_bucket,
                           commit_every=commit_every,
                           maintenance="thread")
    eng.warmup()
    n = len(reqs)
    done_t = np.zeros(n)
    futs = [None] * n

    def _stamp(idx):
        def cb(_):
            done_t[idx] = time.perf_counter()
        return cb

    with eng:
        t0 = time.perf_counter()
        for i, (t, h) in enumerate(reqs):
            if i in churn:
                _apply_churn(session.maint, churn[i])
            t_sched = t0 + arrivals[i]
            now = time.perf_counter()
            if now < t_sched:
                time.sleep(t_sched - now)
            f = eng.submit(t, h)
            f.add_done_callback(_stamp(i))
            futs[i] = f
        results = [f.result(timeout=60) for f in futs]
    makespan = time.perf_counter() - t0
    session.maintain()                       # flush any straggler delta
    latencies = done_t - (t0 + arrivals)
    outputs = [(r.hit, r.locations, r.up, r.down) for r in results]
    return latencies, outputs, makespan, eng.stats, eng.hot_recompiles


def _equal(a, b) -> bool:
    return all(np.array_equal(x, y) for ar, br in zip(a, b)
               for x, y in zip(ar, br))


def run(num_trees: int = 64, entities_per_tree: int = 48,
        hot_factor: int = 8, n_requests: int = 400, rate: float = 1200.0,
        seed: int = 0, batch_requests: int = 48, maintain_every: int = 4,
        latency_budget: float = 2e-3, max_batch: int = 256,
        min_bucket: int = 32, commit_every: int = 4,
        churn_every: int = 50, churn_inserts: int = 8,
        churn_deletes: int = 4) -> List[Dict]:
    forest, bank, s_sync = _build_session(num_trees, entities_per_tree,
                                          hot_factor, seed)
    _, _, s_async = _build_session(num_trees, entities_per_tree,
                                   hot_factor, seed, forest=forest)
    arrivals, reqs = _request_stream(forest, bank, n_requests, rate, seed)
    churn = _churn_plan(n_requests, churn_every, churn_inserts,
                        churn_deletes, seed)
    lat_s, out_s, span_s = run_sync(
        s_sync, arrivals, reqs, churn, batch_requests=batch_requests,
        pad_to=max_batch, maintain_every=maintain_every)
    lat_a, out_a, span_a, stats, hot = run_continuous(
        s_async, arrivals, reqs, churn, latency_budget=latency_budget,
        max_batch=max_batch, min_bucket=min_bucket,
        commit_every=commit_every)
    equal = _equal(out_s, out_a)
    p = lambda v, q: float(np.percentile(v, q) * 1e3)    # noqa: E731
    row = dict(layout="replicated", trees=num_trees,
               n_requests=n_requests, offered_rps=rate,
               sync_p50_ms=p(lat_s, 50), sync_p99_ms=p(lat_s, 99),
               async_p50_ms=p(lat_a, 50), async_p99_ms=p(lat_a, 99),
               p99_ratio=p(lat_s, 99) / max(p(lat_a, 99), 1e-6),
               sync_goodput_rps=n_requests / max(span_s, 1e-9),
               async_goodput_rps=n_requests / max(span_a, 1e-9),
               batches=stats.batches, prepares=stats.prepares,
               commits=stats.commits,
               hot_recompiles=int(hot),
               bucket_histogram={str(k): v for k, v
                                 in sorted(stats.bucket_histogram.items())},
               equal=bool(equal))
    return [row]


def measure_overhead(num_trees: int = 48, entities_per_tree: int = 32,
                     n_requests: int = 150, rate: float = 800.0,
                     seed: int = 3) -> float:
    """p50 latency with metrics enabled over p50 with them disabled, on
    identically built sessions and the same arrival schedule (no churn,
    so the runs differ only in observability).  The acceptance guard is
    ratio <= 1.05 — instrumented counters and spans must stay invisible
    next to the millisecond-scale coalescing budget."""
    reg = get_registry()
    forest, bank, _ = _build_session(num_trees, entities_per_tree, 8, seed)
    arrivals, reqs = _request_stream(forest, bank, n_requests, rate, seed)
    p50 = {}
    try:
        for mode in ("disabled", "enabled"):
            _, _, session = _build_session(num_trees, entities_per_tree,
                                           8, seed, forest=forest)
            reg.enabled = mode == "enabled"
            lat, _, _, _, _ = run_continuous(
                session, arrivals, reqs, {}, latency_budget=2e-3,
                max_batch=256, min_bucket=32, commit_every=4)
            p50[mode] = float(np.percentile(lat, 50))
    finally:
        reg.enable()
    return p50["enabled"] / max(p50["disabled"], 1e-9)


def print_rows(rows: List[Dict]) -> None:
    print("closed-loop tail latency under Poisson arrivals + churn: "
          "sync-batch vs continuous batching")
    print(f"{'layout':>10s} {'offered':>8s} {'sync_p99':>9s} "
          f"{'async_p99':>10s} {'p99_x':>6s} {'goodput':>8s} {'equal':>6s}")
    for r in rows:
        print(f"{r['layout']:>10s} {r['offered_rps']:7.0f}r "
              f"{r['sync_p99_ms']:8.2f}m {r['async_p99_ms']:9.2f}m "
              f"{r['p99_ratio']:6.1f} {r['async_goodput_rps']:7.0f}r "
              f"{str(r['equal']):>6s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_async",
                                        flags=("--smoke",))
    kw = (dict(num_trees=48, entities_per_tree=32, n_requests=250,
               rate=800.0)
          if "--smoke" in flags else
          dict(num_trees=64, entities_per_tree=48, n_requests=500,
               rate=1000.0))
    rows = run(**kw)
    # wall-clock gate: retry so a scheduler stall on shared CI hardware
    # can never fail the job on its own
    for _ in range(3):
        if all(r["equal"] and r["p99_ratio"] >= 2.0 for r in rows):
            break
        rows = run(**kw)
    print_rows(rows)
    for r in rows:
        assert r["equal"], \
            "continuous-batching outputs diverged from the sync path"
        assert r["p99_ratio"] >= 2.0, r
        # the recompile sentinel across the full churn schedule: the
        # padded path must never compile after warmup
        assert r["hot_recompiles"] == 0, r
    # observability overhead guard: enabled-metrics p50 within 5% of
    # disabled (same retry discipline as the wall-clock gates)
    for _ in range(3):
        overhead = measure_overhead()
        if overhead <= 1.05:
            break
    print(f"metrics overhead: enabled/disabled p50 = {overhead:.3f}x")
    assert overhead <= 1.05, f"metrics overhead {overhead:.3f}x > 1.05x"
    snap = get_registry().snapshot()
    write_json(json_path, {"rows": rows, "obs": snap,
                           "metrics_overhead": overhead})
    # standalone artifact for the CI smoke job (uploaded next to the
    # BENCH trajectories; also the thing to read first on a gate trip)
    write_json("metrics_snapshot.json", snap)


if __name__ == "__main__":
    main()
