"""Filter micro-benchmarks: load factor / error rate (paper §4.5.1 claims)
and batched device lookup vs sequential host lookup (TPU adaptation win)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import CuckooFilter, build_forest, build_index, lookup_batch
from repro.core import hashing
from repro.data import hospital_corpus
from repro.kernels.cuckoo_lookup import cuckoo_lookup


def error_rate(num_entities: int = 3148, num_buckets: int = 1024,
               probes: int = 100_000):
    f = CuckooFilter(num_buckets=num_buckets)
    hs = hashing.hash_entities([f"entity {i}" for i in range(num_entities)])
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    miss = hashing.hash_entities([f"absent {i}" for i in range(probes)])
    fp = sum(f.contains(int(h)) for h in miss)
    return {"load_factor": f.load_factor, "buckets": f.num_buckets,
            "false_positive_rate": fp / probes,
            "expansions": f.num_expansions}


def batched_vs_sequential(num_trees: int = 300, batch: int = 512,
                          repeats: int = 5):
    corpus = hospital_corpus(num_trees=num_trees)
    forest = build_forest(corpus.trees)
    idx = build_index(forest, num_buckets=1024)
    t = idx.filter.tables()
    fps, heads = jnp.asarray(t.fingerprints), jnp.asarray(t.heads)
    names = [forest.entity_names[i % forest.num_entities]
             for i in range(batch)]
    hs = hashing.hash_entities(names)
    hj = jnp.asarray(hs)

    t0 = time.perf_counter()
    for _ in range(repeats):
        for h in hs:
            idx.filter.lookup(int(h), bump=False)
    t_seq = (time.perf_counter() - t0) / repeats

    lookup_batch(fps, heads, hj).hit.block_until_ready()   # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        lookup_batch(fps, heads, hj).hit.block_until_ready()
    t_vec = (time.perf_counter() - t0) / repeats

    out = cuckoo_lookup(fps, heads, hj, interpret=True)
    out.hit.block_until_ready()
    t0 = time.perf_counter()
    cuckoo_lookup(fps, heads, hj, interpret=True).hit.block_until_ready()
    t_kernel_interp = time.perf_counter() - t0

    return {"batch": batch, "sequential_s": t_seq, "vectorized_s": t_vec,
            "speedup": t_seq / t_vec,
            "pallas_interpret_s": t_kernel_interp}


def main():
    er = error_rate()
    print("filter: load factor / error rate (paper: 0.7686 load, ~0 errors)")
    for k, v in er.items():
        print(f"  {k}: {v}")
    bv = batched_vs_sequential()
    print("\nbatched lookup vs sequential host loop (TPU adaptation):")
    for k, v in bv.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
