"""Noisy-neighbor isolation under chaos: the multi-tenant benchmark
behind the fault-isolation claim.

Two identically built continuous-batching runs serve the same Poisson
request stream over K tenants (tenant -> tree-range registry attached).
The second run is the chaos run, and everything bad in it happens to ONE
victim tenant:

* **maintenance faults** — background churn touches only the victim's
  trees, and a deterministic :class:`FaultPlan` fails the first prepare
  pass: the blame lands on the victim's breaker (``maint.failures``
  labeled with its name), the global breaker stays closed, and every
  other tenant's maintenance keeps flowing;
* **overload** — mid-stream the victim bursts far past its queue share:
  its own excess sheds with ``EngineOverloaded(tenant=victim)`` while
  healthy tenants keep admitting through the same engine;
* **lifecycle chaos** — after the stream the victim is evicted to host
  (with an injected ``evict`` fault first, proving the site fires before
  the surgery), its submits shed with ``TenantEvicted``, a commit fault
  quarantines and recovers, and the reload splices it back bit-exactly.

Gates: healthy tenants' goodput stays >= 90% of the fault-free run and
every healthy answer is bit-identical to it; the victim — the tenant
taking faults, an overload burst and an eviction — still keeps >= 50%
goodput on its base stream; post-recovery both sessions replay the full
request set identically.

``python -m benchmarks.bench_tenant [--smoke] [--json BENCH_tenant.json]``
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (CFTDeviceState, MaintenanceEngine, TenantRegistry,
                        build_bank, build_forest)
from repro.core import hashing
from repro.obs import get_registry
from repro.serving import (AsyncServeEngine, EngineOverloaded, FaultPlan,
                           InjectedFault, RetrievalSession, TenantEvicted,
                           inject)

from .common import parse_bench_args, write_json


def _tenant_forest(num_tenants: int, trees_per_tenant: int,
                   entities_per_tree: int):
    t_total = num_tenants * trees_per_tenant
    forest = build_forest(
        [[(f"root {t}", f"entity {t}_{i}")
          for i in range(entities_per_tree)] for t in range(t_total)])
    ranges = {f"tenant{k}": (k * trees_per_tenant,
                             (k + 1) * trees_per_tenant)
              for k in range(num_tenants)}
    return forest, ranges


def _build_session(forest, ranges, seed: int):
    import jax
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    session.attach_maintenance(MaintenanceEngine(bank, seed=seed), forest,
                               registry=TenantRegistry(ranges))
    jax.block_until_ready(session.state.fingerprints)
    return bank, session


def _request_stream(forest, bank, ranges, n: int, rate: float, seed: int):
    """Poisson arrivals; every request's queries stay inside ONE tenant's
    tree range (the admission path requires single-tenant batches) and
    only touch live base keys, so outputs compare bit-for-bit no matter
    when maintenance lands (same argument as ``bench_async``)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    hashes = hashing.hash_entities(forest.entity_names)
    names = sorted(ranges)
    rows_of = {name: np.flatnonzero(
        (bank.row_tree >= lo) & (bank.row_tree < hi))
        for name, (lo, hi) in ranges.items()}
    reqs, owners = [], []
    for i in range(n):
        name = names[int(rng.integers(len(names)))]
        k = int(rng.integers(1, 4))
        rows = rows_of[name][rng.integers(0, len(rows_of[name]), size=k)]
        reqs.append(([int(bank.row_tree[r]) for r in rows],
                     [int(hashes[bank.row_entity[r]]) for r in rows]))
        owners.append(name)
    return arrivals, reqs, owners


def _victim_churn_plan(n: int, every: int, inserts: int, victim_lo: int,
                       victim_hi: int, seed: int):
    """Background churn confined to the victim's trees — every
    maintenance cycle in the chaos run involves the victim, so fault
    blame is attributable to it and to it alone."""
    rng = np.random.default_rng(seed + 17)
    plan: Dict[int, List[Tuple[int, str]]] = {}
    serial = 0
    for at in range(every, n, every):
        ops = []
        for _ in range(inserts):
            t = victim_lo + int(rng.integers(victim_hi - victim_lo))
            ops.append((t, f"victim churn {serial}"))
            serial += 1
        plan[at] = ops
    return plan


def run_engine(session, arrivals, reqs, owners, churn, *, victim: str,
               plan: Optional[FaultPlan], burst_at: Optional[int],
               burst_size: int, tenant_quota: int, latency_budget: float,
               max_batch: int, min_bucket: int, commit_every: int):
    """One open-loop run.  The chaos run additionally fires a victim
    overload burst at ``burst_at`` (its excess must shed with the victim
    attributed) and runs under ``plan``.  Returns per-request outputs
    (None where the request was shed), per-class shed counts, and the
    makespan."""
    eng = AsyncServeEngine(session, latency_budget=latency_budget,
                           max_batch=max_batch, min_bucket=min_bucket,
                           commit_every=commit_every, maintenance="thread",
                           tenant_quota=tenant_quota)
    eng.warmup()
    n = len(reqs)
    futs: List = [None] * n
    shed = {"victim": 0, "healthy": 0, "burst": 0}
    burst_req = next((reqs[i] for i in range(n) if owners[i] == victim),
                     None)
    ctx = inject(plan) if plan is not None else contextlib.nullcontext()
    with ctx:
        with eng:
            t0 = time.perf_counter()
            for i, (t, h) in enumerate(reqs):
                if i in churn:
                    for tree, name in churn[i]:
                        session.maint.queue_insert(tree, name, [1])
                if i == burst_at and burst_req is not None:
                    for _ in range(burst_size):
                        try:
                            eng.submit(*burst_req)
                        except EngineOverloaded as e:
                            assert e.tenant == victim, e.tenant
                            shed["burst"] += 1
                t_sched = t0 + arrivals[i]
                now = time.perf_counter()
                if now < t_sched:
                    time.sleep(t_sched - now)
                try:
                    futs[i] = eng.submit(t, h)
                except EngineOverloaded as e:
                    key = "victim" if e.tenant == victim else "healthy"
                    shed[key] += 1
        makespan = time.perf_counter() - t0
    outs: List = [None] * n
    for i, f in enumerate(futs):
        if f is None:
            continue
        assert f.done(), f"future {i} left unresolved after drain"
        r = f.result()           # no dispatch faults here: all must serve
        outs[i] = (r.hit, r.locations, r.up, r.down)
    session.maintain()           # recovery flush for any held victim ops
    return outs, shed, makespan


def drive_lifecycle_chaos(s_fault, s_clean, victim: str, probe_tree: int
                          ) -> Dict:
    """Post-stream, deterministically: a commit fault blamed on the
    victim, an injected ``evict`` fault (site fires before the surgery),
    a real evict whose submits shed with ``TenantEvicted`` while a
    healthy tenant keeps serving, and the bit-exact reload.  Probe
    mutations mirror into the fault-free session so replay equivalence
    stays exact."""
    ev: Dict = {}
    plan = FaultPlan({"commit": [0], "evict": [0]})
    with inject(plan):
        s_fault.maint.queue_insert(probe_tree, "victim probe", [1])
        s_fault.prepare_maintenance(now=0.0)
        try:
            s_fault.commit_maintenance(now=0.0)
            ev["commit_faulted"] = False
        except InjectedFault:
            ev["commit_faulted"] = True
        ev["victim_blamed"] = victim in s_fault.coord.tenant_breakers
        s_fault.prepare_maintenance(now=1.0)        # recovery cycle
        ev["recovered_commit"] = s_fault.commit_maintenance(now=1.0)
        try:
            s_fault.evict_tenant(victim)
            ev["evict_fault_blocked"] = False
        except InjectedFault:
            # the site fired before the surgery: still fully resident
            ev["evict_fault_blocked"] = \
                s_fault.tenants.resident(victim)
    cold = s_fault.evict_tenant(victim)
    eng = AsyncServeEngine(s_fault, maintenance="off", min_bucket=4,
                           max_batch=32)
    lo, _ = s_fault.tenants.trees(victim)
    healthy = next(n for n in s_fault.tenants.names if n != victim)
    hlo, _ = s_fault.tenants.trees(healthy)
    try:
        eng.submit([lo], [0])
        ev["evicted_sheds"] = False
    except TenantEvicted:
        ev["evicted_sheds"] = True
    f = eng.submit([hlo], [0])       # healthy serves through the window
    eng.flush()
    ev["healthy_serves_while_cold"] = f.result(timeout=30) is not None
    eng.stop()
    s_fault.reload_tenant(victim, cold)
    for name in ("victim probe",):
        s_clean.maint.queue_insert(probe_tree, name, [1])
    s_clean.maintain()
    ev["lifecycle_faults"] = plan.hits()
    return ev


def replay(session, reqs) -> List[Tuple]:
    outs = []
    for t, h in reqs:
        r = session.retrieve(t, h)
        outs.append((np.asarray(r.hit), np.asarray(r.locations),
                     np.asarray(r.up), np.asarray(r.down)))
    return outs


def _pairs_equal(a, b) -> bool:
    return all(np.array_equal(x, y) for x, y in zip(a, b))


def _class_ratio(outs_f, outs_c, owners, names, span_f, span_c) -> float:
    served_f = sum(1 for i, o in enumerate(outs_f)
                   if o is not None and owners[i] in names)
    served_c = sum(1 for i, o in enumerate(outs_c)
                   if o is not None and owners[i] in names)
    gp_f = served_f / max(span_f, 1e-9)
    gp_c = served_c / max(span_c, 1e-9)
    # clamped at 1: both runs are pacing-dominated (see bench_faults)
    return min(1.0, gp_f / max(gp_c, 1e-9))


def run(num_tenants: int = 4, trees_per_tenant: int = 2,
        entities_per_tree: int = 24, n_requests: int = 240,
        rate: float = 800.0, seed: int = 0, tenant_quota: int = 4,
        burst_size: int = 24, latency_budget: float = 2e-3,
        max_batch: int = 32, min_bucket: int = 16, commit_every: int = 4,
        churn_every: int = 40, churn_inserts: int = 5) -> List[Dict]:
    forest, ranges = _tenant_forest(num_tenants, trees_per_tenant,
                                    entities_per_tree)
    victim = sorted(ranges)[0]
    vlo, vhi = ranges[victim]
    bank_c, s_clean = _build_session(forest, ranges, seed)
    _, s_fault = _build_session(forest, ranges, seed)
    arrivals, reqs, owners = _request_stream(forest, bank_c, ranges,
                                             n_requests, rate, seed)
    churn = _victim_churn_plan(n_requests, churn_every, churn_inserts,
                               vlo, vhi, seed)
    knobs = dict(victim=victim, tenant_quota=tenant_quota,
                 latency_budget=latency_budget, max_batch=max_batch,
                 min_bucket=min_bucket, commit_every=commit_every)

    out_c, shed_c, span_c = run_engine(
        s_clean, arrivals, reqs, owners, churn, plan=None, burst_at=None,
        burst_size=0, **knobs)
    assert shed_c["victim"] == shed_c["healthy"] == 0, \
        "fault-free run shed base traffic"

    # chaos run: churn is victim-only, so the first in-engine prepare
    # fault is attributable to the victim; the burst overloads only its
    # queue share
    plan = FaultPlan({"prepare": [0]})
    out_f, shed_f, span_f = run_engine(
        s_fault, arrivals, reqs, owners, churn, plan=plan,
        burst_at=n_requests // 2, burst_size=burst_size, **knobs)

    life = drive_lifecycle_chaos(s_fault, s_clean, victim, probe_tree=vlo)

    healthy_names = [n for n in sorted(ranges) if n != victim]
    healthy_ratio = _class_ratio(out_f, out_c, owners, healthy_names,
                                 span_f, span_c)
    victim_ratio = _class_ratio(out_f, out_c, owners, [victim],
                                span_f, span_c)
    # healthy answers bit-identical to the fault-free run, request by
    # request, straight through the victim's faults and burst
    equal_healthy = all(
        _pairs_equal(out_c[i], out_f[i])
        for i in range(n_requests) if owners[i] != victim)
    equal_victim_served = all(
        out_f[i] is None or _pairs_equal(out_c[i], out_f[i])
        for i in range(n_requests) if owners[i] == victim)
    equal_recovered = all(_pairs_equal(a, b) for a, b in
                          zip(replay(s_clean, reqs), replay(s_fault, reqs)))
    coord = s_fault.coord
    reg = get_registry()
    row = dict(layout="replicated", tenants=num_tenants,
               trees=num_tenants * trees_per_tenant,
               n_requests=n_requests, offered_rps=rate, victim=victim,
               healthy_goodput_ratio=healthy_ratio,
               victim_goodput_ratio=victim_ratio,
               burst_shed=shed_f["burst"],
               victim_base_shed=shed_f["victim"],
               healthy_base_shed=shed_f["healthy"],
               prepare_faults=plan.hits("prepare"),
               faults_injected=plan.hits() + life.pop("lifecycle_faults"),
               victim_fault_attributed=bool(
                   reg.counter("maint.failures").value(
                       phase="prepare", tenant=victim)
                   + reg.counter("maint.failures").value(
                       phase="commit", tenant=victim)),
               global_breaker=coord.breaker.state,
               tenant_breakers=sorted(coord.tenant_breakers),
               evictions=int(reg.counter("tenant.evictions").value(
                   tenant=victim)),
               reloads=int(reg.counter("tenant.reloads").value(
                   tenant=victim)),
               equal_healthy=bool(equal_healthy),
               equal_victim_served=bool(equal_victim_served),
               equal_recovered=bool(equal_recovered), **life)
    return [row]


def print_rows(rows: List[Dict]) -> None:
    print("noisy-neighbor isolation: victim takes faults + overload + "
          "eviction; healthy tenants must not notice")
    print(f"{'healthy%':>9s} {'victim%':>8s} {'burst_shed':>11s} "
          f"{'faults':>7s} {'eq_heal':>8s} {'eq_rec':>7s} {'breaker':>8s}")
    for r in rows:
        print(f"{100 * r['healthy_goodput_ratio']:8.1f}% "
              f"{100 * r['victim_goodput_ratio']:7.1f}% "
              f"{r['burst_shed']:11d} {r['faults_injected']:7d} "
              f"{str(r['equal_healthy']):>8s} "
              f"{str(r['equal_recovered']):>7s} {r['global_breaker']:>8s}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_tenant",
                                        flags=("--smoke",))
    kw = (dict(entities_per_tree=16, n_requests=160, rate=600.0)
          if "--smoke" in flags else
          dict(entities_per_tree=24, n_requests=300, rate=800.0))
    rows = run(**kw)
    # goodput ratios are wall-clock; retry so a shared-CI scheduler stall
    # cannot fail the job on its own (the equivalence and attribution
    # flags are deterministic — a retry rebuilds the same banks)
    for _ in range(3):
        if all(r["healthy_goodput_ratio"] >= 0.9
               and r["victim_goodput_ratio"] >= 0.5 for r in rows):
            break
        rows = run(**kw)
    print_rows(rows)
    for r in rows:
        assert r["equal_healthy"], \
            "a healthy tenant's answer diverged under the victim's chaos"
        assert r["equal_victim_served"], \
            "a served victim request diverged from the fault-free run"
        assert r["equal_recovered"], \
            "post-recovery replay diverged between sessions"
        assert r["prepare_faults"] == 1 and r["victim_fault_attributed"], r
        assert r["tenant_breakers"] == [r["victim"]], \
            "fault blame leaked beyond the victim tenant"
        assert r["global_breaker"] == "closed", \
            "a victim-scoped fault tripped the global breaker"
        assert r["burst_shed"] >= 1, "the overload burst was never shed"
        assert r["healthy_base_shed"] == 0, \
            "the victim's burst shed a healthy tenant's traffic"
        assert r["commit_faulted"] and r["recovered_commit"], r
        assert r["victim_blamed"] and r["evict_fault_blocked"], r
        assert r["evicted_sheds"] and r["healthy_serves_while_cold"], r
        assert r["evictions"] >= 1 and r["reloads"] >= 1, r
        assert r["healthy_goodput_ratio"] >= 0.9, r
        assert r["victim_goodput_ratio"] >= 0.5, r
    write_json(json_path, {"rows": rows, "obs": get_registry().snapshot()})


if __name__ == "__main__":
    main()
