"""Perf-regression gate over the committed benchmark baselines.

Every smoke benchmark writes a ``BENCH_*.json`` trajectory; this checker
compares each one against the committed copy in ``benchmarks/baselines/``
and fails (exit 1) when any **gated metric** worsens by more than the
threshold (default 25%), printing a diff table of everything it compared.

Gated metrics are dimensionless ratios measured within one process on
one machine (incremental-vs-rebuild speedup, sharded byte fraction,
pause reduction, tail-latency ratio …), so they transfer across hosts in
a way raw milliseconds never could — a laptop baseline still gates a CI
runner.  Raw timings in the same files are reported but not gated.

Direction matters: ``speedup`` regressing means it *dropped*,
``bytes_fraction`` regressing means it *rose*.  Rows are matched by
position within each row list and sanity-checked on their identity keys
(``trees``/``layout``/``devices``/``batch``); a bench whose shape
changed should simply refresh its baseline (see CONTRIBUTING.md):

    PYTHONPATH=src python -m benchmarks.bench_<name> --smoke \
        --json benchmarks/baselines/BENCH_<name>.json

Usage (CI runs this from the repo root after the smoke benches):

    python -m benchmarks.check_regression [--current DIR] \
        [--baselines DIR] [--threshold 0.25]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Tuple

# metric -> direction a *regression* moves ("down": worse when it drops)
GATED_METRICS: Dict[str, str] = {
    "speedup": "down",            # bench_churn, bench_distributed
    "expand_speedup": "down",     # bench_ragged
    "pause_reduction": "down",    # bench_pause
    "p99_ratio": "down",          # bench_async
    "goodput_ratio": "down",      # bench_faults (faulted / fault-free)
    "healthy_goodput_ratio": "down",   # bench_tenant (healthy / clean)
    "victim_goodput_ratio": "down",    # bench_tenant (victim / clean)
    "bytes_fraction": "up",       # bench_ragged / bench_distributed
    "fused_speedup": "down",      # bench_kernels (fused vs unfused)
}

# keys that identify a row's scenario — a mismatch means the bench's
# shape changed and the baseline must be refreshed, not diffed
IDENTITY_KEYS = ("layout", "trees", "devices", "batch", "hot_factor",
                 "n_requests", "hit_rate")


def _row_lists(payload: Dict) -> List[Tuple[str, List[Dict]]]:
    """Every top-level list-of-dicts in a BENCH payload (the benches use
    different key names: "rows", "churn", "bank", ...)."""
    return [(k, v) for k, v in payload.items()
            if isinstance(v, list) and v
            and all(isinstance(r, dict) for r in v)]


def _ident(row: Dict) -> Tuple:
    return tuple((k, row[k]) for k in IDENTITY_KEYS if k in row)


def compare(name: str, current: Dict, baseline: Dict,
            threshold: float = 0.25) -> Tuple[List[Dict], List[str]]:
    """Diff one BENCH payload against its baseline.

    Returns ``(entries, notes)``: one entry per gated metric per matched
    row — ``entry["regressed"]`` marks a worsening beyond ``threshold``
    — plus human-readable notes for anything skipped."""
    entries: List[Dict] = []
    notes: List[str] = []
    base_lists = dict(_row_lists(baseline))
    for key, cur_rows in _row_lists(current):
        base_rows = base_lists.get(key)
        if base_rows is None:
            notes.append(f"{name}:{key}: no baseline rows — skipped")
            continue
        if len(base_rows) != len(cur_rows):
            notes.append(f"{name}:{key}: row count changed "
                         f"({len(base_rows)} -> {len(cur_rows)}) — "
                         "comparing the common prefix")
        for i, (cur, base) in enumerate(zip(cur_rows, base_rows)):
            if _ident(cur) != _ident(base):
                notes.append(f"{name}:{key}[{i}]: scenario changed "
                             f"({_ident(base)} -> {_ident(cur)}) — "
                             "refresh the baseline")
                continue
            for metric, direction in GATED_METRICS.items():
                if metric not in cur or metric not in base:
                    continue
                b, c = float(base[metric]), float(cur[metric])
                if b <= 0:
                    continue
                if direction == "down" and b < 1.0:
                    # a higher-is-better ratio below 1 means the bench
                    # scenario sits below its crossover point on the
                    # recording host (e.g. a host-mesh shard speedup on
                    # an oversubscribed CPU) — relative noise dominates
                    notes.append(f"{name}:{key}[{i}]:{metric}: baseline "
                                 f"{b:.3f} < 1 (below crossover on the "
                                 "recording host) — not gated")
                    continue
                change = (c - b) / b
                worsened = -change if direction == "down" else change
                entries.append(dict(
                    file=name, rows=f"{key}[{i}]", metric=metric,
                    baseline=b, current=c, change=change,
                    regressed=worsened > threshold))
    return entries, notes


def print_table(entries: List[Dict]) -> None:
    print(f"{'file':>18s} {'row':>10s} {'metric':>16s} "
          f"{'baseline':>9s} {'current':>9s} {'change':>8s}")
    for e in entries:
        flag = "  << REGRESSED" if e["regressed"] else ""
        print(f"{e['file']:>18s} {e['rows']:>10s} {e['metric']:>16s} "
              f"{e['baseline']:9.3f} {e['current']:9.3f} "
              f"{e['change']:+7.1%}{flag}")


def print_snapshot_diff(name: str, current: Dict, baseline: Dict) -> None:
    """The causal trail behind a gate trip: diff the embedded
    observability snapshots (``payload["obs"]`` — counters and gauges)
    of the regressed file against its baseline.  A p99 regression with
    ``serve.hot_recompiles`` up, or a pause regression with
    ``maint.plans{kind=full}`` up, answers "why" without a rerun."""
    cur, base = current.get("obs"), baseline.get("obs")
    if not cur:
        print(f"{name}: no embedded obs snapshot in the current run")
        return
    base = base or {}
    print(f"\n{name}: embedded metrics snapshot "
          f"(current vs baseline{'' if base else ' — none recorded'})")
    print(f"{'metric':>44s} {'baseline':>12s} {'current':>12s}")
    for section in ("counters", "gauges"):
        c = cur.get(section, {})
        b = base.get(section, {})
        for key in sorted(set(c) | set(b)):
            bv, cv = b.get(key, "-"), c.get(key, "-")
            mark = "" if bv == cv else "  <<"
            fmt = lambda v: f"{v:12.4g}" if isinstance(v, (int, float)) \
                else f"{v:>12s}"                          # noqa: E731
            print(f"{key:>44s} {fmt(bv)} {fmt(cv)}{mark}")


def check_dirs(current_dir: str, baseline_dir: str,
               threshold: float = 0.25) -> int:
    """Compare every BENCH_*.json present in both dirs; returns the
    number of regressed metrics (0 = pass)."""
    entries: List[Dict] = []
    notes: List[str] = []
    names = sorted(n for n in os.listdir(baseline_dir)
                   if n.startswith("BENCH_") and n.endswith(".json"))
    if not names:
        print(f"no BENCH_*.json baselines under {baseline_dir}",
              file=sys.stderr)
        return 1
    compared = 0
    payloads: Dict[str, Tuple[Dict, Dict]] = {}
    for name in names:
        cur_path = os.path.join(current_dir, name)
        if not os.path.exists(cur_path):
            notes.append(f"{name}: not produced by this run — skipped")
            continue
        with open(cur_path) as f:
            current = json.load(f)
        with open(os.path.join(baseline_dir, name)) as f:
            baseline = json.load(f)
        payloads[name] = (current, baseline)
        e, n = compare(name, current, baseline, threshold)
        entries.extend(e)
        notes.extend(n)
        compared += 1
    print(f"perf-regression gate: {compared} benchmark file(s), "
          f"{len(entries)} gated metric(s), threshold "
          f"{threshold:.0%} (ratios only — raw timings are not gated)")
    if entries:
        print_table(entries)
    for n in notes:
        print(f"note: {n}")
    bad = sum(e["regressed"] for e in entries)
    if bad:
        # surface the causal trail of every regressed file before failing
        for name in sorted({e["file"] for e in entries if e["regressed"]}):
            cur, base = payloads[name]
            print_snapshot_diff(name, cur, base)
        print(f"\nFAIL: {bad} metric(s) regressed more than "
              f"{threshold:.0%} vs benchmarks/baselines/ — if the change "
              "is intended, refresh the baseline JSON (CONTRIBUTING.md)")
    elif compared:
        print("\nOK: no gated metric regressed beyond the threshold")
    return bad


def main(argv: Optional[List[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    here = os.path.dirname(os.path.abspath(__file__))
    current_dir, threshold = os.getcwd(), 0.25
    baseline_dir = os.path.join(here, "baselines")

    def opt(flag, default):
        if flag in args:
            i = args.index(flag)
            val = args[i + 1]
            del args[i:i + 2]
            return val
        return default

    current_dir = opt("--current", current_dir)
    baseline_dir = opt("--baselines", baseline_dir)
    threshold = float(opt("--threshold", threshold))
    if args:
        print(__doc__)
        return 2
    return 1 if check_dirs(current_dir, baseline_dir, threshold) else 0


if __name__ == "__main__":
    sys.exit(main())
