"""Replicated vs bank-axis-sharded lookup — the scaling claim, measured.

The replicated path keeps the whole ``(T, NB, S)`` bank on one device and
probes it with ``lookup_batch_bank``; the sharded path partitions tree
ranges over the mesh (``FilterBank.shard`` + ``stage_sharded_bank``) and
routes each query batch through the ``shard_map`` all-to-all
(``sharded_lookup_bank``).  For every T the sweep records wall-clock for
both, the per-device filter-table bytes for both (the capacity axis the
sharding actually buys), and gates on bit-identical results before any
timing is reported.

Run on a forced multi-device host platform::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_distributed \\
        [--smoke] [--json BENCH_shard.json]

The CI smoke job writes ``BENCH_shard.json`` from here (next to
``BENCH_bank.json`` from ``bench_churn``) so the distributed-lookup perf
trajectory is recorded per commit.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import build_bank, build_forest, lookup_batch_bank
from repro.core import hashing
from repro.core.distributed import stage_sharded_bank, sharded_lookup_bank


def _forest(num_trees: int, entities_per_tree: int):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _queries(forest, bank, batch: int, seed: int):
    """Mixed hit/miss batch spread over every tree."""
    rng = np.random.default_rng(seed)
    t = bank.num_trees
    qt = rng.integers(0, t, size=batch).astype(np.int32)
    names = np.asarray(forest.entity_names)
    qh = np.empty(batch, np.uint32)
    for j in range(batch):
        if j % 4 == 0:                                   # 25% misses
            qh[j] = np.uint32(rng.integers(1, 2 ** 32))
        else:
            qh[j] = hashing.entity_hash(
                f"entity {qt[j]}_{rng.integers(len(names) // t)}")
    return qt, qh


def _time(fn, iters: int) -> float:
    fn()                                                 # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(tree_counts: Sequence[int] = (16, 64, 256),
        entities_per_tree: int = 24, batch: int = 1024, iters: int = 5,
        seed: int = 0) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    d = jax.device_count()
    mesh = jax.make_mesh((d,), ("model",))
    rows = []
    for t in tree_counts:
        forest = _forest(t, entities_per_tree)
        bank = build_bank(forest)
        sbank = bank.shard(d)
        state = stage_sharded_bank(sbank, forest, mesh, "model")
        qt, qh = _queries(forest, bank, batch, seed)
        qt_j, qh_j = jnp.asarray(qt), jnp.asarray(qh)

        mf, _, mh = sbank.merged_tables()
        fps_r, heads_r = jnp.asarray(mf), jnp.asarray(mh)
        rep_fn = jax.jit(lookup_batch_bank)

        # ---- equivalence gate before timing
        ref = rep_fn(fps_r, heads_r, qt_j, qh_j)
        got = sharded_lookup_bank(state, qt_j, qh_j)
        for f in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
                err_msg=f"sharded {f} diverged at T={t}")

        t_rep = _time(
            lambda: jax.block_until_ready(
                rep_fn(fps_r, heads_r, qt_j, qh_j)), iters)
        t_shd = _time(
            lambda: jax.block_until_ready(
                sharded_lookup_bank(state, qt_j, qh_j)), iters)

        table_bytes = lambda a: int(a.nbytes)            # noqa: E731
        rep_dev = sum(table_bytes(x) for x in (fps_r, heads_r)) \
            + int(jnp.asarray(mf).nbytes)                # temperature too
        shard_dev = sum(
            next(iter(x.addressable_shards)).data.nbytes
            for x in (state.fingerprints, state.temperature, state.heads))
        rows.append(dict(
            trees=t, num_buckets=bank.num_buckets, slots=bank.slots,
            devices=d, batch=batch,
            replicated_ms=t_rep * 1e3, sharded_ms=t_shd * 1e3,
            speedup=t_rep / t_shd if t_shd else 0.0,
            replicated_device_bytes=rep_dev,
            sharded_device_bytes=shard_dev,
            bytes_fraction=shard_dev / rep_dev,
            hits=int(np.asarray(got.hit).sum()),
        ))
    return rows


def print_rows(rows: List[Dict]) -> None:
    print("distributed: replicated vs bank-axis sharded lookup "
          "(all-to-all routed, no bank broadcast)")
    print(f"{'trees':>6s} {'dev':>4s} {'batch':>6s} {'rep_ms':>9s} "
          f"{'shard_ms':>9s} {'speedup':>8s} {'dev_bytes':>10s} "
          f"{'frac':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['devices']:4d} {r['batch']:6d} "
              f"{r['replicated_ms']:9.3f} {r['sharded_ms']:9.3f} "
              f"{r['speedup']:8.2f} {r['sharded_device_bytes']:10d} "
              f"{r['bytes_fraction']:6.3f}")


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        json_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    unknown = [a for a in args if a != "--smoke"]
    if unknown:
        sys.exit(f"usage: python -m benchmarks.bench_distributed "
                 f"[--smoke] [--json PATH] (unknown: {' '.join(unknown)})")
    kw = (dict(tree_counts=(16, 64), entities_per_tree=12, batch=256,
               iters=2)
          if "--smoke" in args else
          dict(tree_counts=(16, 64, 256), entities_per_tree=24,
               batch=1024, iters=5))
    import jax
    rows = run(**kw)
    print_rows(rows)
    for r in rows:
        # the capacity claim: per-device table bytes shrink ~1/D
        # (padding can round one tree range up)
        assert r["bytes_fraction"] <= 1.0 / r["devices"] + 0.05, r
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"device_count": jax.device_count(),
                       "rows": rows}, f, indent=2)
        print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
