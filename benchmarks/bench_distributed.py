"""Replicated vs bank-axis-sharded lookup — the scaling claim, measured.

The replicated path keeps the whole ragged bucket arena on one device and
probes it with ``lookup_batch_ragged``; the sharded path partitions tree
ranges over the mesh (``FilterBank.shard`` + ``stage_sharded_bank``) and
routes each query batch through the ``shard_map`` all-to-all
(``sharded_lookup_bank``).  For every T the sweep records wall-clock for
both, the per-device filter-table bytes for both (the capacity axis the
sharding actually buys), and gates on bit-identical results before any
timing is reported.

Run on a forced multi-device host platform::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_distributed \\
        [--smoke] [--json BENCH_shard.json]

The CI smoke job writes ``BENCH_shard.json`` from here (next to
``BENCH_bank.json`` from ``bench_churn``) so the distributed-lookup perf
trajectory is recorded per commit.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core import build_bank, lookup_batch_ragged
from repro.core import hashing

from .common import (best_time, parse_bench_args, synthetic_forest,
                     write_json)


def _queries(forest, bank, batch: int, seed: int):
    """Mixed hit/miss batch spread over every tree."""
    rng = np.random.default_rng(seed)
    t = bank.num_trees
    qt = rng.integers(0, t, size=batch).astype(np.int32)
    names = np.asarray(forest.entity_names)
    qh = np.empty(batch, np.uint32)
    for j in range(batch):
        if j % 4 == 0:                                   # 25% misses
            qh[j] = np.uint32(rng.integers(1, 2 ** 32))
        else:
            qh[j] = hashing.entity_hash(
                f"entity {qt[j]}_{rng.integers(len(names) // t)}")
    return qt, qh


def run(tree_counts: Sequence[int] = (16, 64, 256),
        entities_per_tree: int = 24, batch: int = 1024, iters: int = 5,
        seed: int = 0) -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import (stage_sharded_bank,
                                        sharded_lookup_bank)

    d = jax.device_count()
    mesh = jax.make_mesh((d,), ("model",))
    rows = []
    for t in tree_counts:
        forest = synthetic_forest(t, entities_per_tree)
        bank = build_bank(forest)
        sbank = bank.shard(d)
        state = stage_sharded_bank(sbank, forest, mesh, "model")
        qt, qh = _queries(forest, bank, batch, seed)
        qt_j, qh_j = jnp.asarray(qt), jnp.asarray(qh)

        mf, mt, mh = sbank.merged_tables()
        moff, mnb = sbank.merged_layout()
        fps_r, heads_r = jnp.asarray(mf), jnp.asarray(mh)
        off_r = jnp.asarray(moff.astype(np.int32))
        nb_r = jnp.asarray(mnb)
        rep_fn = jax.jit(lookup_batch_ragged)

        # ---- equivalence gate before timing
        ref = rep_fn(fps_r, heads_r, off_r, nb_r, qt_j, qh_j)
        got = sharded_lookup_bank(state, qt_j, qh_j)
        for f in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
                err_msg=f"sharded {f} diverged at T={t}")

        t_rep = best_time(
            lambda: jax.block_until_ready(
                rep_fn(fps_r, heads_r, off_r, nb_r, qt_j, qh_j)), iters)
        t_shd = best_time(
            lambda: jax.block_until_ready(
                sharded_lookup_bank(state, qt_j, qh_j)), iters)

        rep_dev = sum(int(jnp.asarray(x).nbytes) for x in (mf, mt, mh))
        shard_dev = sum(
            next(iter(x.addressable_shards)).data.nbytes
            for x in (state.fingerprints, state.temperature, state.heads))
        rows.append(dict(
            trees=t, arena_rows=bank.total_buckets,
            max_tree_rows=int(bank.tree_nb.max()), slots=bank.slots,
            devices=d, batch=batch,
            replicated_ms=t_rep * 1e3, sharded_ms=t_shd * 1e3,
            speedup=t_rep / t_shd if t_shd else 0.0,
            replicated_device_bytes=rep_dev,
            sharded_device_bytes=shard_dev,
            bytes_fraction=shard_dev / rep_dev,
            hits=int(np.asarray(got.hit).sum()),
        ))
    return rows


def print_rows(rows: List[Dict]) -> None:
    print("distributed: replicated vs bank-axis sharded lookup "
          "(all-to-all routed, no bank broadcast)")
    print(f"{'trees':>6s} {'dev':>4s} {'batch':>6s} {'rep_ms':>9s} "
          f"{'shard_ms':>9s} {'speedup':>8s} {'dev_bytes':>10s} "
          f"{'frac':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['devices']:4d} {r['batch']:6d} "
              f"{r['replicated_ms']:9.3f} {r['sharded_ms']:9.3f} "
              f"{r['speedup']:8.2f} {r['sharded_device_bytes']:10d} "
              f"{r['bytes_fraction']:6.3f}")


def main() -> None:
    import sys
    flags, json_path = parse_bench_args(sys.argv[1:], "bench_distributed",
                                        flags=("--smoke",))
    kw = (dict(tree_counts=(16, 64), entities_per_tree=12, batch=256,
               iters=2)
          if "--smoke" in flags else
          dict(tree_counts=(16, 64, 256), entities_per_tree=24,
               batch=1024, iters=5))
    import jax
    rows = run(**kw)
    print_rows(rows)
    for r in rows:
        # the capacity claim: per-device table bytes shrink ~1/D.  The
        # packed ragged layout pads every shard to the largest shard's
        # arena, and a contiguous tree partition can misplace at most
        # about one tree's worth of rows — so the honest bound is
        # 1/D + (largest tree segment)/A, tight as T grows.
        bound = (1.0 / r["devices"]
                 + r["max_tree_rows"] / r["arena_rows"] + 0.02)
        assert r["bytes_fraction"] <= bound, (r, bound)
    write_json(json_path, {"device_count": jax.device_count(),
                           "rows": rows})


if __name__ == "__main__":
    main()
