"""Shared benchmark machinery: corpus builders, timed retrieval rounds."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence

from repro.core import (BloomTRAG, BloomTRAG2, CFTRAG, NaiveTRAG,
                        build_forest, build_index)
from repro.data import hospital_corpus

ALGOS = ("naive", "bf", "bf2", "cf")


def build_retrievers(num_trees: int, seed: int = 7, depth: int = 3,
                     branching: int = 3):
    corpus = hospital_corpus(num_trees=num_trees, depth=depth,
                             branching=branching, num_queries=32, seed=seed)
    forest = build_forest(corpus.trees)
    index = build_index(forest, num_buckets=1024)
    return corpus, forest, {
        "naive": NaiveTRAG(forest),
        "bf": BloomTRAG(forest),
        "bf2": BloomTRAG2(forest),
        "cf": CFTRAG(index, sort_every=1),
    }


def time_retrieval(retriever, queries: Sequence[Sequence[str]],
                   repeats: int = 3) -> float:
    """Mean seconds per full query set (paper times the retrieval phase)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for ents in queries:
            for e in ents:
                retriever.locate(e)
        best = min(best, time.perf_counter() - t0)
    return best


def accuracy_proxy(forest, retriever, queries: Sequence[Sequence[str]],
                   naive: NaiveTRAG) -> float:
    """Retrieval-context exactness vs naive BFS (DESIGN.md §7)."""
    total = correct = 0
    for ents in queries:
        for e in ents:
            total += 1
            if sorted(retriever.locate(e)) == sorted(naive.locate(e)):
                correct += 1
    return correct / max(total, 1)
