"""Shared benchmark machinery: corpus builders, timed retrieval rounds,
and the timing / CLI / JSON-report helpers every bench module used to
copy-paste (``best_time`` / ``parse_bench_args`` / ``write_json``)."""
from __future__ import annotations

import json
import sys
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core import (BloomTRAG, BloomTRAG2, CFTRAG, NaiveTRAG,
                        build_forest, build_index)
from repro.data import hospital_corpus

ALGOS = ("naive", "bf", "bf2", "cf")


def synthetic_forest(num_trees: int, entities_per_tree: int):
    """Flat one-root-per-tree forest — the shared bank-bench corpus."""
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def best_time(fn: Callable[[], object], iters: int,
              warmup: bool = True) -> float:
    """Best-of-N wall clock; one untimed call first to absorb compiles."""
    if warmup:
        fn()
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def timed_call(fn: Callable[[], object]):
    """Run ``fn`` once; returns (result, seconds) — the per-query timing
    shape the serving benches repeat."""
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def parse_bench_args(argv: Sequence[str], prog: str,
                     flags: Sequence[str] = ("--fast", "--smoke")
                     ) -> Tuple[set, Optional[str]]:
    """The ``[--fast|--smoke] [--json PATH]`` CLI every bench repeats.

    Returns (set of present flags, json path or None); exits with a usage
    message on anything unrecognized (a typo'd flag must not silently run
    the full suite)."""
    args = list(argv)
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        json_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    unknown = [a for a in args if a not in flags]
    if unknown:
        sys.exit(f"usage: python -m benchmarks.{prog} "
                 f"[{'|'.join(flags)}] [--json PATH] "
                 f"(unknown: {' '.join(unknown)})")
    return set(args), json_path


def write_json(path: Optional[str], payload: Dict) -> None:
    """Write a bench report artifact (no-op when no path was requested)."""
    if not path:
        return
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")


def build_retrievers(num_trees: int, seed: int = 7, depth: int = 3,
                     branching: int = 3):
    corpus = hospital_corpus(num_trees=num_trees, depth=depth,
                             branching=branching, num_queries=32, seed=seed)
    forest = build_forest(corpus.trees)
    index = build_index(forest, num_buckets=1024)
    return corpus, forest, {
        "naive": NaiveTRAG(forest),
        "bf": BloomTRAG(forest),
        "bf2": BloomTRAG2(forest),
        "cf": CFTRAG(index, sort_every=1),
    }


def time_retrieval(retriever, queries: Sequence[Sequence[str]],
                   repeats: int = 3) -> float:
    """Mean seconds per full query set (paper times the retrieval phase)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for ents in queries:
            for e in ents:
                retriever.locate(e)
        best = min(best, time.perf_counter() - t0)
    return best


def accuracy_proxy(forest, retriever, queries: Sequence[Sequence[str]],
                   naive: NaiveTRAG) -> float:
    """Retrieval-context exactness vs naive BFS (DESIGN.md §7)."""
    total = correct = 0
    for ents in queries:
        for e in ents:
            total += 1
            if sorted(retriever.locate(e)) == sorted(naive.locate(e)):
                correct += 1
    return correct / max(total, 1)
