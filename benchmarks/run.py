"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the human tables from
each module's main()).  ``python -m benchmarks.run [--fast|--smoke]``
(``--smoke`` is the CI-sized variant: tiny inputs, every harness exercised).
"""
from __future__ import annotations

import sys

from . import (bench_bank, bench_churn, bench_fig5, bench_filter,
               bench_kernels, bench_pause, bench_ragged, bench_serving,
               bench_table1, bench_table2)


def main() -> None:
    unknown = [a for a in sys.argv[1:] if a not in ("--fast", "--smoke")]
    if unknown:        # a typo'd flag must not silently run the full suite
        sys.exit(f"usage: python -m benchmarks.run [--fast|--smoke] "
                 f"(unknown: {' '.join(unknown)})")
    smoke = "--smoke" in sys.argv
    fast = smoke or "--fast" in sys.argv
    csv = []

    tree_counts = ((12, 25) if smoke else
                   (50, 120) if fast else (50, 300, 600))
    rows = bench_table1.run(tree_counts=tree_counts)
    print("\n== Table 1: retrieval time vs #trees ==")
    print(f"{'trees':>6s} {'algo':>6s} {'time_s':>12s} {'speedup':>9s} "
          f"{'acc':>6s}")
    for r in rows:
        print(f"{r['trees']:6d} {r['algo']:>6s} {r['time_s']:12.6f} "
              f"{r['speedup_vs_naive']:9.1f} {r['acc']:6.3f}")
        csv.append((f"table1/trees{r['trees']}/{r['algo']}",
                    r["time_s"] * 1e6, r["speedup_vs_naive"]))

    ent_counts = (5,) if smoke else (5, 10) if fast else (5, 10, 20)
    rows = bench_table2.run(entity_counts=ent_counts,
                            num_trees=25 if smoke else
                            120 if fast else 600)
    print("\n== Table 2: retrieval time vs #entities per query ==")
    print(f"{'ents':>5s} {'algo':>6s} {'time_s':>12s} {'speedup':>9s} "
          f"{'acc':>6s}")
    for r in rows:
        print(f"{r['entities']:5d} {r['algo']:>6s} {r['time_s']:12.6f} "
              f"{r['speedup_vs_naive']:9.1f} {r['acc']:6.3f}")
        csv.append((f"table2/ents{r['entities']}/{r['algo']}",
                    r["time_s"] * 1e6, r["speedup_vs_naive"]))

    rows = bench_fig5.run(num_trees=20 if smoke else 60 if fast else 300,
                          rounds=2 if smoke else 4 if fast else 8)
    print("\n== Figure 5: temperature-sort ablation (per round) ==")
    print(f"{'round':>6s} {'unsorted_probes':>16s} {'sorted_probes':>14s} "
          f"{'gain':>6s}")
    nr = 2 if smoke else 4 if fast else 8
    for rnd in range(1, nr + 1):
        u = next(r for r in rows if not r["sorted"] and r["round"] == rnd)
        s = next(r for r in rows if r["sorted"] and r["round"] == rnd)
        gain = u["probes"] / s["probes"]
        print(f"{rnd:6d} {u['probes']:16d} {s['probes']:14d} {gain:6.2f}")
        csv.append((f"fig5/round{rnd}/sorted", s["time_s"] * 1e6, gain))

    er = bench_filter.error_rate(probes=2_000 if smoke else
                                 20_000 if fast else 100_000)
    print("\n== Filter: load factor / error rate ==")
    for k, v in er.items():
        print(f"  {k}: {v}")
    csv.append(("filter/error_rate", 0.0, er["false_positive_rate"]))
    csv.append(("filter/load_factor", 0.0, er["load_factor"]))

    bv = bench_filter.batched_vs_sequential(
        num_trees=20 if smoke else 60 if fast else 300,
        batch=128 if smoke else 256 if fast else 512)
    print("\n== Batched device lookup vs sequential host loop ==")
    for k, v in bv.items():
        print(f"  {k}: {v}")
    csv.append(("filter/batched_speedup", bv["vectorized_s"] * 1e6,
                bv["speedup"]))

    bank_trees = ((1, 4) if smoke else (1, 8, 64) if fast
                  else (1, 8, 64, 256))
    rows = bench_bank.run(tree_counts=bank_trees,
                          entities_per_tree=8 if smoke else 48,
                          batch_per_tree=16 if smoke else 64,
                          repeats=1 if smoke else 3)
    print("\n== Filter bank: bulk build + vmapped lookup vs #trees ==")
    print(f"{'trees':>6s} {'items':>7s} {'build_x':>8s} {'lookup_x':>9s} "
          f"{'exact':>6s}")
    for r in rows:
        assert r["vmap_exact"], "bank lookup diverged from reference"
        print(f"{r['trees']:6d} {r['items']:7d} {r['build_speedup']:8.1f} "
              f"{r['lookup_speedup']:9.1f} {str(r['vmap_exact']):>6s}")
        csv.append((f"bank/trees{r['trees']}/build",
                    r["build_bulk_s"] * 1e6, r["build_speedup"]))
        csv.append((f"bank/trees{r['trees']}/lookup",
                    r["lookup_vmap_s"] * 1e6, r["lookup_speedup"]))

    churn_kw = (dict(tree_counts=(16,), entities_per_tree=24, ops=128,
                     batch=32) if smoke else
                dict(tree_counts=(16, 64), entities_per_tree=32, ops=512)
                if fast else
                dict(tree_counts=(16, 64, 256), ops=2048))
    rows = bench_churn.run(**churn_kw)
    print("\n== Churn: incremental bank maintenance vs full rebuild ==")
    bench_churn.print_rows(rows)
    for r in rows:
        assert r["equal"], "incremental bank diverged from fresh build"
        csv.append((f"churn/trees{r['trees']}/incremental",
                    r["inc_us_per_op"], r["speedup"]))
        csv.append((f"churn/trees{r['trees']}/rebuild",
                    r["rebuild_us_per_op"], 1.0))

    rows = bench_ragged.run(
        tree_counts=(64,) if fast else (64, 256),
        entities_per_tree=4 if smoke else 8,
        iters=1 if smoke else 3)
    print("\n== Ragged arena: bytes + tree-local expand vs dense ==")
    bench_ragged.print_rows(rows)
    for r in rows:
        assert r["equal"], "ragged lookup diverged from reference"
        csv.append((f"ragged/trees{r['trees']}/bytes_fraction",
                    0.0, r["bytes_fraction"]))
        csv.append((f"ragged/trees{r['trees']}/expand",
                    r["expand_tree_ms"] * 1e3, r["expand_speedup"]))

    rows = bench_pause.run(
        num_trees=96 if smoke else 192,
        entities_per_tree=24 if smoke else 48,
        cycles=3 if smoke else 5, batches_per_cycle=4,
        batch=96 if smoke else 160, use_mesh=False)
    print("\n== Zero-pause maintenance: sync vs double-buffered "
          "restage ==")
    bench_pause.print_rows(rows)
    for r in rows:
        assert r["equal"], "splice commit diverged from full restage"
        csv.append((f"pause/{r['layout']}/sync", r["sync_max_pause_ms"]
                    * 1e3, 1.0))
        csv.append((f"pause/{r['layout']}/double_buffered",
                    r["db_max_pause_ms"] * 1e3, r["pause_reduction"]))

    print("\n== Kernel microbenchmarks (vs jnp oracle) ==")
    for r in bench_kernels.micro_rows():
        print(f"  {r['name']:34s} work~{r['work']:10.1f}  "
              f"derived {r['derived']:.3e}")
        csv.append((f"kernels/{r['name']}", r["work"], r["derived"]))

    if not fast:
        rows = bench_serving.run()
        ret = sum(r["retrieval_ms"] for r in rows) / len(rows)
        gen = sum(r["generation_ms"] for r in rows) / len(rows)
        print("\n== Serving: retrieval vs generation latency ==")
        print(f"  mean retrieval {ret:.2f} ms, generation {gen:.1f} ms "
              f"({100 * ret / (ret + gen):.2f}% of latency)")
        csv.append(("serving/retrieval_fraction", ret * 1e3,
                    ret / (ret + gen)))
        rows = bench_serving.run_bank_sweep()
        print("\n== Serving vs #trees: retrieval fraction + upkeep ==")
        bench_serving.print_bank_sweep(rows)
        for r in rows:
            csv.append((f"serving/trees{r['trees']}/retrieval_fraction",
                        r["retrieval_ms"] * 1e3, r["retrieval_fraction"]))
            csv.append((f"serving/trees{r['trees']}/maint_speedup",
                        r["maint_inc_us_per_op"], r["maint_speedup"]))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.2f},{derived:.4f}")


if __name__ == "__main__":
    main()
