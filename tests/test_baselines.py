"""The paper's comparison invariant: all four retrievers locate the same
entity addresses (CF may only add fingerprint-collision false positives,
measured ~0 at the paper's load factor)."""
import random

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback (CI installs the real one)
    from _hypothesis_stub import given, settings, st

from repro.core import (BloomTRAG, BloomTRAG2, CFTRAG, NaiveTRAG,
                        build_forest, build_index)
from repro.core import hashing
from repro.data import hospital_corpus, unhcr_corpus


@pytest.mark.parametrize("corpus_fn,trees", [(hospital_corpus, 25),
                                             (unhcr_corpus, 8)])
def test_all_methods_agree_on_corpora(corpus_fn, trees):
    c = corpus_fn(num_trees=trees, num_queries=6)
    forest = build_forest(c.trees)
    idx = build_index(forest, num_buckets=1024)
    cf = CFTRAG(idx)
    naive = NaiveTRAG(forest)
    b1 = BloomTRAG(forest)
    b2 = BloomTRAG2(forest)
    rng = random.Random(0)
    probe = rng.sample(forest.entity_names, min(60, forest.num_entities))
    probe += ["Unknown Entity X", "Nobody"]
    for nm in probe:
        expect = sorted(naive.locate(nm))
        assert sorted(cf.locate(nm)) == expect, nm
        assert sorted(b1.locate(nm)) == expect, nm
        assert sorted(b2.locate(nm)) == expect, nm


def test_contexts_match():
    c = hospital_corpus(num_trees=10, num_queries=4)
    forest = build_forest(c.trees)
    idx = build_index(forest)
    cf = CFTRAG(idx, sort_every=1)
    naive = NaiveTRAG(forest)
    for q in c.query_entities:
        a = cf.retrieve(q)
        b = naive.retrieve(q, n=3)
        for ca, cb in zip(a, b):
            assert ca.locations == cb.locations
            assert ca.up == cb.up and ca.down == cb.down


def test_blocklist_vs_csr_paths():
    c = hospital_corpus(num_trees=10)
    forest = build_forest(c.trees)
    idx = build_index(forest)
    faithful = CFTRAG(idx, use_csr=False)
    fast = CFTRAG(idx, use_csr=True)
    for nm in forest.entity_names[:50]:
        assert sorted(faithful.locate(nm)) == sorted(fast.locate(nm))


def test_csr_path_consistent_on_false_positive():
    """Regression: a filter hit on an unknown name must walk the same
    addresses on the CSR path as on the arena path (previously the CSR
    path re-resolved the name and silently returned nothing)."""
    c = hospital_corpus(num_trees=25)
    forest = build_forest(c.trees)
    idx = build_index(forest, num_buckets=1024)
    faithful = CFTRAG(idx, use_csr=False)
    fast = CFTRAG(idx, use_csr=True)
    ghost = None
    for i in range(200_000):       # deterministic: fixed corpus + hashing
        nm = f"ghost {i}"
        if nm not in forest.name_to_id and idx.filter.contains(
                int(hashing.entity_hash(nm))):
            ghost = nm
            break
    assert ghost is not None, "no fingerprint collision found"
    assert sorted(faithful.locate(ghost)) == sorted(fast.locate(ghost))
    assert faithful.locate(ghost)          # the collision does walk entries


name = st.text(alphabet="xyzw", min_size=1, max_size=3)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.tuples(name, name), min_size=1, max_size=12),
                min_size=1, max_size=6))
def test_property_cf_equals_naive(trees):
    forest = build_forest([list(t) for t in trees])
    idx = build_index(forest, num_buckets=256)
    cf = CFTRAG(idx)
    naive = NaiveTRAG(forest)
    for nm in forest.entity_names:
        assert sorted(cf.locate(nm)) == sorted(naive.locate(nm)), nm
