"""Dynamic bank maintenance: incremental insert/delete/expand, temperature
write-back, idle-time sort, and churn equivalence vs a fresh bulk build."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # offline container
    from _hypothesis_stub import given, settings, st

from repro.core import (CFTDeviceState, MaintenanceEngine, build_bank,
                        build_bank_from_rows, build_forest, retrieve_device,
                        sort_buckets_arena)
from repro.core import hashing


def _forest(num_trees=8, entities_per_tree=20):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _setup(num_trees=8, entities_per_tree=20, **kw):
    forest = _forest(num_trees, entities_per_tree)
    bank = build_bank(forest)
    return forest, bank, MaintenanceEngine(bank, **kw), \
        hashing.hash_entities(forest.entity_names)


# ---------------------------------------------------------- insert / delete

def test_insert_round_trip():
    """insert -> lookup hit with the exact node list and entity payload."""
    forest, bank, eng, hashes = _setup()
    h = int(hashing.entity_hash("brand new entity"))
    eng.insert(3, h, [5, 9, 11], entity_id=12345)
    hit, row, eid = bank.lookup(3, h)
    assert hit and eid == 12345
    assert bank.walk_row(row) == [5, 9, 11]
    # routed: the other trees still miss it (modulo fp collisions, which
    # exact-find rules out)
    rows, _ = bank.find_exact(np.asarray([0, 1, 2]), np.asarray([h] * 3))
    assert (rows == -1).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_delete_keeps_remaining_rows(seed):
    """delete -> no false negative for any surviving (tree, entity)."""
    forest, bank, eng, hashes = _setup(num_trees=6)
    rng = np.random.default_rng(seed)
    kill = rng.choice(bank.num_rows, size=bank.num_rows // 3, replace=False)
    killset = set(int(k) for k in kill)
    for r in kill:
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        assert eng.delete(t, int(hashes[e]))
    for r in range(bank.num_rows):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        h = int(hashes[e])
        if r in killset:
            rows, _ = bank.find_exact(np.asarray([t]), np.asarray([h]))
            assert int(rows[0]) == -1          # exact hash really gone
        else:
            hit, row, eid = bank.lookup(t, h)
            assert hit and eid == e            # survivors never go missing
            assert bank.walk_row(row)


def test_replace_semantics():
    """Inserting a live key replaces its CSR row (no duplicate slots)."""
    forest, bank, eng, hashes = _setup()
    r = 7
    t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
    h = int(hashes[e])
    eng.insert(t, h, [1, 2], entity_id=e)
    hit, row, eid = bank.lookup(t, h)
    assert hit and bank.walk_row(row) == [1, 2]
    lo, hi = bank.segment(t)
    occ = bank.stored_hash[lo:hi] == np.uint32(h)
    occ &= bank.fingerprints[lo:hi] != hashing.EMPTY_FP
    assert int(occ.sum()) == 1                 # exactly one slot holds it


def test_expand_preserves_memberships_and_temperature():
    forest, bank, eng, hashes = _setup(num_trees=4, entities_per_tree=12)
    bank.temperature[bank.fingerprints != hashing.EMPTY_FP] = 7
    nb0 = bank.tree_nb.copy()
    eng.expand()
    assert np.array_equal(bank.tree_nb, 2 * nb0)
    for r in range(bank.num_rows):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        hit, row, eid = bank.lookup(t, int(hashes[e]))
        assert hit and eid == e and row == r
    assert (bank.temperature[bank.fingerprints
                             != hashing.EMPTY_FP] == 7).all()


def test_overload_triggers_expand():
    """Inserts past the load threshold restage ONLY the overflowing tree's
    arena segment at a bigger nb — every other tree's bucket count (and
    segment bytes) stay untouched (the ragged tree-local expand policy)."""
    forest, bank, eng, hashes = _setup(num_trees=4, entities_per_tree=12)
    nb0 = bank.tree_nb.copy()
    cap = int(nb0[1]) * bank.slots
    extra = int(cap - bank.num_items[1] + 4)   # push tree 1 over
    snaps = {t: tuple(arr[slice(*bank.segment(t))].tobytes()
                      for arr in (bank.fingerprints, bank.heads,
                                  bank.stored_hash))
             for t in (0, 2, 3)}
    for i in range(extra):
        eng.queue_insert(1, int(hashing.entity_hash(f"stuffing {i}")), [i])
    eng.apply()
    assert bank.tree_nb[1] > nb0[1]
    assert (np.delete(bank.tree_nb, 1) == np.delete(nb0, 1)).all()
    assert eng.stats["expansions"] >= 1
    for t, snap in snaps.items():              # other segments byte-equal
        cur = tuple(arr[slice(*bank.segment(t))].tobytes()
                    for arr in (bank.fingerprints, bank.heads,
                                bank.stored_hash))
        assert cur == snap, t
    for i in range(extra):
        h = int(hashing.entity_hash(f"stuffing {i}"))
        hit, row, _ = bank.lookup(1, h)
        assert hit and bank.walk_row(row) == [i]


def test_compaction_reclaims_and_preserves():
    forest, bank, eng, hashes = _setup()
    rows0 = bank.num_rows
    for r in range(0, rows0, 2):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        eng.queue_delete(t, int(hashes[e]))
    eng.apply()
    assert eng.num_dead_rows == (rows0 + 1) // 2
    survivors = {}
    for r in range(1, rows0, 2):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        survivors[(t, e)] = bank.walk_row(r)
    assert eng.compact()
    assert eng.num_dead_rows == 0 and bank.num_rows == len(survivors)
    for (t, e), nodes in survivors.items():
        hit, row, eid = bank.lookup(t, int(hashes[e]))
        assert hit and eid == e and bank.walk_row(row) == nodes


# --------------------------------------------------- temperature + sorting

def test_absorb_temperature_counts_bumps():
    forest, bank, eng, hashes = _setup()
    state = CFTDeviceState.from_bank(bank, forest)
    tid = jnp.asarray(bank.row_tree[:16].astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity[:16]])
    out = retrieve_device(state, hh, tid)
    state = state.with_temperature(out.temperature)
    assert eng.absorb(state) == 16
    assert eng.bumps_since_sort == 16
    assert eng.absorb(state) == 0              # idempotent re-absorb
    np.testing.assert_array_equal(bank.temperature,
                                  np.asarray(out.temperature))


def test_sort_trigger_policy_and_host_device_agreement():
    forest, bank, eng, hashes = _setup(sort_threshold=8)
    # heat a few slots, below threshold: no sort
    occ = np.argwhere(bank.fingerprints != hashing.EMPTY_FP)
    r0, s0 = occ[len(occ) // 2]
    bank.temperature[r0, s0] = 50
    eng.bumps_since_sort = 4
    assert not eng.maybe_sort()
    eng.bumps_since_sort = 9                   # past threshold: sorts
    # device sort of the same arena must agree with the host sort
    f, tt, hd = sort_buckets_arena(jnp.asarray(bank.fingerprints),
                                   jnp.asarray(bank.temperature),
                                   jnp.asarray(bank.heads))
    assert eng.maybe_sort()
    assert eng.bumps_since_sort == 0
    np.testing.assert_array_equal(np.asarray(f), bank.fingerprints)
    np.testing.assert_array_equal(np.asarray(tt), bank.temperature)
    np.testing.assert_array_equal(np.asarray(hd), bank.heads)
    assert bank.temperature[r0, 0] == 50       # hot slot floated to 0
    # membership survives the reorder
    for r in range(bank.num_rows):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        assert bank.lookup(t, int(hashes[e]))[0]


def test_maintain_reports_and_restage_flag():
    forest, bank, eng, hashes = _setup(sort_threshold=4)
    state = CFTDeviceState.from_bank(bank, forest)
    rep = eng.maintain(state)
    assert not rep.changed                     # nothing pending, no heat
    tid = jnp.asarray(bank.row_tree[:8].astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity[:8]])
    out = retrieve_device(state, hh, tid)
    eng.queue_insert(0, int(hashing.entity_hash("fresh")), [0])
    rep = eng.maintain(state.with_temperature(out.temperature))
    assert rep.absorbed_bumps == 8 and rep.inserted == 1 and rep.sorted
    assert rep.changed                         # caller must restage


# ------------------------------------------------------- churn equivalence

def test_churn_equivalence_1k_ops_16_trees():
    """Acceptance gate: after >= 1k randomized insert/delete ops across
    >= 16 trees, the incrementally maintained bank answers exactly like a
    from-scratch bulk build — every surviving key is stored (no false
    negatives, exact-hash check) and routed lookups return identical node
    lists."""
    num_trees, total_ops, batch = 16, 1024, 64
    forest = _forest(num_trees, 48)
    hashes = hashing.hash_entities(forest.entity_names)
    bank = build_bank(forest)
    eng = MaintenanceEngine(bank, seed=1)
    rng = np.random.default_rng(42)

    all_rows = {}
    for r in range(bank.num_rows):
        all_rows[(int(bank.row_tree[r]),
                  int(bank.row_entity[r]))] = bank.walk_row(r)
    live = dict(all_rows)
    ops = 0
    while ops < total_ops:
        touched = set()
        for _ in range(batch):
            dead = [k for k in all_rows if k not in live
                    and k not in touched]
            if len(live) > len(all_rows) // 3 and \
                    (not dead or rng.random() < 0.5):
                cands = [k for k in live if k not in touched]
                k = cands[int(rng.integers(len(cands)))]
                eng.queue_delete(k[0], int(hashes[k[1]]))
                del live[k]
            else:
                k = dead[int(rng.integers(len(dead)))]
                eng.queue_insert(k[0], int(hashes[k[1]]), all_rows[k],
                                 entity_id=k[1])
                live[k] = all_rows[k]
            touched.add(k)
            ops += 1
        eng.maintain()                         # apply + maybe compact
    assert ops >= 1000 and bank.num_trees >= 16

    ks = sorted(live)
    rt = np.asarray([k[0] for k in ks], np.int32)
    re_ = np.asarray([k[1] for k in ks], np.int32)
    rh = hashes[re_].astype(np.uint32)
    lens = np.asarray([len(live[k]) for k in ks], np.int32)
    off = np.zeros(len(ks) + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    nodes = np.concatenate([np.asarray(live[k], np.int32) for k in ks])
    fresh = build_bank_from_rows(num_trees, rt, re_, rh, off, nodes)

    assert int(bank.num_items.sum()) == len(live)
    np.testing.assert_array_equal(bank.num_items, fresh.num_items)
    rows_i, _ = bank.find_exact(rt, rh)
    rows_f, _ = fresh.find_exact(rt, rh)
    assert (rows_i >= 0).all() and (rows_f >= 0).all()   # no false negs
    for j, k in enumerate(ks):
        h = int(rh[j])
        hi, ri, _ = bank.lookup(k[0], h)
        hf, rf, _ = fresh.lookup(k[0], h)
        assert hi and hf
        assert bank.walk_row(ri) == fresh.walk_row(rf)   # identical CSR


def test_out_of_range_tree_rejected_at_queue_time():
    forest, bank, eng, hashes = _setup(num_trees=4)
    for bad in (-1, 4, 99):
        try:
            eng.queue_insert(bad, "x", [0])
            assert False, "expected ValueError"
        except ValueError:
            pass
        try:
            eng.queue_delete(bad, "x")
            assert False, "expected ValueError"
        except ValueError:
            pass
    assert not eng.delta                       # nothing half-queued


def test_pipeline_live_insert_reachable_in_queries():
    """A live-inserted entity must be recognizable by NER and resolvable
    end to end (gazetteer learns the name, bank serves the nodes)."""
    from repro.data import HashTokenizer, hospital_corpus
    from repro.serving import RAGPipeline
    corpus = hospital_corpus(num_trees=6, num_queries=2)
    rag = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024),
                      use_bank=True)
    node = int(rag.forest.child_index[0])      # a node with a parent
    rag.insert_entity(2, "Brand New Clinic", [node])
    rep = rag.maintain()
    assert rep.inserted == 1
    ans = rag.retrieve("Describe the Brand New Clinic please")
    assert "Brand New Clinic" in ans.entities
    assert "hierarchical relationship of Brand New Clinic" in ans.context


# -------------------------------------------------- serving-path retrieval

def test_maintained_bank_serves_through_device_path():
    """Inserted rows become retrievable through retrieve_device after the
    idle-time restage; deleted rows stop hitting."""
    forest, bank, eng, hashes = _setup(num_trees=6)
    h_new = int(hashing.entity_hash("night shift ward"))
    eng.queue_insert(2, h_new, [3, 4], entity_id=-1)
    r0 = 0
    t0, e0 = int(bank.row_tree[r0]), int(bank.row_entity[r0])
    eng.queue_delete(t0, int(hashes[e0]))
    rep = eng.maintain()
    assert rep.changed
    state = CFTDeviceState.from_bank(bank, forest)
    out = retrieve_device(
        state, jnp.asarray(np.asarray([h_new, hashes[e0]], np.uint32)),
        jnp.asarray(np.asarray([2, t0], np.int32)))
    assert bool(out.hit[0])
    locs = [int(v) for v in np.asarray(out.locations[0]) if v >= 0]
    assert locs == [3, 4]
    assert not bool(out.hit[1])
