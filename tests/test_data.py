"""Data substrate: NER, relations, tokenizer, pipeline determinism."""
import numpy as np

from repro.data import (HashTokenizer, PackedBatches, TextDataset,
                        extract_relations, filter_relations, hospital_corpus,
                        recognize_entities, unhcr_corpus)
from repro.data.filtering import is_forest
from repro.data.ner import build_gazetteer


def test_ner_gazetteer_exact():
    gaz = build_gazetteer(["Cardiology Ward A", "Oncology Center",
                           "Dr House"])
    ents = recognize_entities(
        "What is the history of Cardiology Ward A and Oncology Center?", gaz)
    assert ents == ["Cardiology Ward A", "Oncology Center"]


def test_ner_heuristic_fallback():
    ents = recognize_entities("The Relief Bureau reports to Field Mission.")
    assert "Relief Bureau" in ents and "Field Mission" in ents


def test_relation_patterns():
    text = ("Ward A belongs to Cardiology Dept. "
            "Oncology Center contains Ward B. "
            "Lab One and Lab Two belong to Pathology Dept.")
    ents = ["Ward A", "Ward B", "Cardiology Dept", "Oncology Center",
            "Lab One", "Lab Two", "Pathology Dept"]
    edges = extract_relations(text, entities=ents)
    assert ("Cardiology Dept", "Ward A") in edges
    assert ("Oncology Center", "Ward B") in edges
    assert ("Pathology Dept", "Lab One") in edges       # conjunction
    assert ("Pathology Dept", "Lab Two") in edges


def test_corpus_extraction_recovers_gold():
    c = hospital_corpus(num_trees=12)
    recovered, gold_total = 0, 0
    for doc, gold in zip(c.documents[:6], c.trees[:6]):
        edges = filter_relations(extract_relations(doc, entities=c.entities))
        assert is_forest(edges)
        gold_set = set(gold)
        recovered += sum(1 for e in edges if e in gold_set)
        gold_total += len(gold_set)
    assert recovered / gold_total > 0.8


def test_tokenizer_deterministic_and_bounded():
    tok = HashTokenizer(vocab_size=1000)
    ids = tok.encode("Cardiology Ward A belongs to Hospital.", bos=True,
                     eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert all(0 <= i < 1000 for i in ids)
    assert ids == tok.encode("Cardiology Ward A belongs to Hospital.",
                             bos=True, eos=True)


def test_pipeline_sharding_and_resume():
    c = unhcr_corpus(num_trees=6)
    tok = HashTokenizer(4096)
    ds0 = TextDataset(c.documents, tok, host_id=0, num_hosts=2)
    ds1 = TextDataset(c.documents, tok, host_id=1, num_hosts=2)
    assert not np.array_equal(ds0.epoch_tokens(0)[:64], ds1.epoch_tokens(0)[:64])

    pb = PackedBatches(ds0, batch_size=2, seq_len=64, prefetch=False)
    b1 = pb.next_batch()
    st = pb.checkpoint_state()
    b2 = pb.next_batch()
    pb.restore_state(st)
    b3 = pb.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
