"""Device-side batched lookup semantics vs host filter; temperature path."""
import jax.numpy as jnp
import numpy as np

from repro.core import (CFTDeviceState, build_forest, build_index,
                        bump_temperature, lookup_batch, retrieve_device,
                        sort_buckets)
from repro.core import hashing
from repro.data import hospital_corpus


def _setup(trees=20):
    c = hospital_corpus(num_trees=trees)
    forest = build_forest(c.trees)
    idx = build_index(forest, num_buckets=1024)
    return c, forest, idx


def test_lookup_batch_matches_host():
    _, forest, idx = _setup()
    t = idx.filter.tables()
    names = forest.entity_names[:100] + [f"missing {i}" for i in range(20)]
    hs = hashing.hash_entities(names)
    res = lookup_batch(jnp.asarray(t.fingerprints), jnp.asarray(t.heads),
                       jnp.asarray(hs))
    for i, nm in enumerate(names):
        hit, head = idx.filter.lookup(int(hs[i]), bump=False)
        assert bool(res.hit[i]) == hit, nm
        if hit:
            assert int(res.head[i]) == head, nm


def test_bump_and_sort_device():
    _, forest, idx = _setup(trees=5)
    t = idx.filter.tables()
    fps = jnp.asarray(t.fingerprints)
    temps = jnp.asarray(t.temperature)
    heads = jnp.asarray(t.heads)
    eids = jnp.asarray(t.entity_ids)
    h = jnp.asarray(hashing.hash_entities([forest.entity_names[3]] * 4))
    res = lookup_batch(fps, heads, h)
    temps2 = bump_temperature(temps, res)
    assert int(temps2.sum()) == int(temps.sum()) + 4
    fps2, temps3, heads2, eids2 = sort_buckets(fps, temps2, heads, eids)
    # hot entity now at slot 0 of its bucket; membership preserved
    res2 = lookup_batch(fps2, heads2, h)
    assert bool(res2.hit[0]) and int(res2.slot[0]) == 0
    assert int((fps2 != 0).sum()) == int((fps != 0).sum())


def test_retrieve_device_matches_host_contexts():
    _, forest, idx = _setup(trees=10)
    state = CFTDeviceState.from_index(idx)
    names = forest.entity_names[:32]
    hs = jnp.asarray(hashing.hash_entities(names))
    out = retrieve_device(state, hs, max_locs=6, n=3)
    for i, nm in enumerate(names):
        eid = forest.name_to_id[nm]
        gold_locs = sorted(n for _, n in forest.entity_locations[eid])[:6]
        got = sorted(int(v) for v in np.asarray(out.locations[i]) if v >= 0)
        assert got == gold_locs[:len(got)] and len(got) == min(6, len(gold_locs))
        # ancestors per location must match host walk
        for j, node in enumerate(np.asarray(out.locations[i])):
            if node < 0:
                continue
            up = [int(u) for u in np.asarray(out.up[i, j]) if u >= 0]
            assert up == forest.ancestors(int(node), 3)
            down = [int(dn) for dn in np.asarray(out.down[i, j]) if dn >= 0]
            assert down == forest.descendants(int(node), 3)
