"""Cuckoo filter unit + property tests (paper §3, §4.5 claims)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback (CI installs the real one)
    from _hypothesis_stub import given, settings, st

from repro.core import CuckooFilter, build_forest, build_index
from repro.core import hashing


def _hashes(n, seed=0):
    return hashing.hash_entities([f"entity {seed}_{i}" for i in range(n)])


def test_insert_lookup_basic():
    f = CuckooFilter(num_buckets=64)
    hs = _hashes(100)
    for i, h in enumerate(hs):
        f.insert(int(h), head=i, entity_id=i)
    for i, h in enumerate(hs):
        hit, head = f.lookup(int(h), bump=False)
        assert hit and head == i


def test_delete():
    f = CuckooFilter(num_buckets=64)
    hs = _hashes(50)
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    for h in hs[:25]:
        assert f.delete(int(h))
    for h in hs[:25]:
        assert not f.contains(int(h))       # no false negatives after delete
    for i, h in enumerate(hs[25:], start=25):
        hit, head = f.lookup(int(h), bump=False)
        assert hit and head == i
    assert f.num_items == 25


def test_eviction_chain_under_load():
    """Insertions past bucket conflicts must relocate, not lose items."""
    f = CuckooFilter(num_buckets=16, load_threshold=0.99)
    hs = _hashes(48)                       # 75% of 16*4 slots
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    for i, h in enumerate(hs):
        assert f.contains(int(h)), i


def test_expansion():
    f = CuckooFilter(num_buckets=8, load_threshold=0.9)
    hs = _hashes(200)
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    assert f.num_expansions >= 1
    assert f.num_buckets > 8
    for i, h in enumerate(hs):
        hit, head = f.lookup(int(h), bump=False)
        assert hit and head == i
    assert f.load_factor <= 0.95


def test_false_positive_rate():
    """12-bit fingerprints: fp rate ~ 2 * 4 / 4096 ~ 0.2% (paper: ~0)."""
    f = CuckooFilter(num_buckets=1024)
    for i, h in enumerate(_hashes(3148)):   # paper's entity count
        f.insert(int(h), i, i)
    probes = _hashes(20000, seed=99)
    fp = sum(f.contains(int(h)) for h in probes)
    assert fp / len(probes) < 0.01


def test_temperature_bump_and_sort():
    f = CuckooFilter(num_buckets=32)
    hs = _hashes(60)
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    hot = hs[7]
    for _ in range(5):
        f.lookup(int(hot))
    f.sort_buckets()
    # the hot entity must sit at slot 0 of its bucket
    loc = f._find(np.uint32(hot))
    assert loc is not None and loc[1] == 0
    # sort preserves membership + payloads
    for i, h in enumerate(hs):
        hit, head = f.lookup(int(h), bump=False)
        assert hit and head == i


def test_paper_load_factor_scenario():
    """3148 entities / 1024 buckets x 4 slots = 0.7686 (paper §4.5.1)."""
    forest = build_forest([[(f"root{t}", f"e{t}_{i}") for i in range(7)]
                           for t in range(450)])
    idx = build_index(forest, num_buckets=1024)
    assert idx.filter.num_buckets == 1024   # no expansion needed
    assert 0.5 < idx.filter.load_factor < 0.95


@settings(max_examples=30, deadline=None)
@given(st.lists(st.text(min_size=1, max_size=20), min_size=1, max_size=120,
                unique=True))
def test_property_insert_then_find(names):
    f = CuckooFilter(num_buckets=32)
    hs = hashing.hash_entities(names)
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    for i, h in enumerate(hs):
        assert f.contains(int(h))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.text(min_size=1, max_size=12), min_size=2, max_size=60,
                unique=True),
       st.data())
def test_property_delete_keeps_others(names, data):
    f = CuckooFilter(num_buckets=32)
    hs = hashing.hash_entities(names)
    for i, h in enumerate(hs):
        f.insert(int(h), i, i)
    victim = data.draw(st.integers(0, len(names) - 1))
    f.delete(int(hs[victim]))
    for i, h in enumerate(hs):
        if i != victim:
            assert f.contains(int(h))
