"""Block-linked-list arena vs CSR arena equivalence."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback (CI installs the real one)
    from _hypothesis_stub import given, settings, st

from repro.core import BlockListBuilder, build_csr

addr = st.tuples(st.integers(0, 600), st.integers(0, 5000))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(addr, max_size=17), min_size=1, max_size=20),
       st.integers(1, 7))
def test_arena_csr_equivalence(address_lists, block_cap):
    b = BlockListBuilder(block_cap=block_cap)
    heads = [b.add_entity(a) for a in address_lists]
    arena = b.build()
    csr = build_csr(address_lists)
    for eid, (head, addrs) in enumerate(zip(heads, address_lists)):
        assert arena.walk(head) == [tuple(map(int, a)) for a in addrs]
        assert csr.walk(eid) == [tuple(map(int, a)) for a in addrs]


def test_block_chaining():
    b = BlockListBuilder(block_cap=2)
    head = b.add_entity([(0, 1), (0, 2), (1, 3), (2, 4), (2, 5)])
    arena = b.build()
    assert arena.num_blocks == 3            # ceil(5/2)
    assert arena.walk(head) == [(0, 1), (0, 2), (1, 3), (2, 4), (2, 5)]


def test_empty_entity():
    b = BlockListBuilder()
    head = b.add_entity([])
    assert head == -1
    assert b.build().walk(head) == []
