"""Ragged bucket arena: dense-equivalence property tests (lookup bit-
identity incl. temperature bumps), empty-tree minimum allocation, and
tree-local expansion byte-identity under churn."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # offline container
    from _hypothesis_stub import given, settings, st

from repro.core import (CFTDeviceState, MaintenanceEngine,
                        bump_temperature_arena, bump_temperature_bank,
                        build_bank, build_forest, lookup_batch,
                        lookup_batch_bank, lookup_batch_ragged,
                        retrieve_device)
from repro.core import hashing
from repro.core.bank import EMPTY_TREE_NB
from repro.kernels.cuckoo_lookup import cuckoo_lookup_ragged


def _skewed_forest(rng, num_trees):
    """Random skewed forest: per-tree sizes vary ~25x, empty trees
    allowed, one randomly chosen hot tree blown up further."""
    sizes = rng.integers(0, 14, size=num_trees)
    sizes[int(rng.integers(num_trees))] *= 8
    return build_forest(
        [[(f"r{t}", f"e{t}_{i}") for i in range(int(sizes[t]))]
         for t in range(num_trees)])


def _query_batch(bank, hashes, rng, misses=24):
    tid = np.concatenate([
        bank.row_tree,
        rng.integers(0, bank.num_trees, size=misses)]).astype(np.int32)
    hh = np.concatenate([
        hashes[bank.row_entity] if bank.num_rows else
        np.zeros(0, np.uint32),
        rng.integers(1, 2 ** 32, size=misses).astype(np.uint32)])
    return tid, hh


def _ragged_args(bank):
    return (jnp.asarray(bank.fingerprints), jnp.asarray(bank.heads),
            jnp.asarray(bank.bucket_offsets.astype(np.int32)),
            jnp.asarray(bank.tree_nb))


# --------------------------------------------------- dense equivalence

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_ragged_bit_identical_to_dense_equivalent(seed):
    """A forced-uniform build is the dense-equivalent bank: its arena
    reshapes to the old (T, NB, S) layout, and the ragged routed lookup
    must answer bit-identically to the dense reference on every field —
    hit/miss, head, bucket, slot — and produce identical temperature
    bumps."""
    rng = np.random.default_rng(seed)
    forest = _skewed_forest(rng, int(rng.integers(3, 10)))
    bank = build_bank(forest, num_buckets=64)        # uniform forced
    assert bank.num_buckets == 64                    # stayed uniform
    hashes = hashing.hash_entities(forest.entity_names)
    tid, hh = _query_batch(bank, hashes, rng)
    tid_j, hh_j = jnp.asarray(tid), jnp.asarray(hh)

    df, dt, dh = bank.dense_tables()
    ref = lookup_batch_bank(jnp.asarray(df), jnp.asarray(dh), tid_j, hh_j)
    got = lookup_batch_ragged(*_ragged_args(bank), tid_j, hh_j)
    for f in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f"dense-equivalence {f}")

    # temperature bumps land on the same slots through both layouts
    temp_d = bump_temperature_bank(jnp.asarray(dt), tid_j, ref)
    row_off = jnp.asarray(bank.bucket_offsets.astype(np.int32))[tid_j]
    temp_r = bump_temperature_arena(jnp.asarray(bank.temperature),
                                    row_off, got)
    np.testing.assert_array_equal(
        np.asarray(temp_d).reshape(np.asarray(temp_r).shape),
        np.asarray(temp_r))


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_ragged_lookup_matches_per_tree_standalone(seed):
    """On a naturally ragged build, routing a query through the arena is
    bit-identical to probing that tree's standalone (nb_t, S) filter
    slice — host path, pure-jnp path and the Pallas kernel agree."""
    rng = np.random.default_rng(seed)
    forest = _skewed_forest(rng, int(rng.integers(3, 10)))
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid, hh = _query_batch(bank, hashes, rng)
    tid_j, hh_j = jnp.asarray(tid), jnp.asarray(hh)

    got = lookup_batch_ragged(*_ragged_args(bank), tid_j, hh_j)
    ker = cuckoo_lookup_ragged(*_ragged_args(bank), tid_j, hh_j,
                               interpret=True)
    m = np.asarray(got.hit)
    np.testing.assert_array_equal(m, np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(got.head),
                                  np.asarray(ker.head))
    for f in ("bucket", "slot"):                     # defined on hits
        np.testing.assert_array_equal(np.asarray(getattr(got, f))[m],
                                      np.asarray(getattr(ker, f))[m])

    for t in range(bank.num_trees):                  # standalone slices
        sel = tid == t
        if not sel.any():
            continue
        lo, hi = bank.segment(t)
        ref = lookup_batch(jnp.asarray(bank.fingerprints[lo:hi]),
                           jnp.asarray(bank.heads[lo:hi]),
                           jnp.asarray(hh[sel]))
        for f in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)),
                np.asarray(getattr(got, f))[sel],
                err_msg=f"standalone tree {t} {f}")
    # host reference agrees everywhere
    for i in range(tid.shape[0]):
        hit, row, _ = bank.lookup(int(tid[i]), int(hh[i]))
        assert bool(m[i]) == hit
        if hit:
            assert int(np.asarray(got.head)[i]) == row


# ------------------------------------------------ tree-local expansion

@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_churn_expand_leaves_other_segments_byte_identical(seed):
    """A churn run that overflows one hot tree (queued inserts + a forced
    expand): every other tree's arena segment stays byte-identical across
    all five tables, CSR row ids survive (no renumbering), and every live
    row still answers."""
    rng = np.random.default_rng(seed)
    forest = _skewed_forest(rng, 6)
    bank = build_bank(forest)
    eng = MaintenanceEngine(bank, seed=seed & 0xFFFF)
    hashes = hashing.hash_entities(forest.entity_names)
    hot = int(np.argmax(bank.num_items))
    cold = [t for t in range(bank.num_trees) if t != hot]

    def seg_bytes(t):
        lo, hi = bank.segment(t)
        return tuple(arr[lo:hi].tobytes() for arr in
                     (bank.fingerprints, bank.temperature, bank.heads,
                      bank.entity_ids, bank.stored_hash))

    # churn the hot tree past its load threshold
    cap = int(bank.tree_nb[hot]) * bank.slots
    extra = cap - int(bank.num_items[hot]) + 4
    for i in range(extra):
        eng.queue_insert(hot, f"stuffing {seed}_{i}", [i])
    snaps = {t: seg_bytes(t) for t in cold}
    nb0 = bank.tree_nb.copy()
    rows0 = {r: bank.walk_row(r) for r in range(bank.num_rows)}
    eng.maintain()
    assert eng.stats["expansions"] >= 1
    assert bank.tree_nb[hot] > nb0[hot]
    eng.expand_tree(hot, force=True)                 # and once more
    for t in cold:
        assert bank.tree_nb[t] == nb0[t]
        assert seg_bytes(t) == snaps[t], f"cold segment {t} mutated"
    # CSR rows kept their ids and node lists (tree-local expand never
    # renumbers), and every pre-existing row still resolves
    for r, nodes in rows0.items():
        assert bank.walk_row(r) == nodes
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        hit, row, _ = bank.lookup(t, int(hashes[e]))
        assert hit and row == r
    for i in range(extra):
        h = int(hashing.entity_hash(f"stuffing {seed}_{i}"))
        hit, row, _ = bank.lookup(hot, h)
        assert hit and bank.walk_row(row) == [i]


# ------------------------------------------------- empty-tree allocation

def test_empty_tree_gets_minimum_buckets():
    """Regression: a tree with zero entities used to inherit the shared
    bank-wide NB (the hot tree's bucket count); the ragged builder must
    allocate it the minimum instead."""
    trees = [[("r0", "e0_a"), ("r0", "e0_b")],
             [],                                     # empty tree
             [(f"r2", f"e2_{i}") for i in range(60)]]
    forest = build_forest(trees)
    bank = build_bank(forest)
    assert int(bank.tree_nb[1]) == EMPTY_TREE_NB
    assert int(bank.tree_nb[1]) < int(bank.tree_nb[0]) \
        < int(bank.tree_nb[2])
    assert bank.total_buckets == int(bank.tree_nb.sum())
    # the empty tree answers misses on host + device
    h = int(hashing.entity_hash("e2_0"))
    assert not bank.contains(1, h)
    state = CFTDeviceState.from_bank(bank, forest)
    out = retrieve_device(state, jnp.asarray(np.asarray([h], np.uint32)),
                          jnp.asarray(np.asarray([1], np.int32)))
    assert not bool(out.hit[0])
    # and it can still grow: a late insert expands it tree-locally
    eng = MaintenanceEngine(bank)
    for i in range(9):
        eng.queue_insert(1, f"late {i}", [i])
    eng.maintain()
    assert int(bank.tree_nb[1]) > EMPTY_TREE_NB
    assert int(bank.tree_nb[2]) == 32               # hot tree untouched
    for i in range(9):
        assert bank.locate(1, f"late {i}") == [i]


def test_skewed_forest_arena_bytes_beat_dense():
    """The memory claim at test scale: one 16x tree among 64 — arena rows
    are a small fraction of the dense pad-to-max rows."""
    sizes = [8 * 16 if t == 0 else 8 for t in range(64)]
    forest = build_forest(
        [[(f"r{t}", f"e{t}_{i}") for i in range(sizes[t])]
         for t in range(64)])
    bank = build_bank(forest)
    dense_rows = 64 * int(bank.tree_nb.max())
    assert bank.total_buckets < 0.5 * dense_rows
    # every row still resolves through the packed arena
    hashes = hashing.hash_entities(forest.entity_names)
    rows_i, _ = bank.find_exact(bank.row_tree.astype(np.int64),
                                hashes[bank.row_entity])
    assert (rows_i >= 0).all()
