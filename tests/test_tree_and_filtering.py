"""Entity forest construction + relationship filtering properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback (CI installs the real one)
    from _hypothesis_stub import given, settings, st

from repro.core import build_forest
from repro.data.filtering import filter_relations, is_forest

name = st.text(alphabet="abcdef", min_size=1, max_size=3)
edge = st.tuples(name, name)


def test_forest_basic():
    f = build_forest([[("a", "b"), ("a", "c"), ("b", "d")]])
    assert f.num_nodes == 4
    na = f.name_to_id["a"]
    nd = f.name_to_id["d"]
    d_node = [g for g in range(4) if f.entity_id[g] == nd][0]
    assert f.ancestors(d_node, 3) == [f.name_to_id["b"], na]
    a_node = [g for g in range(4) if f.entity_id[g] == na][0]
    assert set(f.descendants(a_node, 3)) == {f.name_to_id["b"],
                                             f.name_to_id["c"],
                                             f.name_to_id["d"]}


def test_forest_cycle_guard():
    """Adversarial edges must never detach nodes from the roots."""
    f = build_forest([[("a", "b"), ("b", "c"), ("c", "a")]])   # cycle edge
    reachable = set()
    stack = list(f.roots)
    while stack:
        g = stack.pop()
        reachable.add(g)
        stack.extend(int(c) for c in f.children(g))
    assert reachable == set(range(f.num_nodes))


def test_filter_rules():
    edges = [("a", "a"),                  # self loop
             ("a", "b"), ("a", "b"),      # duplicate
             ("b", "c"), ("c", "a"),      # cycle back-edge
             ("a", "c")]                  # transitive (a->b->c exists)
    out = filter_relations(edges)
    assert ("a", "a") not in out
    assert out.count(("a", "b")) == 1
    assert ("c", "a") not in out
    assert ("a", "c") not in out
    assert is_forest(out)


@settings(max_examples=60, deadline=None)
@given(st.lists(edge, max_size=40))
def test_property_filter_yields_forest(edges):
    out = filter_relations(edges)
    assert is_forest(out)
    # no edge is invented
    assert set(out) <= set(edges)


@settings(max_examples=40, deadline=None)
@given(st.lists(edge, max_size=30))
def test_property_forest_build_total(edges):
    """build_forest never crashes and preserves reachability from roots."""
    f = build_forest([list(edges)])
    reachable = set()
    stack = list(f.roots)
    while stack:
        g = stack.pop()
        reachable.add(g)
        stack.extend(int(c) for c in f.children(g))
    assert reachable == set(range(f.num_nodes))
