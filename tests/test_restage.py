"""Double-buffered restage: PendingRestage classification, splice-commit
byte-identity against a from-scratch restage across random churn schedules
(insert/delete/expand/shrink), the shrink policy, and the serving-layer
prepare/commit lifecycle."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # offline container
    from _hypothesis_stub import given, settings, st

from repro.core import (CFTDeviceState, MaintenanceEngine, build_bank,
                        build_forest, commit_restage, retrieve_device)
from repro.core import hashing

_STATE_FIELDS = ("fingerprints", "temperature", "heads", "bucket_offsets",
                 "tree_nb", "csr_offsets", "csr_nodes")


def _forest(num_trees=6, entities_per_tree=12):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _assert_state_equal(state, ref, tag=""):
    for f in _STATE_FIELDS:
        a, b = np.asarray(getattr(state, f)), np.asarray(getattr(ref, f))
        assert a.shape == b.shape, (tag, f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f"{tag}: {f}")


def _setup(**kw):
    forest = _forest()
    bank = build_bank(forest)
    eng = MaintenanceEngine(bank, **kw)
    state = CFTDeviceState.from_bank(bank, forest)
    eng.mark_staged()
    return forest, bank, eng, state


# ------------------------------------------------------- classification

def test_plan_kinds():
    """Each cycle shape classifies to the cheapest plan that can express
    it: nothing -> none, slot edits -> delta, one tree resized -> segment,
    compaction -> full."""
    forest, bank, eng, state = _setup()
    assert eng.plan_restage().kind == "none"

    eng.queue_insert(1, "fresh", [2, 3])
    eng.maintain()
    plan = eng.plan_restage()
    assert plan.kind == "delta" and plan.changed_rows > 0
    assert plan.csr_offsets is not None          # the insert appended a row
    state = commit_restage(state, plan, eng, forest)
    _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                        "delta")

    eng.expand_tree(2, force=True)
    plan = eng.plan_restage()
    assert plan.kind == "segment" and plan.seg_tree == 2
    state = commit_restage(state, plan, eng, forest)
    _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                        "segment")

    # two trees resized in one cycle cannot splice -> full
    eng.expand_tree(0, force=True)
    eng.expand_tree(4, force=True)
    plan = eng.plan_restage()
    assert plan.kind == "full"
    state = commit_restage(state, plan, eng, forest)
    _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                        "multi-segment full")

    # compaction renumbers CSR rows -> full
    hashes = hashing.hash_entities(forest.entity_names)
    for r in range(0, bank.num_rows, 2):
        eng.queue_delete(int(bank.row_tree[r]),
                         int(hashes[int(bank.row_entity[r])]))
    rep = eng.maintain()                  # enough dead rows: auto-compacts
    assert rep.compacted or eng.compact()
    plan = eng.plan_restage()
    assert plan.kind == "full"
    state = commit_restage(state, plan, eng, forest)
    _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                        "compaction full")


def test_absorbed_temperature_not_restaged():
    """Temperature the engine absorbed is already on device: an
    absorb-only cycle plans to none, and a later delta does not re-stage
    the bumped rows."""
    forest, bank, eng, state = _setup()
    hashes = hashing.hash_entities(forest.entity_names)
    tid = jnp.asarray(bank.row_tree[:16].astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity[:16]])
    out = retrieve_device(state, hh, tid)
    state = state.with_temperature(out.temperature)
    assert eng.absorb(state) == 16
    plan = eng.plan_restage()
    assert plan.kind == "none"                  # device already has them
    eng.queue_insert(0, "one more", [1])
    eng.maintain()
    plan = eng.plan_restage()
    assert plan.kind == "delta"
    # only the inserted slot's row (plus eviction traffic in tree 0's
    # segment) stages — far fewer rows than the 16 bumped ones
    lo, hi = bank.segment(0)
    rows = np.asarray(plan.rows)[:plan.changed_rows]
    assert ((rows >= lo) & (rows < hi)).all()
    state = commit_restage(state, plan, eng, forest)
    _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                        "post-absorb delta")


def test_bump_between_plan_and_commit_max_merges():
    """A temperature bump that lands while a plan is staged (serving
    continues on the old state through the prepare window) survives the
    commit wherever the plan left the slot's key in place — and never
    leaks onto a slot whose key the plan moved or cleared."""
    forest, bank, eng, state = _setup()
    eng.queue_delete(0, "entity 0_5")
    eng.queue_insert(0, "one more", [1])
    eng.maintain()
    plan = eng.plan_restage()
    assert plan.kind == "delta"
    k = plan.changed_rows
    rows = np.asarray(plan.rows)[:k]
    vt = np.asarray(plan.val_temp)[:k]
    vf = np.asarray(plan.val_fps)[:k]
    vk = np.asarray(plan.val_keep)[:k]
    # a staged slot whose key the plan did not move: its stored hash lets
    # us aim a query (and so a device-side bump) exactly at it
    cand = np.argwhere(vk & (vf != hashing.EMPTY_FP))
    assert cand.size, "delta left no key in place"
    i, s = cand[0]
    r = int(rows[i])
    kept_hash = np.uint32(bank.stored_hash[r, s])
    # the deleted key is still live on the old device state — querying it
    # bumps its (soon to be cleared) slot
    del_hash = hashing.hash_entities(["entity 0_5"])[0]
    out = retrieve_device(state, jnp.asarray([kept_hash, del_hash]),
                          jnp.zeros(2, jnp.int32))
    state = state.with_temperature(out.temperature)    # bumped, NOT absorbed
    assert np.asarray(state.temperature)[r, s] == vt[i, s] + 1
    state = commit_restage(state, plan, eng, forest)
    t = np.asarray(state.temperature)
    # kept slot: the in-flight bump max-merges into the staged row
    assert t[r, s] == vt[i, s] + 1
    # moved/cleared slots: staged value wins — the deleted key's bump
    # must not leak onto its cleared slot (or any successor key)
    assert (t[rows][~vk] == vt[~vk]).all()
    # the bank never saw the bump; a post-commit absorb reconciles and
    # the next plan has nothing to restage
    assert eng.absorb(state) >= 1
    assert int(bank.temperature[r, s]) == int(t[r, s])
    assert eng.plan_restage().kind == "none"


# ------------------------------------------------------------ shrink path

def test_shrink_tree_reverses_expansion():
    """shrink_tree halves an overprovisioned tree's segment through the
    same splice machinery: other segments byte-identical, memberships and
    temperatures preserved, CSR rows never renumbered."""
    forest, bank, eng, state = _setup()
    hashes = hashing.hash_entities(forest.entity_names)
    bank.temperature[bank.fingerprints != hashing.EMPTY_FP] = 5
    eng.expand_tree(3, force=True)
    eng.expand_tree(3, force=True)              # 4x overprovisioned now
    nb_big = int(bank.tree_nb[3])
    cold = [t for t in range(bank.num_trees) if t != 3]
    snaps = {t: tuple(arr[slice(*bank.segment(t))].tobytes()
                      for arr in (bank.fingerprints, bank.heads,
                                  bank.stored_hash))
             for t in cold}
    rows0 = {r: bank.walk_row(r) for r in range(bank.num_rows)}
    assert eng.shrink_tree(3, force=True)
    assert int(bank.tree_nb[3]) < nb_big
    assert eng.stats["shrinks"] == 1
    for t in cold:
        cur = tuple(arr[slice(*bank.segment(t))].tobytes()
                    for arr in (bank.fingerprints, bank.heads,
                                bank.stored_hash))
        assert cur == snaps[t], f"cold segment {t} mutated"
    for r, nodes in rows0.items():
        assert bank.walk_row(r) == nodes
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        hit, row, _ = bank.lookup(t, int(hashes[e]))
        assert hit and row == r
    assert (bank.temperature[bank.fingerprints
                             != hashing.EMPTY_FP] == 5).all()


def test_shrink_policy_and_packing_stats():
    """maintain() auto-shrinks at most one cold tree per pass when
    shrink_load is set; packing_stats reports the overprovision it acts
    on.  Without shrink_load the engine never shrinks on its own."""
    forest, bank, eng, state = _setup()
    eng.expand_tree(1, force=True)
    eng.expand_tree(1, force=True)
    assert eng.maintain().shrinks == 0           # policy off by default
    stats = eng.packing_stats()
    assert stats["overprovision"] > 1.0
    assert int(stats["tree_nb"][1]) > int(stats["ideal_nb"][1])

    forest2, bank2, eng2, _ = _setup(shrink_load=0.5)
    eng2.expand_tree(1, force=True)
    eng2.expand_tree(2, force=True)
    rep = eng2.maintain()
    assert rep.shrinks == 1                      # one per idle window
    rep = eng2.maintain()
    assert rep.shrinks == 1                      # the other one next pass
    over = eng2.packing_stats()["overprovision"]
    assert over <= eng.packing_stats()["overprovision"]
    # a loaded tree never shrinks below what its items need
    assert (bank2.tree_nb >= eng2.packing_stats()["ideal_nb"]).all()


# ------------------------------------------------- churn property test

@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_splice_commit_matches_from_scratch_restage(seed):
    """Acceptance gate (replicated): across a random schedule of
    insert/delete/expand/shrink cycles, every plan+commit leaves the
    device state byte-identical to a from-scratch
    ``CFTDeviceState.from_bank`` of the mutated bank — all tables, all
    geometry, the CSR arena."""
    rng = np.random.default_rng(seed)
    forest = _forest(num_trees=5, entities_per_tree=10)
    bank = build_bank(forest)
    eng = MaintenanceEngine(bank, seed=seed & 0xFFFF, shrink_load=0.3)
    state = CFTDeviceState.from_bank(bank, forest)
    eng.mark_staged()
    hashes = hashing.hash_entities(forest.entity_names)
    live = {(int(bank.row_tree[r]), int(bank.row_entity[r]))
            for r in range(bank.num_rows)}
    serial = 0
    for cycle in range(5):
        for _ in range(int(rng.integers(1, 6))):
            op = rng.random()
            tree = int(rng.integers(bank.num_trees))
            if op < 0.5:
                eng.queue_insert(tree, f"new {seed} {serial}",
                                 [int(rng.integers(forest.num_nodes))])
                serial += 1
            elif live:
                t, e = sorted(live)[int(rng.integers(len(live)))]
                eng.queue_delete(t, int(hashes[e]))
                live.discard((t, e))
        eng.maintain()
        if rng.random() < 0.4:
            eng.expand_tree(int(rng.integers(bank.num_trees)), force=True)
        if rng.random() < 0.4:
            eng.shrink_tree(int(rng.integers(bank.num_trees)), force=True)
        plan = eng.plan_restage()
        state = commit_restage(state, plan, eng, forest)
        _assert_state_equal(state, CFTDeviceState.from_bank(bank, forest),
                            f"seed {seed} cycle {cycle} ({plan.kind})")
        # and the committed state actually serves: a live row resolves
        if bank.num_rows:
            r = int(rng.integers(bank.num_rows))
            if bool(eng.row_alive[r]):
                out = retrieve_device(
                    state,
                    jnp.asarray(np.asarray([eng.row_hash[r]], np.uint32)),
                    jnp.asarray(np.asarray([bank.row_tree[r]], np.int32)))
                assert bool(out.hit[0])
                state = state.with_temperature(out.temperature)
                eng.absorb(state)


# ------------------------------------------------- serving integration

def test_pipeline_prepare_commit_lifecycle():
    """RAGPipeline two-phase maintenance: prepare stages the plan while
    the old state keeps serving (absorb deferred), commit swaps in the
    spliced state, and the answer paths see the mutation."""
    from repro.data import HashTokenizer, hospital_corpus
    from repro.serving import RAGPipeline
    corpus = hospital_corpus(num_trees=6, num_queries=2)
    rag = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024),
                      use_bank=True)
    node = int(rag.forest.child_index[0])
    rag.insert_entity(2, "Brand New Clinic", [node])
    rep = rag.prepare_maintenance()
    assert rep.inserted == 1 and rag._coord.deferring
    assert rag._coord.pending.kind in ("delta", "segment")
    # serving on the pre-commit state still works (and defers absorb)
    ans = rag.retrieve(f"Tell me about {rag.forest.entity_names[0]}")
    assert ans.context
    assert rag.commit_maintenance()
    assert not rag._coord.deferring
    ans = rag.retrieve("Describe the Brand New Clinic please")
    assert "Brand New Clinic" in ans.entities
    assert "hierarchical relationship of Brand New Clinic" in ans.context
    # the wrapper still works end to end
    rag.delete_entity(2, "Brand New Clinic")
    rep = rag.maintain()
    assert rep.deleted == 1 and not rag._coord.deferring
