"""Distribution tests — run in SUBPROCESSES with XLA host-device counts so
the main pytest process keeps its single default device (dry-run rule:
never set the flag globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=520)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_filter_lookup():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import build_forest, build_index, lookup_batch
    from repro.core import hashing
    from repro.core.distributed import shard_filter_tables, sharded_lookup
    from repro.data import hospital_corpus

    c = hospital_corpus(num_trees=15)
    forest = build_forest(c.trees)
    idx = build_index(forest, num_buckets=256)
    t = idx.filter.tables()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    fps, heads = shard_filter_tables(mesh, "model",
                                     jnp.asarray(t.fingerprints),
                                     jnp.asarray(t.heads))
    names = forest.entity_names[:64] + ["missing A", "missing B"]
    h = jnp.asarray(hashing.hash_entities(names))
    ref = lookup_batch(jnp.asarray(t.fingerprints), jnp.asarray(t.heads), h)
    got = sharded_lookup(mesh, "model", fps, heads, h)
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(got.hit))
    np.testing.assert_array_equal(np.asarray(ref.head), np.asarray(got.head))
    print("sharded lookup OK")
    """)


def test_small_mesh_train_step_sharded():
    """Sharded train step == single-device train step (tiny dense model)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch
    from repro.models import init_params, runtime
    from repro.training import AdamWConfig, adamw_init, make_train_step
    from repro.launch import sharding as sh

    # capacity_factor high enough that no tokens drop: per-shard capacity
    # (sharded path) and global capacity (local path) then agree exactly
    cfg = get_arch("granite-moe-1b-a400m").smoke().replace(
        d_model=128, num_experts=4, top_k=2, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (8, 32), 4, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
             "mask": jnp.ones((8, 32), jnp.float32)}
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)

    p1, _, m1 = make_train_step(cfg, ocfg)(params, adamw_init(params), batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    runtime.set_mesh(mesh, ("data",))
    params_sh = sh.params_shardings(mesh, jax.eval_shape(lambda: params))
    opt_abs = jax.eval_shape(adamw_init, params)
    opt_sh = sh.opt_shardings(mesh, opt_abs, params_sh)
    bs = jax.tree.map(lambda t: NamedSharding(
        mesh, P("data", *(None,) * (t.ndim - 1))), batch)
    step = make_train_step(cfg, ocfg, param_shardings=params_sh,
                           data_axes=("data",))
    with mesh:
        fn = jax.jit(step, in_shardings=(params_sh, opt_sh, bs),
                     out_shardings=(params_sh, opt_sh, None))
        p2, _, m2 = fn(jax.device_put(params, params_sh),
                       jax.device_put(adamw_init(params), opt_sh),
                       jax.device_put(batch, bs))
    runtime.clear_mesh()
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-2, rtol=3e-2)
    print("sharded train step OK")
    """)


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (2,4) mesh, restore onto (4,2) — elastic re-shard."""
    _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models import init_params
    from repro.training import adamw_init, restore, save
    from repro.launch import sharding as sh

    cfg = get_arch("qwen2-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    sh_a = sh.params_shardings(mesh_a, jax.eval_shape(lambda: params))
    sh_b = sh.params_shardings(mesh_b, jax.eval_shape(lambda: params))
    placed = jax.device_put(params, sh_a)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"params": placed})
        got, step, _ = restore(d, {"params": params},
                               shardings={"params": sh_b})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("elastic restore OK")
    """)


def test_moe_small_batch_token_routing():
    """Decode-scale MoE: token-routed path == local path (weights resident)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models import moe as M

    cfg = get_arch("granite-moe-1b-a400m").smoke().replace(
        d_model=64, num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
        shared_expert=True)
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64), jnp.float32)
    y_local = M._moe_apply_local(cfg, p, x)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        y_small = M._moe_small_batch(cfg, p, x, mesh, ("data",), "model", 2)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_small),
                               atol=2e-5, rtol=2e-5)
    print("token-routed MoE OK")
    """)


def test_mini_dryrun_multi_pod_mesh():
    """A miniature multi-pod mesh (2,2,2) lower+compile for a smoke arch —
    proves the pod axis shards end to end without the 512-device cost."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_arch, SHAPES
    from repro.launch import sharding as sh, specs
    from repro.models import lm, runtime
    from repro.training.grad import make_train_step
    from repro.training.optimizer import AdamWConfig, adamw_init
    import dataclasses

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    runtime.set_mesh(mesh, ("pod", "data"))
    cfg = get_arch("qwen2-0.5b").smoke()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                global_batch=8)
    params_abs = specs.params_specs(cfg)
    params_sh = sh.params_shardings(mesh, params_abs)
    with mesh:
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_sh = sh.opt_shardings(mesh, opt_abs, params_sh)
        batch_abs = specs.train_batch_specs(cfg, shape)
        batch_sh = sh.batch_shardings(mesh, cfg, shape, batch_abs)
        step = make_train_step(cfg, AdamWConfig(), microbatches=2,
                               param_shardings=params_sh,
                               data_axes=("pod", "data"))
        c = jax.jit(step, in_shardings=(params_sh, opt_sh, batch_sh),
                    out_shardings=(params_sh, opt_sh, None)
                    ).lower(params_abs, opt_abs, batch_abs).compile()
    assert c.memory_analysis() is not None
    print("mini multi-pod dryrun OK")
    """, devices=8)
