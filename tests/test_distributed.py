"""Distribution tests — run in SUBPROCESSES with XLA host-device counts so
the main pytest process keeps its single default device (dry-run rule:
never set the flag globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a fresh interpreter + an 8-device host mesh —
# the expensive tier CI runs as its own job (see .github/workflows/ci.yml)
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=520)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_filter_lookup():
    """Legacy bucket-striped single filter — now a wrapper over the
    bank-axis all-to-all router; bit-identical to lookup_batch."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import build_forest, build_index, lookup_batch
    from repro.core import hashing
    from repro.core.distributed import shard_filter_tables, sharded_lookup
    from repro.data import hospital_corpus

    c = hospital_corpus(num_trees=15)
    forest = build_forest(c.trees)
    idx = build_index(forest, num_buckets=256)
    t = idx.filter.tables()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    fps, heads = shard_filter_tables(mesh, "model",
                                     jnp.asarray(t.fingerprints),
                                     jnp.asarray(t.heads))
    names = forest.entity_names[:64] + ["missing A", "missing B"]
    h = jnp.asarray(hashing.hash_entities(names))
    ref = lookup_batch(jnp.asarray(t.fingerprints), jnp.asarray(t.heads), h)
    got = sharded_lookup(mesh, "model", fps, heads, h)
    for f in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f)
    print("sharded lookup OK")
    """)


def test_bank_axis_sharded_lookup_equivalence():
    """Bank-axis sharding: all-to-all routed lookup is bit-identical to
    lookup_batch_ragged on the merged replicated arena — queries hitting
    trees on every shard, a ragged batch size, and an all-miss batch."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (build_forest, build_bank, lookup_batch_ragged,
                            sharded_lookup_bank, stage_sharded_bank)
    from repro.core import hashing

    T, D = 32, 8
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(4 + (t % 5) * 3)]
             for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    mesh = jax.make_mesh((D,), ("model",))
    state = stage_sharded_bank(sbank, forest, mesh, "model")
    mf, _, mh = sbank.merged_tables()
    moff, mnb = sbank.merged_layout()
    moff_j = jnp.asarray(moff.astype(np.int32))
    mnb_j = jnp.asarray(mnb)

    def check(qt, qh):
        ref = lookup_batch_ragged(jnp.asarray(mf), jnp.asarray(mh),
                                  moff_j, mnb_j,
                                  jnp.asarray(qt), jnp.asarray(qh))
        got = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh))
        for f in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                          np.asarray(getattr(got, f)),
                                          err_msg=f)
        return ref, got

    # hits on every shard + interleaved misses; B=113 not divisible by D
    rng = np.random.default_rng(0)
    qt = [t for t in range(T) for _ in range(3)] + \
         [int(rng.integers(T)) for _ in range(17)]
    qh = [int(hashing.entity_hash(f"e{t}_{k}"))
          for t in range(T) for k in (0, 1, 2)] + \
         [int(rng.integers(1, 2 ** 32)) for _ in range(17)]
    qt, qh = np.asarray(qt, np.int32), np.asarray(qh, np.uint32)
    ref, got = check(qt, qh)
    hit = np.asarray(got.hit)
    assert hit[:3 * T].all(), "every stored entity must hit"
    owners = sbank.tree_shard_map()[qt[hit]]
    assert set(owners.tolist()) == set(range(D)), "hits on every shard"

    # semantic equivalence vs the original unsharded bank: same hits,
    # identical node lists through the merged row numbering
    ref0 = lookup_batch_ragged(
        jnp.asarray(bank.fingerprints), jnp.asarray(bank.heads),
        jnp.asarray(bank.bucket_offsets.astype(np.int32)),
        jnp.asarray(bank.tree_nb), jnp.asarray(qt), jnp.asarray(qh))
    np.testing.assert_array_equal(np.asarray(ref0.hit), hit)
    gh, rh = np.asarray(got.head), np.asarray(ref0.head)
    for j in np.flatnonzero(hit):
        assert sbank.walk_row(int(gh[j])) == bank.walk_row(int(rh[j]))

    # all-miss batch
    qt_m = np.arange(24, dtype=np.int32) % T
    qh_m = np.asarray([int(hashing.entity_hash(f"missing {j}"))
                       for j in range(24)], np.uint32)
    _, got_m = check(qt_m, qh_m)
    assert not np.asarray(got_m.hit).any()

    # the row-tiled Pallas arena kernel as the shard-local probe;
    # bucket/slot compare on hits only — on a miss the kernel reports the
    # last probed position, the jnp reference reports (i1, 0) (both are
    # dont-cares: head is NULL and the hit-masked temperature add is 0)
    from repro.kernels.cuckoo_lookup.ops import cuckoo_lookup_arena_auto
    got_k = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh),
                                lookup_fn=cuckoo_lookup_arena_auto)
    np.testing.assert_array_equal(hit, np.asarray(got_k.hit))
    np.testing.assert_array_equal(gh, np.asarray(got_k.head))
    for f in ("bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f))[hit],
                                      np.asarray(getattr(got_k, f))[hit],
                                      err_msg=f"kernel probe {f}")
    print("bank-axis sharded lookup equivalence OK")
    """)


def test_bank_sharded_memory_fraction():
    """Acceptance: at T=256 on an 8-device mesh each device holds exactly
    1/8 of the replicated per-device filter-table bytes (sharding
    inspection on every table)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (build_forest, build_bank, sharded_lookup_bank,
                            stage_sharded_bank)
    from repro.core import hashing

    T, D = 256, 8
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(6)] for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    mesh = jax.make_mesh((D,), ("model",))
    state = stage_sharded_bank(sbank, forest, mesh, "model")
    for arr in (state.fingerprints, state.temperature, state.heads):
        replicated = bank.total_buckets * bank.slots * arr.dtype.itemsize
        shards = list(arr.addressable_shards)
        assert len(shards) == D
        per_dev = {s.data.nbytes for s in shards}
        assert len(per_dev) == 1, "unbalanced shards"
        assert per_dev.pop() * D <= replicated, (arr.shape, replicated)
    # and the sharded state still answers: one hit per tree
    qt = np.arange(T, dtype=np.int32)
    qh = np.asarray([int(hashing.entity_hash(f"e{t}_0")) for t in range(T)],
                    np.uint32)
    got = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh))
    assert bool(np.asarray(got.hit).all())
    print("sharded memory fraction OK")
    """)


def test_sharded_maintenance_shard_local_churn():
    """Insert/delete/expand on one hot tree: non-owning shards'
    tables stay byte-identical, expand restages only the hot tree's
    arena segment (even the owner's other trees keep their bytes), and
    the maintained sharded bank answers identically to a from-scratch
    sharded build — including the heterogeneous per-tree-nb device
    lookup after the expansion."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (build_forest, build_bank, build_bank_from_rows,
                            lookup_batch_ragged, ShardedMaintenanceEngine,
                            sharded_lookup_bank, stage_sharded_bank)
    from repro.core import hashing

    T, D = 16, 4
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(12)] for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    eng = ShardedMaintenanceEngine(sbank)
    mesh = jax.make_mesh((D,), ("model",))
    TABLES = ("fingerprints", "temperature", "heads", "entity_ids",
              "stored_hash")

    hot = 9
    owner, hot_lt = sbank.owner(hot)
    others = [d for d in range(D) if d != owner]
    snap = {d: tuple(getattr(sbank.banks[d], f).tobytes() for f in TABLES)
            for d in others}
    nb_before = [b.tree_nb.copy() for b in sbank.banks]

    node_pool = sorted(sbank.banks[owner].walk_row(0))
    eng.queue_delete(hot, f"e{hot}_0")
    eng.queue_delete(hot, f"e{hot}_1")
    for k in range(3):
        eng.queue_insert(hot, f"new {hot}_{k}", node_pool[:2])
    rep = eng.maintain()
    assert rep.inserted == 3 and rep.deleted == 2, rep
    ob = sbank.banks[owner]
    cold_snap = {lt: tuple(
        arr[int(ob.bucket_offsets[lt]):int(ob.bucket_offsets[lt + 1])]
        .tobytes() for arr in (ob.fingerprints, ob.heads, ob.stored_hash))
        for lt in range(ob.num_trees) if lt != hot_lt}
    nb_mid = int(ob.tree_nb[hot_lt])
    assert eng.expand_tree(hot, force=True)
    assert int(ob.tree_nb[hot_lt]) == 2 * nb_mid
    # ... and within the owner, only the hot tree's segment changed
    assert (np.delete(ob.tree_nb, hot_lt)
            == np.delete(nb_before[owner], hot_lt)).all()
    for lt, s in cold_snap.items():
        cur = tuple(
            arr[int(ob.bucket_offsets[lt]):int(ob.bucket_offsets[lt + 1])]
            .tobytes() for arr in (ob.fingerprints, ob.heads,
                                   ob.stored_hash))
        assert cur == s, f"cold tree {lt} of the owner mutated"

    # expand + churn touched ONLY the owner: everyone else byte-equal
    for d in others:
        cur = tuple(getattr(sbank.banks[d], f).tobytes() for f in TABLES)
        assert cur == snap[d], f"non-owning shard {d} mutated"
        assert np.array_equal(sbank.banks[d].tree_nb, nb_before[d])

    # maintained sharded bank == from-scratch sharded build (answers)
    live = {}
    for t in range(T):
        for _, name in trees[t]:
            if t == hot and name in (f"e{hot}_0", f"e{hot}_1"):
                continue
            live[(t, name)] = bank.locate(t, name)
    for k in range(3):
        live[(hot, f"new {hot}_{k}")] = node_pool[:2]
    ks = sorted(live)
    rt = np.asarray([t for t, _ in ks], np.int32)
    rh = np.asarray([int(hashing.entity_hash(n)) for _, n in ks],
                    np.uint32)
    lens = np.asarray([len(live[k]) for k in ks], np.int32)
    off = np.zeros(len(ks) + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    nodes = np.concatenate([np.asarray(live[k], np.int32) for k in ks])
    fresh = build_bank_from_rows(
        T, rt, np.full(len(ks), -1, np.int32), rh, off,
        nodes).shard(tree_starts=sbank.tree_starts)
    for (t, name), nl in live.items():
        assert sorted(sbank.locate(t, name)) == \
            sorted(fresh.locate(t, name)) == sorted(nl), (t, name)
    assert not sbank.contains(hot, int(hashing.entity_hash(f"e{hot}_0")))

    # device lookup on the heterogeneous per-tree-nb sharded bank:
    # per-shard ragged reference (each shard's own arena + offsets table)
    # matches bit-identically
    state = stage_sharded_bank(sbank, forest, mesh, "model")
    assert len(set(sbank.tree_nb_map().tolist())) > 1  # really ragged now
    qt = np.asarray([t for t, _ in ks], np.int32)
    qh = rh
    got = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh))
    base = sbank.shard_row_base()
    shard_of = sbank.tree_shard_map()
    local_of = sbank.tree_local_map()
    for d in range(D):
        sel = shard_of[qt] == d
        if not sel.any():
            continue
        b = sbank.banks[d]
        occ = b.fingerprints != hashing.EMPTY_FP
        heads_m = np.where(occ, b.heads + np.int32(base[d]), -1)
        ref = lookup_batch_ragged(
            jnp.asarray(b.fingerprints), jnp.asarray(heads_m),
            jnp.asarray(b.bucket_offsets.astype(np.int32)),
            jnp.asarray(b.tree_nb),
            jnp.asarray(local_of[qt[sel]]), jnp.asarray(qh[sel]))
        for f in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)),
                np.asarray(getattr(got, f))[sel], err_msg=f)
    gh = np.asarray(got.head)
    assert bool(np.asarray(got.hit).all())
    for j, k in enumerate(ks):
        assert sorted(sbank.walk_row(int(gh[j]))) == sorted(live[k])
    print("shard-local maintenance churn OK")
    """)


def test_sharded_temperature_absorb_no_double_count():
    """Temperature feedback under sharding: two serve+maintain cycles pin
    the exact bump totals — each slot's bumps harvested once against the
    owning shard's baseline, padding rows/buckets never counted, repeated
    absorb of an unchanged device state adds zero."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (build_forest, build_bank,
                            ShardedMaintenanceEngine,
                            sharded_retrieve_device, stage_sharded_bank)
    from repro.core import hashing

    T, D = 10, 4            # ragged partition -> padded rows exist
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(8)] for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    assert sbank.arena_rows_per_shard * D > sbank.total_buckets, \
        "need packed-arena padding for this test"
    eng = ShardedMaintenanceEngine(sbank)
    mesh = jax.make_mesh((D,), ("model",))
    state = stage_sharded_bank(sbank, forest, mesh, "model")

    # every stored entity once, plus misses; B=87 pads internally
    qt = np.asarray([t for t in range(T) for _ in range(8)] + [3] * 7,
                    np.int32)
    qh = np.asarray(
        [int(hashing.entity_hash(f"e{t}_{i}"))
         for t in range(T) for i in range(8)]
        + [int(hashing.entity_hash(f"nope {j}")) for j in range(7)],
        np.uint32)

    totals = 0
    for cycle in range(2):
        out = sharded_retrieve_device(state, jnp.asarray(qh),
                                      jnp.asarray(qt))
        hits = int(np.asarray(out.hit).sum())
        assert hits == 8 * T, hits
        state = state.with_temperature(out.temperature)
        rep = eng.maintain(state)
        totals += hits
        assert rep.absorbed_bumps == hits, (cycle, rep.absorbed_bumps,
                                            hits)
        host_total = sum(int(b.temperature.sum()) for b in sbank.banks)
        assert host_total == totals, (cycle, host_total, totals)
        # re-absorbing the same device state must add nothing
        assert eng.absorb(state) == 0
        if rep.changed:           # sort may have fired: restage
            state = stage_sharded_bank(sbank, forest, mesh, "model")
    # per-tree pinning: each tree absorbed exactly 2 * its query hits
    for t in range(T):
        d, lt = sbank.owner(t)
        b = sbank.banks[d]
        lo, hi = int(b.bucket_offsets[lt]), int(b.bucket_offsets[lt + 1])
        tree_total = int(b.temperature[lo:hi].sum())
        assert tree_total == 2 * 8, (t, tree_total)
    print("sharded temperature absorb OK")
    """)


def test_two_pass_capacity():
    """Two-pass count-then-exchange capacity: balanced loads answer
    bit-identically through the factor-sized (fast path) buffer, the
    count pass reports exact per-pair routing, and an adversarial batch
    that overflowed the old eager pre-check (every query to one shard)
    now adapts the buffer to the measured maximum and answers exactly —
    no raise, no dropped queries."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (build_forest, build_bank, routing_counts,
                            sharded_lookup_bank, sharded_retrieve_device,
                            stage_sharded_bank)
    from repro.core.distributed import _pick_capacity
    from repro.core import hashing

    T, D = 32, 8
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(6)] for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    mesh = jax.make_mesh((D,), ("model",))
    state = stage_sharded_bank(sbank, forest, mesh, "model")

    # balanced: round-robin trees -> per-(src, dst) load is B/(D*D)
    qt = (np.arange(128) % T).astype(np.int32)
    qh = np.asarray([int(hashing.entity_hash(f"e{t}_0")) for t in qt],
                    np.uint32)
    counts = routing_counts(state, qt)
    assert counts.shape == (D, D) and counts.sum() == 128
    # each source's 16 round-robin queries cover 16 consecutive trees =
    # 4 shards at 4 queries each (pads included) -- the counts are exact
    assert counts.max() == 4, counts
    full = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh))
    half = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh),
                               capacity_factor=0.5)
    for f in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                      np.asarray(getattr(half, f)),
                                      err_msg=f"capacity_factor {f}")
    assert bool(np.asarray(half.hit).all())
    # fast path: counts fit, so the factor sizes the (shrunken) buffer
    cap = _pick_capacity(state, qt, 0.5)
    assert cap == 8 and cap < 128 // D, cap

    # retrieve path threads the factor too
    out = sharded_retrieve_device(state, jnp.asarray(qh), jnp.asarray(qt),
                                  capacity_factor=0.5)
    assert bool(np.asarray(out.hit).all())

    # adversarial: every query to shard 0's trees overflowed the old
    # eager check at factor 0.25 -- the second pass now sizes the buffer
    # from the measured max and the batch answers bit-identically
    qt_bad = np.zeros(64, np.int32)
    assert int(routing_counts(state, qt_bad).max()) == 64 // D
    cap_bad = _pick_capacity(state, qt_bad, 0.25)
    assert cap_bad == 64 // D, cap_bad          # adapted past ceil(f*Bl)
    ref = sharded_lookup_bank(state, jnp.asarray(qt_bad),
                              jnp.asarray(qh[:64]))
    got = sharded_lookup_bank(state, jnp.asarray(qt_bad),
                              jnp.asarray(qh[:64]), capacity_factor=0.25)
    for f in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f"adaptive {f}")
    print("two-pass capacity OK")
    """)


def test_sharded_splice_commit_matches_from_scratch():
    """Acceptance gate (sharded): across random churn schedules
    (insert/delete/expand/shrink), plan_restage + commit_restage leaves
    the packed ShardedBankState byte-identical to a from-scratch
    stage_sharded_bank — and a splice-only cycle never writes a
    non-owning shard's block (device buffers compared byte-for-byte)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (ShardedMaintenanceEngine, build_bank,
                            build_forest, commit_restage,
                            sharded_retrieve_device, stage_sharded_bank)
    from repro.core import hashing

    T, D = 16, 4
    FIELDS = ("fingerprints", "temperature", "heads", "tree_shard",
              "tree_offset", "tree_nb", "csr_offsets", "csr_nodes")

    def shard_bytes(state, d):
        ap = state.arena_rows_per_shard
        return tuple(np.asarray(getattr(state, f))[d * ap:(d + 1) * ap]
                     .tobytes() for f in ("fingerprints", "temperature",
                                          "heads"))

    for seed in (0, 7):
        rng = np.random.default_rng(seed)
        trees = [[(f"r{t}", f"e{t}_{i}") for i in range(12)]
                 for t in range(T)]
        forest = build_forest(trees)
        bank = build_bank(forest)
        sbank = bank.shard(D)
        eng = ShardedMaintenanceEngine(sbank, seed=seed)
        mesh = jax.make_mesh((D,), ("model",))
        state = stage_sharded_bank(sbank, forest, mesh, "model")
        eng.mark_staged()
        serial = 0
        for cycle in range(4):
            # churn one shard's trees only, so the others must stay
            # byte-identical through the splice commit
            hot_shard = int(rng.integers(D))
            lo, hi = (int(sbank.tree_starts[hot_shard]),
                      int(sbank.tree_starts[hot_shard + 1]))
            for _ in range(int(rng.integers(2, 6))):
                t = int(rng.integers(lo, hi))
                if rng.random() < 0.6:
                    eng.queue_insert(t, f"new {seed} {serial}", [serial])
                    serial += 1
                else:
                    eng.queue_delete(t, f"e{t}_{int(rng.integers(12))}")
            eng.maintain()
            if rng.random() < 0.5:
                eng.expand_tree(int(rng.integers(lo, hi)), force=True)
            elif rng.random() < 0.5:
                eng.shrink_tree(int(rng.integers(lo, hi)), force=True)
            before = {d: shard_bytes(state, d) for d in range(D)
                      if d != hot_shard}
            plan = eng.plan_restage()
            state2 = commit_restage(state, plan, eng, forest)
            ref = stage_sharded_bank(sbank, forest, mesh, "model",
                                     arena_rows=state2.arena_rows_per_shard)
            for f in FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(state2, f)),
                    np.asarray(getattr(ref, f)),
                    err_msg=f"seed {seed} cycle {cycle} {plan.kind}: {f}")
            in_place = (plan.kind == "splice"
                        and state2.arena_rows_per_shard
                        == state.arena_rows_per_shard)
            if in_place:   # else: segment outgrew the padding -> repack
                for d, b in before.items():
                    # shards before the churned one are always untouched;
                    # later shards too unless an insert shifted their
                    # merged head numbering (zero host bytes either way)
                    if d < hot_shard or plan.head_shift is None:
                        assert shard_bytes(state2, d) == b, \
                            (seed, cycle, d, "non-owner block mutated")
            state = state2
            # committed state serves: every surviving key resolves
            qt = np.asarray([t for t in range(T)], np.int32)
            qh = np.asarray([int(hashing.entity_hash(f"e{t}_2"))
                             for t in range(T)], np.uint32)
            out = sharded_retrieve_device(state, jnp.asarray(qh),
                                          jnp.asarray(qt))
            state = state.with_temperature(out.temperature)
            eng.absorb(state)
    print("sharded splice commit OK")
    """, devices=4)


def test_small_mesh_train_step_sharded():
    """Sharded train step == single-device train step (tiny dense model)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch
    from repro.models import init_params, runtime
    from repro.training import AdamWConfig, adamw_init, make_train_step
    from repro.launch import sharding as sh

    # capacity_factor high enough that no tokens drop: per-shard capacity
    # (sharded path) and global capacity (local path) then agree exactly
    cfg = get_arch("granite-moe-1b-a400m").smoke().replace(
        d_model=128, num_experts=4, top_k=2, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (8, 32), 4, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
             "mask": jnp.ones((8, 32), jnp.float32)}
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)

    p1, _, m1 = make_train_step(cfg, ocfg)(params, adamw_init(params), batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    runtime.set_mesh(mesh, ("data",))
    params_sh = sh.params_shardings(mesh, jax.eval_shape(lambda: params))
    opt_abs = jax.eval_shape(adamw_init, params)
    opt_sh = sh.opt_shardings(mesh, opt_abs, params_sh)
    bs = jax.tree.map(lambda t: NamedSharding(
        mesh, P("data", *(None,) * (t.ndim - 1))), batch)
    step = make_train_step(cfg, ocfg, param_shardings=params_sh,
                           data_axes=("data",))
    with mesh:
        fn = jax.jit(step, in_shardings=(params_sh, opt_sh, bs),
                     out_shardings=(params_sh, opt_sh, None))
        p2, _, m2 = fn(jax.device_put(params, params_sh),
                       jax.device_put(adamw_init(params), opt_sh),
                       jax.device_put(batch, bs))
    runtime.clear_mesh()
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-2, rtol=3e-2)
    print("sharded train step OK")
    """)


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (2,4) mesh, restore onto (4,2) — elastic re-shard."""
    _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models import init_params
    from repro.training import adamw_init, restore, save
    from repro.launch import sharding as sh

    cfg = get_arch("qwen2-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    sh_a = sh.params_shardings(mesh_a, jax.eval_shape(lambda: params))
    sh_b = sh.params_shardings(mesh_b, jax.eval_shape(lambda: params))
    placed = jax.device_put(params, sh_a)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"params": placed})
        got, step, _ = restore(d, {"params": params},
                               shardings={"params": sh_b})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("elastic restore OK")
    """)


def test_moe_small_batch_token_routing():
    """Decode-scale MoE: token-routed path == local path (weights resident)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models import moe as M

    cfg = get_arch("granite-moe-1b-a400m").smoke().replace(
        d_model=64, num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
        shared_expert=True)
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64), jnp.float32)
    y_local = M._moe_apply_local(cfg, p, x)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        y_small = M._moe_small_batch(cfg, p, x, mesh, ("data",), "model", 2)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_small),
                               atol=2e-5, rtol=2e-5)
    print("token-routed MoE OK")
    """)


def test_mini_dryrun_multi_pod_mesh():
    """A miniature multi-pod mesh (2,2,2) lower+compile for a smoke arch —
    proves the pod axis shards end to end without the 512-device cost."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_arch, SHAPES
    from repro.launch import sharding as sh, specs
    from repro.models import lm, runtime
    from repro.training.grad import make_train_step
    from repro.training.optimizer import AdamWConfig, adamw_init
    import dataclasses

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    runtime.set_mesh(mesh, ("pod", "data"))
    cfg = get_arch("qwen2-0.5b").smoke()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                global_batch=8)
    params_abs = specs.params_specs(cfg)
    params_sh = sh.params_shardings(mesh, params_abs)
    with mesh:
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_sh = sh.opt_shardings(mesh, opt_abs, params_sh)
        batch_abs = specs.train_batch_specs(cfg, shape)
        batch_sh = sh.batch_shardings(mesh, cfg, shape, batch_abs)
        step = make_train_step(cfg, AdamWConfig(), microbatches=2,
                               param_shardings=params_sh,
                               data_axes=("pod", "data"))
        c = jax.jit(step, in_shardings=(params_sh, opt_sh, batch_sh),
                    out_shardings=(params_sh, opt_sh, None)
                    ).lower(params_abs, opt_abs, batch_abs).compile()
    assert c.memory_analysis() is not None
    print("mini multi-pod dryrun OK")
    """, devices=8)


def test_sharded_tenant_evict_reload_bit_exact():
    """Cold-tenant eviction over a bank-axis sharded deployment: evicting
    a single-shard tenant touches only its owning shard (every other
    shard byte-identical), a tenant spanning two shards splices per
    owning piece, and reload restores every shard's tables bit-exactly —
    the sharded device lookup answers match the pre-eviction baseline
    field for field.  Shard boundaries come from the tenant-aligned
    planner, so no tenant straddles a shard it doesn't own outright."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (TenantRegistry, build_forest, build_bank,
                            ShardedMaintenanceEngine, plan_tenant_partition,
                            sharded_lookup_bank, stage_sharded_bank)
    from repro.core import hashing

    T, D = 8, 4
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(10)] for t in range(T)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    reg = TenantRegistry({"a": (0, 2), "b": (2, 4), "c": (4, 6),
                          "d": (6, 8)})
    starts = plan_tenant_partition(bank.tree_nb, reg, D)
    for name in reg.names:                 # planner honors every boundary
        lo, hi = reg.trees(name)
        assert not any(int(lo) < int(s) < int(hi) for s in starts), name
    sbank = bank.shard(tree_starts=starts)
    mesh = jax.make_mesh((D,), ("model",))
    TABLES = ("fingerprints", "temperature", "heads", "entity_ids",
              "stored_hash")

    def shard_bytes(d):
        return tuple(getattr(sbank.banks[d], f).tobytes() for f in TABLES)

    def answers():
        state = stage_sharded_bank(sbank, forest, mesh, "model")
        got = sharded_lookup_bank(state, jnp.asarray(qt), jnp.asarray(qh))
        return {f: np.asarray(getattr(got, f)).copy()
                for f in ("hit", "head", "bucket", "slot")}

    qt = np.asarray([t for t in range(T) for _ in range(10)], np.int32)
    qh = np.asarray([int(hashing.entity_hash(f"e{t}_{i}"))
                     for t in range(T) for i in range(10)], np.uint32)
    base = answers()
    assert base["hit"].all()
    snap = {d: shard_bytes(d) for d in range(D)}

    # --- single-shard tenant: surgery stays inside the owning shard
    blo, bhi = reg.trees("b")
    owners = [d for d in range(D)
              if max(blo, int(starts[d])) < min(bhi, int(starts[d + 1]))]
    assert len(owners) == 1
    cold = reg.evict(sbank, "b")
    eng = ShardedMaintenanceEngine(sbank)
    eng.pin_tree_range(blo, bhi, True)
    try:
        eng.queue_insert(blo, "blocked", [0])
        raise SystemExit("pinned insert must raise")
    except ValueError:
        pass
    for d in range(D):
        if d not in owners:
            assert shard_bytes(d) == snap[d], f"shard {d} mutated"
    mid = answers()
    sel = (qt >= blo) & (qt < bhi)
    assert not mid["hit"][sel].any()       # the cold tenant misses
    for f in ("hit", "head", "bucket", "slot"):   # everyone else exact
        np.testing.assert_array_equal(mid[f][~sel], base[f][~sel],
                                      err_msg=f)
    reg.reload(sbank, "b")
    eng.pin_tree_range(blo, bhi, False)
    for d in range(D):
        assert shard_bytes(d) == snap[d], f"shard {d} not restored"

    # --- a tenant spanning two shards splices per owning piece
    wide = TenantRegistry({"w": (0, 4), "c": (4, 6), "d": (6, 8)})
    cold_w = wide.evict(sbank, "w")
    assert cold_w.arena_rows > 0
    changed = [d for d in range(D) if shard_bytes(d) != snap[d]]
    assert changed == [d for d in range(D)
                       if max(0, int(starts[d])) < min(4, int(starts[d + 1]))]
    assert len(changed) == 2
    assert not answers()["hit"][qt < 4].any()
    wide.reload(sbank, "w")
    for d in range(D):
        assert shard_bytes(d) == snap[d], f"shard {d} not restored (wide)"
    post = answers()
    for f in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(post[f], base[f], err_msg=f)
    print("sharded tenant evict/reload OK")
    """, devices=4)


def test_sharded_fused_owner_probe_byte_equality():
    """The fused owner-shard probe (probe + bump + CSR window in one
    Pallas launch before the route-back) is byte-identical to the unfused
    sharded path — hit/locations/hierarchy and the *sharded-layout*
    temperature, across rounds and both capacity modes."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import build_forest, build_bank, stage_sharded_bank
    from repro.core.distributed import sharded_retrieve_device
    from repro.core import hashing

    T, D = 32, 8
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(4 + (t % 5) * 3)]
             for t in range(T)]
    for t in range(0, T, 4):                       # deepen a few trees
        trees[t] += [(f"e{t}_0", f"e{t}_c{j}") for j in range(5)]
    forest = build_forest(trees)
    bank = build_bank(forest)
    sbank = bank.shard(D)
    mesh = jax.make_mesh((D,), ("model",))
    rng = np.random.default_rng(1)
    qt = [t for t in range(T) for _ in range(3)] + \\
         [int(rng.integers(T)) for _ in range(15)] + [-3, T + 9]
    qh = [int(hashing.entity_hash(f"e{t}_{k}"))
          for t in range(T) for k in (0, 1, 2)] + \\
         [int(rng.integers(1, 2 ** 32)) for _ in range(17)]
    qt = jnp.asarray(np.asarray(qt, np.int32))
    qh = jnp.asarray(np.asarray(qh, np.uint32))

    for cf in (None, 0.5):
        s_ref = stage_sharded_bank(sbank, forest, mesh, "model")
        s_fus = stage_sharded_bank(sbank, forest, mesh, "model")
        for rnd in range(3):
            ref = sharded_retrieve_device(s_ref, qh, qt,
                                          capacity_factor=cf)
            got = sharded_retrieve_device(s_fus, qh, qt,
                                          capacity_factor=cf, fused=True)
            for f in ("hit", "locations", "up", "down", "temperature"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, f)),
                    np.asarray(getattr(got, f)),
                    err_msg=f"{f} cf={cf} round={rnd}")
            s_ref = s_ref.with_temperature(ref.temperature)
            s_fus = s_fus.with_temperature(got.temperature)
    assert np.asarray(ref.hit)[:3 * T].all()
    assert not np.asarray(ref.hit)[-2:].any()      # out-of-range ids miss
    print("sharded fused owner probe OK")
    """)
