"""End-to-end behaviour tests for the CFT-RAG system.

The pipeline the paper describes (Figure 1), executed completely: raw text
-> entity extraction -> relation extraction/filtering -> entity forest ->
cuckoo index -> query NER -> filter lookup -> hierarchical context ->
augmented prompt -> generator -> answer; plus the speed claim's direction
(CF lookup beats naive BFS) at a miniature scale.
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import (CFTRAG, NaiveTRAG, build_forest, build_index)
from repro.data import (HashTokenizer, extract_relations, filter_relations,
                        hospital_corpus)
from repro.data.filtering import is_forest
from repro.models import init_params
from repro.serving import RAGPipeline, ServeEngine


def test_full_paper_pipeline_from_raw_text():
    c = hospital_corpus(num_trees=15, num_queries=4)
    # §2: data pre-processing from RAW TEXT (not the gold trees)
    trees = []
    for doc in c.documents:
        edges = filter_relations(extract_relations(doc, entities=c.entities))
        assert is_forest(edges)
        trees.append(edges)
    forest = build_forest(trees)
    index = build_index(forest)
    retriever = CFTRAG(index)
    # §3/§4: retrieval equals naive BFS on the same forest
    naive = NaiveTRAG(forest)
    hits = 0
    for ents in c.query_entities:
        for e in ents:
            if e in forest.name_to_id:
                hits += 1
                assert sorted(retriever.locate(e)) == sorted(naive.locate(e))
    assert hits > 0


def test_rag_answers_with_trained_shapes():
    c = hospital_corpus(num_trees=10, num_queries=2)
    cfg = get_arch("paper-cftrag").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, cache_size=128, batch_size=2)
    rag = RAGPipeline(c, engine, tokenizer=HashTokenizer(cfg.vocab))
    for q in c.queries:
        ans = rag.answer(q, max_new_tokens=4)
        assert len(ans.output_ids) == 4
        assert ans.prompt.startswith("You are an assistant")


def test_cf_faster_than_naive_direction():
    """Direction of Table 1 at mini scale: CF locate >= 5x faster than BFS."""
    c = hospital_corpus(num_trees=120, num_queries=1)
    forest = build_forest(c.trees)
    index = build_index(forest)
    cf = CFTRAG(index, sort_every=0)
    naive = NaiveTRAG(forest)
    names = forest.entity_names[:40]
    for nm in names[:4]:           # warm caches
        cf.locate(nm), naive.locate(nm)
    t0 = time.perf_counter()
    for nm in names:
        cf.locate(nm)
    t_cf = time.perf_counter() - t0
    t0 = time.perf_counter()
    for nm in names:
        naive.locate(nm)
    t_naive = time.perf_counter() - t0
    assert t_naive > 5 * t_cf, (t_naive, t_cf)
