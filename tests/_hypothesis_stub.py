"""Minimal deterministic fallback for ``hypothesis``.

Used only when the real package is unavailable (e.g. offline containers);
CI installs the genuine library via the ``test`` extra in pyproject.toml.
Implements exactly the API surface these tests use — ``@given``/``@settings``
and ``st.text`` / ``st.lists`` / ``st.tuples`` / ``st.integers`` /
``st.data`` — drawing examples from a seed derived from the test name so
every run sees the same inputs.  No shrinking, no example database.
"""
from __future__ import annotations


import string
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng):
        return self._draw_fn(rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: None)


class _DataObject:
    """Stand-in for hypothesis's interactive draw object."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy):
        return strategy.draw(self._rng)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def text(alphabet=string.ascii_lowercase, min_size=0, max_size=10):
        chars = list(alphabet)

        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            picks = rng.integers(0, len(chars), size=n)
            return "".join(chars[int(i)] for i in picks)

        return _Strategy(draw)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, unique=False):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            out, seen, attempts = [], set(), 0
            while len(out) < n and attempts < 50 * (n + 1):
                attempts += 1
                v = elements.draw(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

        return _Strategy(draw)

    @staticmethod
    def data():
        return _DataStrategy()


st = strategies


def settings(max_examples=20, deadline=None, **_kwargs):
    def wrap(fn):
        fn._stub_max_examples = max_examples
        return fn

    return wrap


def given(*given_strategies):
    def wrap(fn):
        # NB: no functools.wraps — pytest must see a zero-arg signature,
        # otherwise the given-supplied parameters look like fixtures.
        def run():
            n = getattr(run, "_stub_max_examples", 20)
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            for i in range(n):
                rng = np.random.default_rng((seed + i) & 0xFFFFFFFF)
                vals = [(_DataObject(rng) if isinstance(s, _DataStrategy)
                         else s.draw(rng)) for s in given_strategies]
                fn(*vals)

        run.__name__ = fn.__name__
        run.__qualname__ = fn.__qualname__
        run.__doc__ = fn.__doc__
        return run

    return wrap
