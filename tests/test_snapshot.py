"""Bank/state snapshots: atomic write discipline, bit-identical
restore (tombstones included), elastic merge, commit-driven cadence,
and the RAGPipeline restore-at-startup path."""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax

from repro.core import (CFTDeviceState, MaintenanceEngine,
                        ShardedMaintenanceEngine, SnapshotWriter,
                        apply_maint_bookkeeping, build_bank, build_forest,
                        cleanup_snapshots, latest_snapshot, list_snapshots,
                        merge_sharded_bank, restore_snapshot, restore_state,
                        save_snapshot, stage_sharded_bank)
from repro.core import hashing
from repro.core.snapshot import _bank_array_fields
from repro.serving import FaultPlan, InjectedFault, fault_point, inject


def _forest(num_trees=4, entities_per_tree=10):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _churned_bank(forest):
    """A bank whose maintenance history left tombstones behind (deletes
    stay below the compaction threshold, so dead rows persist)."""
    bank = build_bank(forest)
    maint = MaintenanceEngine(bank)
    for t in range(2):
        maint.queue_insert(t, f"snap extra {t}", [1])
        maint.queue_delete(t, f"entity {t}_3")
    maint.maintain()
    assert not bool(maint.row_alive.all()), "expected tombstoned rows"
    return bank, maint


def _banks_equal(a, b) -> bool:
    return (a.num_trees == b.num_trees and a.slots == b.slots
            and all(np.array_equal(np.asarray(getattr(a, n)),
                                   np.asarray(getattr(b, n)))
                    for n in _bank_array_fields()))


def _leaves_equal(a, b) -> bool:
    names = [f.name for f in dataclasses.fields(CFTDeviceState)]
    return all(np.array_equal(np.asarray(jax.device_get(getattr(a, n))),
                              np.asarray(jax.device_get(getattr(b, n))))
               for n in names)


def test_replicated_roundtrip_bit_exact(tmp_path):
    forest = _forest()
    bank, maint = _churned_bank(forest)
    state = CFTDeviceState.from_bank(bank, forest)
    path = save_snapshot(str(tmp_path), 7, bank, state=state, maint=maint)
    assert os.path.isdir(path) and list_snapshots(str(tmp_path)) == [7]

    snap = restore_snapshot(str(tmp_path))
    assert snap.step == 7
    assert _banks_equal(snap.bank, bank)
    assert snap.bank.build_stats == bank.build_stats
    np.testing.assert_array_equal(snap.row_alive[0], maint.row_alive)
    np.testing.assert_array_equal(snap.row_hash[0], maint.row_hash)
    assert _leaves_equal(restore_state(snap), state)

    # a fresh engine over the restored bank resurrects the tombstones —
    # the saved bookkeeping is what keeps them dead
    m2 = MaintenanceEngine(snap.bank)
    assert bool(m2.row_alive.all())
    apply_maint_bookkeeping(m2, snap)
    np.testing.assert_array_equal(m2.row_alive, maint.row_alive)
    np.testing.assert_array_equal(m2.row_hash, maint.row_hash)


def test_bookkeeping_count_mismatch_rejected(tmp_path):
    forest = _forest()
    bank, maint = _churned_bank(forest)
    save_snapshot(str(tmp_path), 1, bank, maint=maint)
    snap = restore_snapshot(str(tmp_path))
    with pytest.raises(ValueError):
        apply_maint_bookkeeping(
            ShardedMaintenanceEngine(bank.shard(2)), snap)
    with pytest.raises(ValueError):
        restore_state(snap)          # bank-only snapshot carries no state


def test_write_fault_leaves_snapshot_set_intact(tmp_path):
    forest = _forest()
    bank, maint = _churned_bank(forest)
    save_snapshot(str(tmp_path), 1, bank, maint=maint)
    with inject(FaultPlan({"snapshot-write": [0]})):
        with pytest.raises(InjectedFault):
            save_snapshot(str(tmp_path), 2, bank, maint=maint,
                          fault_hook=fault_point)
    # the crash window is after the leaves, before the rename: the
    # previous snapshot is untouched and no half-written one is visible
    assert latest_snapshot(str(tmp_path)) == 1
    snap = restore_snapshot(str(tmp_path))
    assert _banks_equal(snap.bank, bank)
    # the aborted tmp dir (removed on raise, swept by cleanup if a hard
    # crash left it) never shadows a real snapshot
    cleanup_snapshots(str(tmp_path), keep_last=3)
    assert not [d for d in os.listdir(str(tmp_path))
                if d.startswith("tmp.")]
    save_snapshot(str(tmp_path), 2, bank, maint=maint,
                  fault_hook=fault_point)          # no plan: lands
    assert list_snapshots(str(tmp_path)) == [1, 2]
    cleanup_snapshots(str(tmp_path), keep_last=1)
    assert list_snapshots(str(tmp_path)) == [2]


def test_writer_cadence_and_failure_swallowing(tmp_path):
    forest = _forest()
    bank, maint = _churned_bank(forest)
    state = CFTDeviceState.from_bank(bank, forest)
    w = SnapshotWriter(str(tmp_path), every=2, keep_last=2,
                       fault_hook=fault_point)
    assert w.note_commit(state, maint) is None         # commit 1: off-cadence
    assert w.note_commit(state, maint) is not None     # commit 2: saved
    assert w.saved == 1 and w.last_error is None
    with inject(FaultPlan({"snapshot-write": [0]})):
        assert w.note_commit(state, maint) is None     # commit 3: off-cadence
        assert w.note_commit(state, maint) is None     # commit 4: crashes
    assert w.saved == 1 and isinstance(w.last_error, InjectedFault)
    assert latest_snapshot(str(tmp_path)) == 2         # set intact
    w.note_commit(state, maint)
    assert w.note_commit(state, maint) is not None     # commit 6: lands
    assert w.saved == 2 and list_snapshots(str(tmp_path)) == [2, 6]
    with pytest.raises(ValueError):
        SnapshotWriter(str(tmp_path), every=0)


def test_merge_sharded_bank_is_content_equivalent():
    forest = _forest(num_trees=6, entities_per_tree=12)
    bank, _ = _churned_bank(forest)
    merged = merge_sharded_bank(bank.shard(3))
    # shard() drops tombstones and renumbers rows, so compare what the
    # ids point at, not the ids: hit/entity and the CSR node content
    names = list(forest.entity_names) + ["snap extra 0", "snap extra 1"]
    hs = hashing.hash_entities(names)
    checked = 0
    for name, h in zip(names, hs):
        for t in range(bank.num_trees):
            hit_a, row_a, ent_a = bank.lookup(t, int(h))
            hit_b, row_b, ent_b = merged.lookup(t, int(h))
            assert (hit_a, ent_a) == (hit_b, ent_b), (name, t)
            if hit_a:
                nodes_a = sorted(bank.csr_nodes[
                    bank.csr_offsets[row_a]:bank.csr_offsets[row_a + 1]])
                nodes_b = sorted(merged.csr_nodes[
                    merged.csr_offsets[row_b]:merged.csr_offsets[row_b + 1]])
                assert nodes_a == nodes_b, (name, t)
                checked += 1
    assert checked > 0


def test_sharded_snapshot_roundtrip_on_matching_mesh(tmp_path):
    forest = _forest()
    bank = build_bank(forest).shard(1)
    maint = ShardedMaintenanceEngine(bank)
    mesh = jax.make_mesh((1,), ("model",))
    state = stage_sharded_bank(bank, forest, mesh, "model")
    save_snapshot(str(tmp_path), 3, bank, state=state, maint=maint)
    snap = restore_snapshot(str(tmp_path))
    assert snap.meta["kind"] == "sharded"
    assert snap.state_meta == {"layout": "sharded", "axis": "model",
                               "num_shards": 1}
    assert len(snap.row_alive) == 1
    with pytest.raises(ValueError):
        restore_state(snap)                        # sharded needs a mesh
    restored = restore_state(snap, mesh=mesh, axis="model")
    for n in ("fingerprints", "temperature", "heads", "tree_nb",
              "csr_offsets", "csr_nodes"):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(restored, n))),
            np.asarray(jax.device_get(getattr(state, n))))


def test_pipeline_snapshots_on_commit_and_restores_at_startup(tmp_path):
    from repro.data import hospital_corpus
    from repro.serving import RAGPipeline
    corpus = hospital_corpus(num_trees=6, num_queries=2)
    snap_dir = str(tmp_path / "snaps")
    p1 = RAGPipeline(corpus, None, use_bank=True, snapshot_dir=snap_dir)
    assert p1.restored_step is None
    node = int(p1.bank.csr_nodes[0])
    p1.insert_entity(0, "snapshot probe", [node])
    p1.maintain()                       # applied commit -> snapshot lands
    assert latest_snapshot(snap_dir) is not None
    q = corpus.queries[0]

    p2 = RAGPipeline(corpus, None, use_bank=True, snapshot_dir=snap_dir)
    assert p2.restored_step is not None
    # compare before any retrieval: a retrieve harvests temperature
    # bumps into its own bank, diverging the copies (by design)
    assert _banks_equal(p2.bank, p1.bank)
    np.testing.assert_array_equal(p2.maintenance.row_alive,
                                  p1.maintenance.row_alive)
    want = p1.retrieve(q)
    got = p2.retrieve(q)
    assert got.context == want.context
    # the pre-crash insert survived the round trip inside the bank
    h = int(hashing.hash_entities(["snapshot probe"])[0])
    assert p2.bank.lookup(0, h)[0]

    # a corrupt latest snapshot falls back to a fresh build, not a crash
    step = latest_snapshot(snap_dir)
    with open(os.path.join(snap_dir, "snap_%08d" % step,
                           "manifest.json"), "w") as f:
        f.write("{ not json")
    p3 = RAGPipeline(corpus, None, use_bank=True, snapshot_dir=snap_dir)
    assert p3.restored_step is None
    assert p3.retrieve(q).context is not None


def test_pipeline_rejects_layout_mismatched_snapshot(tmp_path):
    from repro.data import hospital_corpus
    from repro.serving import RAGPipeline
    corpus = hospital_corpus(num_trees=6, num_queries=2)
    snap_dir = str(tmp_path / "snaps")
    # a *sharded* snapshot under the dir: the flat pipeline must ignore
    # it (layout mismatch) and build fresh
    forest = build_forest(corpus.trees)
    sbank = build_bank(forest).shard(2)
    save_snapshot(snap_dir, 5, sbank,
                  maint=ShardedMaintenanceEngine(sbank))
    p = RAGPipeline(corpus, None, use_bank=True, snapshot_dir=snap_dir)
    assert p.restored_step is None
    assert p.retrieve(corpus.queries[0]).context is not None


def test_snapshot_manifest_is_json_clean(tmp_path):
    forest = _forest()
    bank, maint = _churned_bank(forest)
    path = save_snapshot(str(tmp_path), 11, bank, maint=maint,
                         extra={"note": "probe"})
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 11
    assert manifest["meta"]["extra"] == {"note": "probe"}
    names = {l["name"] for l in manifest["leaves"]}
    assert "bank0/fingerprints" in names and "maint0/row_alive" in names
    for leaf in manifest["leaves"]:
        assert os.path.exists(os.path.join(path, leaf["file"]))
