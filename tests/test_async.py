"""Async serving engine: scheduler primitives, deterministic-clock
lifecycle, shape-stability of the hot path, and equivalence with the
synchronous engine on the same request stream."""
import asyncio
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CFTDeviceState, MaintenanceEngine, build_bank,
                        build_forest)
from repro.core import hashing
from repro.serving import (AsyncServeEngine, CommitPolicy, MicroBatcher,
                           PendingRetrieval, RAGPipeline, RetrievalSession,
                           bucket_batch, bucket_shapes)


def _forest(num_trees=4, entities_per_tree=10):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _session(maint=True, forest=None):
    forest = forest or _forest()
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    if maint:
        session.attach_maintenance(MaintenanceEngine(bank), forest)
    return forest, bank, session


def _queries(forest, bank, n):
    """Deterministic (tree_ids, hashes) request stream over live keys."""
    hashes = hashing.hash_entities(forest.entity_names)
    reqs = []
    for i in range(n):
        k = 1 + (i % 3)
        rows = [(i * 7 + j) % len(bank.row_entity) for j in range(k)]
        reqs.append(([int(bank.row_tree[r]) for r in rows],
                     [int(hashes[bank.row_entity[r]]) for r in rows]))
    return reqs


# ------------------------------------------------------------- primitives

def test_bucket_batch_pow2_and_bounds():
    assert bucket_batch(1) == 16                     # clamped to min bucket
    assert bucket_batch(16) == 16
    assert bucket_batch(17) == 32
    assert bucket_batch(200) == 256
    assert bucket_batch(3, min_bucket=2, max_batch=8) == 4
    with pytest.raises(ValueError):
        bucket_batch(0)
    with pytest.raises(ValueError):
        bucket_batch(300)
    # the closed shape set: every batch lands on one of these geometries
    shapes = bucket_shapes()
    assert shapes == [16, 32, 64, 128, 256]
    for n in range(1, 257):
        assert bucket_batch(n) in shapes


def test_microbatcher_budget_expiry_vs_bucket_full():
    mb = MicroBatcher(latency_budget=1.0, max_batch=8, min_bucket=2)
    mb.add(PendingRetrieval([0, 0], [1, 2], arrive_t=0.0))
    assert not mb.ready(0.0)
    assert not mb.ready(0.99)          # inside the budget: keep coalescing
    assert mb.ready(1.0)               # budget expiry launches
    assert mb.deadline() == 1.0
    batch = mb.pop()
    assert len(batch) == 1 and mb.pending_queries == 0

    # bucket-full launches immediately, whatever the clock says
    for i in range(4):
        mb.add(PendingRetrieval([0, 0], [i, i], arrive_t=0.0))
    assert mb.pending_queries == 8
    assert mb.ready(0.0)
    assert mb.bucket(mb.pop()) == 8

    # a batch never splits a request: FIFO prefix that fits max_batch
    mb.add(PendingRetrieval([0] * 5, [0] * 5, arrive_t=0.0))
    mb.add(PendingRetrieval([0] * 5, [1] * 5, arrive_t=0.0))
    first = mb.pop()
    assert [len(r) for r in first] == [5]            # 10 > max 8: one rides
    assert mb.pending_queries == 5                   # the other waits

    with pytest.raises(ValueError):
        mb.add(PendingRetrieval([0] * 9, [0] * 9, arrive_t=0.0))


def test_commit_policy_batch_count_and_age():
    p = CommitPolicy(commit_every=3, deadline=0.25)
    assert not p.due(99.0)                           # nothing staged
    p.note_plan(10.0)
    assert not p.due(10.0)
    p.note_batch(); p.note_batch()
    assert not p.due(10.1)
    p.note_batch()
    assert p.due(10.1)                               # third batch since plan
    p.clear(); p.note_plan(20.0)
    assert not p.due(20.24)
    assert p.due(20.25)                              # plan aged past deadline


# -------------------------------------------------- deterministic lifecycle

def test_pump_coalesces_until_budget_then_matches_sync():
    forest, bank, session = _session(maint=False)
    now = [100.0]
    eng = AsyncServeEngine(session, latency_budget=0.5, max_batch=32,
                           min_bucket=4, clock=lambda: now[0],
                           maintenance="off")
    reqs = _queries(forest, bank, 6)
    futs = [eng.submit(t, h) for t, h in reqs]
    assert not eng.pump(now[0])                      # budget not expired
    assert all(not f.done() for f in futs)
    now[0] += 0.5
    assert eng.pump(now[0])                          # one coalesced batch
    assert all(f.done() for f in futs)
    assert eng.stats.batches == 1
    assert eng.stats.requests == 6

    # same stream through a second, identically-built synchronous session
    _, _, ref = _session(maint=False, forest=forest)
    for (t, h), f in zip(reqs, futs):
        want = ref.retrieve(t, h)
        got = f.result()
        np.testing.assert_array_equal(got.hit, np.asarray(want.hit))
        np.testing.assert_array_equal(got.locations,
                                      np.asarray(want.locations))
        np.testing.assert_array_equal(got.up, np.asarray(want.up))
        np.testing.assert_array_equal(got.down, np.asarray(want.down))


def test_hot_path_never_recompiles():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=64,
                           min_bucket=4, clock=lambda: now[0],
                           maintenance="off")
    assert eng.warmup() == len(bucket_shapes(4, 64))
    baseline = session.compile_cache_size()
    if baseline < 0:
        pytest.skip("backend does not expose the jit cache size")
    reqs = _queries(forest, bank, 40)
    for t, h in reqs:                                # varying batch sizes
        eng.submit(t, h)
        now[0] += 1.0
        eng.pump(now[0])
    assert eng.stats.batches > 0
    # every launch hit a warm bucket geometry: zero new compilations
    assert session.compile_cache_size() == baseline


def test_background_lifecycle_prepare_under_batch_commit_between():
    forest, bank, session = _session(maint=True)
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                           min_bucket=4, commit_every=2, commit_deadline=1e9,
                           clock=lambda: now[0], maintenance="inline")
    eng.warmup()
    reqs = _queries(forest, bank, 8)
    session.maint.queue_insert(0, "fresh entity", [1])
    # batch 1: the pending insert triggers a prepare strictly under the
    # in-flight batch; the plan stays staged (commit_every = 2)
    eng.submit(*reqs[0]); now[0] += 1; eng.pump(now[0])
    assert eng.stats.prepares == 1
    assert session.coord.deferring
    assert eng.stats.commits == 0
    # batch 2 completes the policy window: commit lands between batches
    eng.submit(*reqs[1]); now[0] += 1; eng.pump(now[0])
    assert eng.stats.commits == 1
    assert not session.coord.deferring
    # the committed state serves the inserted key
    h = int(hashing.hash_entities(["fresh entity"])[0])
    eng.submit([0], [h]); now[0] += 1; eng.pump(now[0])
    # flush pending absorb/plan state and check host/device agree
    session.maintain()
    ref = CFTDeviceState.from_bank(bank, forest)
    np.testing.assert_array_equal(np.asarray(session.state.fingerprints),
                                  np.asarray(ref.fingerprints))


def test_commit_deadline_triggers_without_batches():
    forest, bank, session = _session(maint=True)
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                           min_bucket=4, commit_every=10 ** 6,
                           commit_deadline=5.0, clock=lambda: now[0],
                           maintenance="inline")
    eng.warmup()
    session.maint.queue_insert(0, "aged entity", [1])
    t, h = _queries(forest, bank, 1)[0]
    eng.submit(t, h); now[0] += 1; eng.pump(now[0])
    assert session.coord.deferring                   # staged, not yet due
    now[0] += 4.0
    eng.pump(now[0])                                 # idle pump: age < 5s
    assert session.coord.deferring
    now[0] += 1.1
    eng.pump(now[0])                                 # plan aged out
    assert not session.coord.deferring
    assert eng.stats.commits == 1


# ------------------------------------------------------------ thread mode

def test_threaded_engine_with_churn_matches_sync():
    forest, bank, session = _session(maint=True)
    reqs = _queries(forest, bank, 24)
    eng = AsyncServeEngine(session, latency_budget=1e-3, max_batch=32,
                           min_bucket=4, commit_every=2,
                           maintenance="thread")
    eng.warmup()
    with eng:
        futs = []
        for i, (t, h) in enumerate(reqs):
            if i == 8:
                session.maint.queue_insert(0, "mid-flight entity", [2])
            futs.append(eng.submit(t, h))
        results = [f.result(timeout=30) for f in futs]
    assert not session.coord.deferring               # stop() commits
    # retrieval outputs are independent of batching schedule and of
    # temperature, so a synchronous replay on the same final bank agrees
    # for keys that predate the churn
    _, _, ref = _session(maint=False, forest=forest)
    for (t, h), got in zip(reqs, results):
        want = ref.retrieve(t, h)
        np.testing.assert_array_equal(got.hit, np.asarray(want.hit))
        np.testing.assert_array_equal(got.locations,
                                      np.asarray(want.locations))

    with pytest.raises(RuntimeError):
        eng.submit([0], [0])                         # stopped engine


def test_concurrent_submitters_stats_stay_consistent():
    """Many submitter threads hammer the running engine while thread-mode
    maintenance churns underneath — the registry-backed counters must
    come out exactly consistent (the old ad-hoc ``AsyncStats`` dataclass
    was mutated from three threads without a lock and could tear)."""
    forest, bank, session = _session(maint=True)
    n_threads, per = 4, 30
    streams = [_queries(forest, bank, per) for _ in range(n_threads)]
    eng = AsyncServeEngine(session, latency_budget=1e-3, max_batch=32,
                           min_bucket=4, commit_every=2,
                           maintenance="thread")
    eng.warmup()
    futs = [[] for _ in range(n_threads)]
    errors = []

    def submitter(i):
        try:
            for j, (t, h) in enumerate(streams[i]):
                if j == 10:                          # mid-flight churn
                    session.maint.queue_insert(
                        i % 4, f"stress entity {i}", [2])
                futs[i].append(eng.submit(t, h))
        except Exception as exc:                     # pragma: no cover
            errors.append(exc)

    with eng:
        workers = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_threads)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        results = [f.result(timeout=30) for fs in futs for f in fs]
    assert not errors
    assert len(results) == n_threads * per

    # exact accounting: no submit lost, no query double-counted
    s = eng.stats
    assert s.requests == n_threads * per
    assert s.queries == sum(len(h) for st in streams for _, h in st)
    assert sum(s.bucket_histogram.values()) == s.batches
    # every dispatched slot is either a true query or a pad slot
    assert (sum(b * n for b, n in s.bucket_histogram.items())
            == s.queries + s.padded_queries)
    assert s.commits >= 1                            # the churn landed
    assert eng.hot_recompiles == 0                   # and stayed padded


# -------------------------------------------------------------- pipeline

def test_rag_answer_async_matches_answer():
    corpus_like = [[("root a", "child a1"), ("root a", "child a2")],
                   [("root b", "child b1")]]

    class _Corpus:
        trees = corpus_like

    rag = RAGPipeline(_Corpus(), engine=None, use_bank=True)
    queries = ["tell me about child a1", "child a2 and child b1?",
               "where is root b"]
    want = [rag.answer(q).prompt for q in queries]

    rag2 = RAGPipeline(_Corpus(), engine=None, use_bank=True)
    aeng = rag2.async_serving(latency_budget=1e-3, max_batch=64,
                              min_bucket=4)
    aeng.warmup()

    async def run():
        with aeng:
            return await asyncio.gather(
                *[rag2.answer_async(q, aeng) for q in queries])

    got = [a.prompt for a in asyncio.run(run())]
    assert got == want
