"""Per-arch smoke tests (REQUIRED: reduced config, one forward/train step on
CPU, output shapes + no NaNs) and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, prefill)
from repro.training import AdamWConfig, adamw_init, make_train_step

B, S = 2, 32


def _batch(cfg, key, seq=S):
    toks = jax.random.randint(key, (B, seq), 4, cfg.vocab)
    batch = {"tokens": toks,
             "labels": jnp.roll(toks, -1, axis=1),
             "mask": jnp.ones((B, seq), jnp.float32)}
    if cfg.family == "vlm" and cfg.num_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.frontend_dim), jnp.float32) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    logits = forward(cfg, params, batch)
    expected_len = S + (cfg.num_patches if cfg.family == "vlm"
                        and cfg.num_patches else 0)
    assert logits.shape == (B, expected_len, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                            total_steps=4))
    params2, opt2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert not any(bool(jnp.any(jnp.isnan(l)))
                   for l in jax.tree.leaves(params2))


@pytest.mark.parametrize("arch", ["yi-34b", "qwen2-0.5b",
                                  "granite-moe-1b-a400m",
                                  "llama4-maverick-400b-a17b",
                                  "rwkv6-1.6b", "zamba2-7b", "whisper-base"])
def test_prefill_decode_consistency(arch):
    cfg = get_arch(arch).smoke().replace(attn_impl="reference",
                                         capacity_factor=64.0)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    seq = 16
    toks = jax.random.randint(key, (B, seq + 1), 4, cfg.vocab)
    batch = {"tokens": toks[:, :seq]}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02

    full = forward(cfg, params, dict(batch))
    lg_pre, state = prefill(cfg, params, batch, cache_size=32)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1]),
                               np.asarray(full[:, -1]), atol=2e-3, rtol=2e-3)
    full2 = forward(cfg, params, {**batch, "tokens": toks})
    lg_dec, _ = decode_step(cfg, params, toks[:, seq:seq + 1], state)
    np.testing.assert_allclose(np.asarray(lg_dec[:, -1]),
                               np.asarray(full2[:, -1]), atol=2e-3, rtol=2e-3)


def test_decode_steps_chain():
    """Multiple decode steps stay consistent with teacher-forced forward."""
    cfg = get_arch("qwen2-0.5b").smoke().replace(attn_impl="reference")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 20), 4, cfg.vocab)
    lg, state = prefill(cfg, params, {"tokens": toks[:, :16]}, cache_size=32)
    for i in range(16, 20):
        full = forward(cfg, params, {"tokens": toks[:, :i + 1]})
        lg, state = decode_step(cfg, params, toks[:, i:i + 1], state)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, -1]),
                                   atol=2e-3, rtol=2e-3)


def test_blocked_attention_equals_reference():
    cfg_ref = get_arch("yi-34b").smoke().replace(attn_impl="reference")
    cfg_blk = cfg_ref.replace(attn_impl="blocked", attn_chunk=16)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg_ref, key)
    batch = _batch(cfg_ref, key, seq=50)
    a = forward(cfg_ref, params, batch)
    b = forward(cfg_blk, params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=2e-4)


def test_vocab_padding_masked():
    cfg = get_arch("whisper-base").smoke().replace(vocab=500)  # pads to 512
    assert cfg.padded_vocab == 512
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    batch["tokens"] = jnp.clip(batch["tokens"], 0, 499)
    logits = forward(cfg, params, batch)
    assert logits.shape[-1] == 512
    assert float(jnp.max(logits[..., 500:])) < -1e29   # padded ids masked
