"""Perf-regression gate: direction-aware thresholding, identity-key
matching, and the committed baselines' self-consistency."""
import json
import pathlib

from benchmarks.check_regression import check_dirs, compare

BASELINES = (pathlib.Path(__file__).resolve().parent.parent
             / "benchmarks" / "baselines")


def _pause(reduction):
    return {"rows": [dict(layout="replicated", trees=8,
                          pause_reduction=reduction, serve_ms=1.0)]}


def _regressed(entries):
    return [e for e in entries if e["regressed"]]


def test_injected_2x_slowdown_fails():
    base = _pause(10.0)
    entries, _ = compare("BENCH_pause.json", _pause(5.0), base)
    assert len(_regressed(entries)) == 1
    # and the untouched payload passes
    entries, _ = compare("BENCH_pause.json", _pause(10.0), base)
    assert not _regressed(entries)


def test_threshold_is_25_percent_and_direction_aware():
    base = _pause(10.0)
    ok, _ = compare("p", _pause(8.0), base)          # -20%: inside
    bad, _ = compare("p", _pause(7.0), base)         # -30%: regressed
    assert not _regressed(ok) and len(_regressed(bad)) == 1
    # an *improvement* of any size never trips the gate
    up, _ = compare("p", _pause(100.0), base)
    assert not _regressed(up)

    # bytes_fraction regresses in the other direction (growth is bad)
    b = {"rows": [dict(trees=4, bytes_fraction=0.10)]}
    grown = {"rows": [dict(trees=4, bytes_fraction=0.20)]}
    shrunk = {"rows": [dict(trees=4, bytes_fraction=0.05)]}
    assert len(_regressed(compare("r", grown, b)[0])) == 1
    assert not _regressed(compare("r", shrunk, b)[0])


def test_raw_timings_are_not_gated():
    base = {"rows": [dict(trees=8, serve_ms=1.0, sync_p99_ms=5.0)]}
    cur = {"rows": [dict(trees=8, serve_ms=50.0, sync_p99_ms=500.0)]}
    entries, _ = compare("t", cur, base)
    assert entries == []                  # nothing gated -> nothing to fail


def test_below_crossover_ratio_is_skipped():
    """A higher-is-better ratio below 1 on the recording host (e.g. a
    host-mesh shard speedup) is noise-dominated and must not gate."""
    base = {"rows": [dict(devices=8, speedup=0.03)]}
    cur = {"rows": [dict(devices=8, speedup=0.01)]}
    entries, notes = compare("s", cur, base)
    assert entries == []
    assert any("not gated" in n for n in notes)


def test_scenario_change_skips_row_with_note():
    base = {"rows": [dict(trees=8, pause_reduction=10.0)]}
    cur = {"rows": [dict(trees=64, pause_reduction=1.0)]}
    entries, notes = compare("p", cur, base)
    assert entries == []
    assert any("refresh the baseline" in n for n in notes)


def test_committed_baselines_self_compare_clean(tmp_path):
    """The checked-in baselines must gate themselves at zero regressions
    (guards against schema drift between the benches and the checker)."""
    assert BASELINES.is_dir() and list(BASELINES.glob("BENCH_*.json"))
    assert check_dirs(str(BASELINES), str(BASELINES)) == 0


def test_check_dirs_end_to_end_with_injection(tmp_path):
    cur = tmp_path / "cur"
    cur.mkdir()
    for p in BASELINES.glob("BENCH_*.json"):
        (cur / p.name).write_text(p.read_text())
    assert check_dirs(str(cur), str(BASELINES)) == 0
    payload = json.loads((cur / "BENCH_pause.json").read_text())
    payload["rows"][0]["pause_reduction"] /= 2.0
    (cur / "BENCH_pause.json").write_text(json.dumps(payload))
    assert check_dirs(str(cur), str(BASELINES)) == 1
    # a bench the run did not produce is skipped, not failed
    (cur / "BENCH_pause.json").unlink()
    assert check_dirs(str(cur), str(BASELINES)) == 0
