"""Fused retrieval kernel: bit-identity against the unfused oracle chain
(``retrieve_device`` -> ``gather_context``) across ragged/skewed forests,
miss-heavy batches, out-of-range tree ids, temperature rounds, and the
tiled-vs-single-block / mxu-vs-direct kernel variants; plus the shared
VMEM-budget derivation and the fused-path observability surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.core import (CFTDeviceState, build_bank, build_forest,
                        build_index, hashing, retrieve_device)
from repro.kernels import vmem
from repro.kernels.fused_retrieve import (fused_retrieve_arena,
                                          fused_retrieve_ref,
                                          fused_retrieve_state_auto,
                                          fused_vmem_budget)
from repro.obs import get_registry

RNG = np.random.default_rng(7)
FIELDS = ("hit", "locations", "up", "down", "temperature")

_unfused = jax.jit(retrieve_device, static_argnames=("max_locs", "n"))


def _forest(tree_sizes, deep_every=0, seed=0):
    """Ragged forest; every ``deep_every``-th tree gets a skewed
    random-parent tail.  A size-0 entry builds a root-only (empty) tree."""
    rng = np.random.default_rng(seed)
    trees = []
    for t, size in enumerate(tree_sizes):
        names = [f"e{t}_{i}" for i in range(size)]
        edges = [(f"r{t}", n) for n in names]
        if not size:
            edges = [(f"r{t}", f"only{t}")]     # leaf carries the tree
        if deep_every and t % deep_every == 0 and names:
            for j in range(11):
                parent = names[int(rng.integers(len(names)))]
                child = f"e{t}_d{j}"
                edges.append((parent, child))
                names.append(child)
        trees.append(edges)
    return build_forest(trees), trees


def _queries(trees, batch, hit_rate, seed=0, oob=True):
    rng = np.random.default_rng(seed)
    num_trees = len(trees)
    qt = rng.integers(num_trees, size=batch).astype(np.int32)
    qh = np.empty(batch, np.uint32)
    for i in range(batch):
        ents = [c for _, c in trees[qt[i]]]
        if rng.random() < hit_rate and ents:
            qh[i] = hashing.entity_hash(
                ents[int(rng.integers(len(ents)))])
        else:
            qh[i] = rng.integers(1, 2 ** 32)
    if oob and batch >= 4:       # out-of-range ids must miss, not alias
        qt[0], qt[1] = -2, num_trees + 5
    return jnp.asarray(qh), jnp.asarray(qt)


def _assert_same(ref, got, msg=""):
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f"{f} {msg}")


def _routing(state, qh, qt):
    """The pre-routed arena inputs retrieve_device computes internally."""
    num_trees = state.bucket_offsets.shape[0] - 1
    in_range = (qt >= 0) & (qt < num_trees)
    tq = jnp.where(in_range, qt, 0).astype(jnp.int32)
    row_off = state.bucket_offsets[tq]
    masks = (state.tree_nb[tq] - 1).astype(jnp.uint32)
    return row_off, masks, in_range


# ------------------------------------------------------------ bit identity

@pytest.mark.parametrize("sizes,hit_rate", [
    ((6, 1, 14, 3), 0.9),
    ((2, 9, 0, 5, 7, 4, 11, 3), 0.5),       # includes an empty tree
    (tuple(3 + (t % 6) * 4 for t in range(24)), 0.1),   # miss-heavy
])
def test_fused_matches_unfused(sizes, hit_rate):
    forest, trees = _forest(sizes, deep_every=3)
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, 96, hit_rate)
    ref = _unfused(state, qh, qt)
    got = retrieve_device(state, qh, qt, fused=True)
    _assert_same(ref, got)


def test_fused_single_filter_state():
    """from_index states (T == 1, dense arena) take the fused path too."""
    forest, trees = _forest((20, 8, 5))
    idx = build_index(forest, num_buckets=64)
    state = CFTDeviceState.from_index(idx)
    qh, _ = _queries(trees, 40, 0.7, oob=False)
    qt = jnp.zeros((40,), jnp.int32)
    _assert_same(_unfused(state, qh, qt),
                 retrieve_device(state, qh, qt, fused=True))


def test_fused_temperature_rounds():
    """Bump equivalence must hold *cumulatively*: thread each round's
    temperature forward on both paths and compare every round."""
    forest, trees = _forest((8, 12, 4, 9), deep_every=2)
    s_ref = CFTDeviceState.from_bank(build_bank(forest), forest)
    s_fus = CFTDeviceState.from_bank(build_bank(forest), forest)
    for rnd in range(4):
        qh, qt = _queries(trees, 64, 0.8, seed=rnd)
        ref = _unfused(s_ref, qh, qt)
        got = retrieve_device(s_fus, qh, qt, fused=True)
        _assert_same(ref, got, msg=f"round {rnd}")
        s_ref = s_ref.with_temperature(ref.temperature)
        s_fus = s_fus.with_temperature(got.temperature)


def test_fused_lookup_fn_conflict():
    forest, trees = _forest((4,))
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, 8, 1.0, oob=False)
    with pytest.raises(ValueError, match="lookup_fn"):
        retrieve_device(state, qh, qt, fused=True,
                        lookup_fn=lambda *a: None)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_fused_bit_identity_property(data):
    """Hypothesis sweep over forest shape, batch size, hit rate, and
    walk geometry: the fused pass is the unfused chain, bit for bit."""
    num_trees = data.draw(st.integers(min_value=1, max_value=12))
    sizes = tuple(
        data.draw(st.integers(min_value=0, max_value=18))
        for _ in range(num_trees))
    batch = data.draw(st.integers(min_value=1, max_value=150))
    hit_rate = data.draw(st.integers(min_value=0, max_value=10)) / 10.0
    max_locs = data.draw(st.integers(min_value=1, max_value=6))
    n = data.draw(st.integers(min_value=1, max_value=4))
    seed = data.draw(st.integers(min_value=0, max_value=999))
    forest, trees = _forest(sizes, deep_every=2, seed=seed)
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, batch, hit_rate, seed=seed)
    ref = _unfused(state, qh, qt, max_locs=max_locs, n=n)
    got = retrieve_device(state, qh, qt, max_locs=max_locs, n=n,
                          fused=True)
    _assert_same(ref, got, msg=f"seed={seed}")


# ------------------------------------------------- kernel variant agreement

def _arena_call(state, qh, qt, **kw):
    row_off, masks, valid = _routing(state, qh, qt)
    return fused_retrieve_arena(
        state.fingerprints, state.temperature, state.heads, row_off,
        masks, valid, qh, state.csr_offsets, state.csr_nodes,
        state.parent, state.entity_id, state.child_offsets,
        state.child_index, **kw)


@pytest.mark.parametrize("mxu", [False, True])
def test_tiled_vs_single_block(mxu):
    """Row-tiled grids (arena split past the VMEM budget) agree exactly
    with the resident single-block launch, in both gather strategies."""
    forest, trees = _forest(tuple(5 for _ in range(40)), deep_every=5)
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    assert state.fingerprints.shape[0] > 128     # tiling is exercised
    qh, qt = _queries(trees, 70, 0.6)
    ref = _arena_call(state, qh, qt, interpret=True, row_tile=0, mxu=mxu)
    got = _arena_call(state, qh, qt, interpret=True, row_tile=128, mxu=mxu)
    _assert_same(ref, got, msg=f"mxu={mxu}")
    # and both agree with the unfused oracle
    _assert_same(_unfused(state, qh, qt), ref, msg=f"oracle mxu={mxu}")


def test_mxu_matches_direct_gather():
    """The one-hot MXU matmul gathers (TPU strategy) are bit-identical
    to direct clipped indexing — f32-exactness of the dot-gather."""
    forest, trees = _forest((9, 2, 16, 0, 6), deep_every=2)
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, 50, 0.5)
    _assert_same(
        _arena_call(state, qh, qt, interpret=True, row_tile=0, mxu=False),
        _arena_call(state, qh, qt, interpret=True, row_tile=0, mxu=True))


def test_ref_matches_oracle():
    """The pure-jnp fused oracle (unrolled walks) is the unfused chain."""
    forest, trees = _forest((7, 3, 12, 5), deep_every=2)
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, 33, 0.6)
    row_off, masks, valid = _routing(state, qh, qt)
    got = fused_retrieve_ref(
        state.fingerprints, state.temperature, state.heads, row_off,
        masks, valid, qh, state.csr_offsets, state.csr_nodes,
        state.parent, state.entity_id, state.child_offsets,
        state.child_index)
    _assert_same(_unfused(state, qh, qt), got)


# --------------------------------------------------------- VMEM derivation

def test_vmem_budget_derivation():
    b = fused_vmem_budget()
    assert b.source in ("measured", "closed_form")
    assert b.per_row_bytes > 0
    assert b.budget_bytes == vmem.DEFAULT_VMEM_BYTES * vmem.BUDGET_FRACTION
    # the closed form upper-bounds the true footprint: a measured
    # per-row cost must never exceed it
    assert b.per_row_bytes <= vmem.closed_form_row_bytes(4, 128)


def test_vmem_budget_measured_on_cpu():
    """The CPU backend exposes memory_analysis(), so the derivation here
    must come from the compiled measurement, not the fallback."""
    assert fused_vmem_budget().source == "measured"


def test_max_rows_monotone():
    b = fused_vmem_budget()
    free = vmem.max_rows_for_vmem(b, 128, 0)
    assert free % 128 == 0 and free >= 128
    # resident context blocks shrink the probe-tile allowance
    assert vmem.max_rows_for_vmem(b, 128, b.budget_bytes // 2) <= free


# ----------------------------------------------------------- observability

def test_fused_obs_surface():
    reg = get_registry()
    forest, trees = _forest((6, 4))
    state = CFTDeviceState.from_bank(build_bank(forest), forest)
    qh, qt = _queries(trees, 16, 0.9, oob=False)
    before = reg.snapshot()["counters"].get("serve.fused_batches", 0)
    out = fused_retrieve_state_auto(state, qh, qt)
    assert out is not None
    snap = reg.snapshot()
    assert snap["counters"]["serve.fused_batches"] == before + 1
    assert snap["gauges"]["kernel.tile_rows"] == 0      # resident on CPU
    b = fused_vmem_budget()
    snap = reg.snapshot()["gauges"]
    assert snap[f"kernel.vmem_budget_bytes{{source={b.source}}}"] == \
        b.budget_bytes


def test_session_fused_flip_forgiven():
    """set_fused() is an intentional geometry change: the armed sentinel
    forgives exactly the flip's compile, then trips again."""
    from repro.serving.engine import RetrievalSession
    forest, trees = _forest((8, 5, 3))
    bank = build_bank(forest)
    sess = RetrievalSession()
    sess.attach(CFTDeviceState.from_bank(bank, forest), fused=True)
    qt = [0, 1, 2, 0]
    qh = [int(hashing.entity_hash(c)) for c in
          ("e0_0", "e1_1", "e2_2", "e0_3")]
    a = sess.retrieve(qt, qh)
    sess.sentinel.rebaseline()
    sess.sentinel.arm()
    sess.set_fused(False)
    b = sess.retrieve(qt, qh)
    assert sess.observe() == {}          # flip compile was forgiven
    sess.set_fused(True)
    c = sess.retrieve(qt, qh)
    assert sess.observe() == {}
    np.testing.assert_array_equal(np.asarray(a.hit), np.asarray(b.hit))
    np.testing.assert_array_equal(np.asarray(b.locations),
                                  np.asarray(c.locations))
    sess.sentinel.disarm()


def test_session_fused_matches_unfused():
    from repro.serving.engine import RetrievalSession
    forest, trees = _forest((10, 2, 7, 4), deep_every=2)
    bank = build_bank(forest)
    s_ref = RetrievalSession()
    s_ref.attach(CFTDeviceState.from_bank(bank, forest))
    s_fus = RetrievalSession()
    s_fus.attach(CFTDeviceState.from_bank(bank, forest), fused=True)
    qh, qt = _queries(trees, 48, 0.7)
    for rnd in range(3):
        a = s_ref.retrieve(list(np.asarray(qt)), list(np.asarray(qh)))
        b = s_fus.retrieve(list(np.asarray(qt)), list(np.asarray(qh)))
        _assert_same(a, b, msg=f"round {rnd}")
