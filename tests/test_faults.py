"""Fault-tolerant serving: deterministic fault injection, admission
control, deadlines, maintenance quarantine/rollback/recovery, the
circuit breaker, and shutdown drain semantics.

Everything here is clock-free (fake clocks passed explicitly) and uses
the :class:`FaultPlan` harness — "the second prepare raises", never
"some prepare eventually raises" — so the chaos suite is exactly
reproducible.  The module is marked ``chaos`` and runs in the slow CI
job next to the distribution tier.
"""
import numpy as np
import pytest

from repro.core import (CFTDeviceState, MaintenanceBreaker,
                        MaintenanceEngine, TenantRegistry, build_bank,
                        build_forest)
from repro.core import hashing
from repro.obs import get_registry
from repro.serving import (AsyncServeEngine, DeadlineExceeded, EngineClosed,
                           EngineOverloaded, FAULT_SITES, FaultPlan,
                           InjectedFault, PendingRetrieval, RetrievalSession,
                           active_plan, fault_point, inject)

pytestmark = pytest.mark.chaos


def _forest(num_trees=4, entities_per_tree=10):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _session(maint=True, forest=None, breaker=None, registry=None):
    forest = forest or _forest()
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    if maint:
        session.attach_maintenance(MaintenanceEngine(bank), forest,
                                   breaker=breaker, registry=registry)
    return forest, bank, session


def _queries(forest, bank, n):
    hashes = hashing.hash_entities(forest.entity_names)
    reqs = []
    for i in range(n):
        k = 1 + (i % 3)
        rows = [(i * 7 + j) % len(bank.row_entity) for j in range(k)]
        reqs.append(([int(bank.row_tree[r]) for r in rows],
                     [int(hashes[bank.row_entity[r]]) for r in rows]))
    return reqs


def _engine(session, now, **kw):
    kw.setdefault("latency_budget", 0.5)
    kw.setdefault("max_batch", 32)
    kw.setdefault("min_bucket", 4)
    kw.setdefault("maintenance", "inline")
    return AsyncServeEngine(session, clock=lambda: now[0], **kw)


def _state_equal(state, bank, forest):
    want = CFTDeviceState.from_bank(bank, forest)
    for n in ("fingerprints", "temperature", "heads", "bucket_offsets",
              "tree_nb", "csr_offsets", "csr_nodes"):
        got = np.asarray(getattr(state, n))
        exp = np.asarray(getattr(want, n))
        if not (got.shape == exp.shape and np.array_equal(got, exp)):
            return False
    return True


# ---------------------------------------------------------- fault harness

def test_fault_plan_fires_exact_ordinals():
    plan = FaultPlan({"prepare": 2, "commit": [1]})     # int = first-n
    fired = []
    for site in ("prepare", "prepare", "prepare", "commit", "commit"):
        try:
            plan.fire(site)
        except InjectedFault as e:
            fired.append((e.site, e.ordinal))
    assert fired == [("prepare", 0), ("prepare", 1), ("commit", 1)]
    assert plan.calls("prepare") == 3 and plan.calls("commit") == 2
    assert plan.hits() == 3 and plan.hits("commit") == 1
    assert plan.history == fired
    assert isinstance(InjectedFault("x", 0), RuntimeError)


def test_fault_point_is_noop_without_plan_and_nests():
    assert active_plan() is None
    for site in FAULT_SITES:
        fault_point(site)                                # must not raise
    outer, inner = FaultPlan({}), FaultPlan({"dispatch": [0]})
    with inject(outer):
        assert active_plan() is outer
        fault_point("dispatch")                          # outer arms nothing
        with inject(inner):
            with pytest.raises(InjectedFault):
                fault_point("dispatch")
        assert active_plan() is outer                    # restored
    assert active_plan() is None
    assert outer.calls("dispatch") == 1


def test_injected_faults_counted_by_site():
    reg = get_registry()
    c = reg.counter("faults.injected")
    before = c.value(site="prepare")
    with inject(FaultPlan({"prepare": [0]})):
        with pytest.raises(InjectedFault):
            fault_point("prepare")
    assert c.value(site="prepare") == before + 1


# ------------------------------------------------------- admission control

def test_overload_rejects_whole_submit():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off", max_queue_requests=2)
    reqs = _queries(forest, bank, 3)
    eng.submit(*reqs[0])
    eng.submit(*reqs[1])
    before = len(eng.batcher)
    with pytest.raises(EngineOverloaded) as ei:
        eng.submit(*reqs[2])
    assert ei.value.pending == 2 and ei.value.limit == 2
    assert isinstance(ei.value, RuntimeError)
    assert len(eng.batcher) == before                 # nothing half-admitted
    # draining the queue re-opens admission
    now[0] = 1.0
    eng.flush()
    f = eng.submit(*reqs[2])
    eng.flush(now[0])
    assert f.result().hit.shape[0] == len(reqs[2][1])
    assert get_registry().counter("serve.rejected").value(
        reason="overload") >= 1


def test_overload_all_or_nothing_for_chunked_submit():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off", max_batch=8,
                  max_queue_requests=3)
    # 20 queries chunk into 3 requests of <= 8; admitting them fills the
    # queue exactly
    t, h = ([0] * 20, [0] * 20)
    eng.submit(t, h)
    assert len(eng.batcher) == 3
    with pytest.raises(EngineOverloaded):
        eng.submit([0], [0])
    eng.flush(now[0])


# ----------------------------------------------------------- deadlines

def test_deadline_expires_in_queue():
    forest, bank, session = _session(maint=False)
    now = [10.0]
    eng = _engine(session, now, maintenance="off")
    t, h = _queries(forest, bank, 1)[0]
    f_dead = eng.submit(t, h, timeout=1.0)
    f_live = eng.submit(t, h)
    now[0] = 12.0                        # past the deadline, past budget
    eng.pump(now[0])
    with pytest.raises(DeadlineExceeded) as ei:
        f_dead.result(timeout=5)
    assert ei.value.deadline_t == 11.0 and ei.value.now >= 12.0
    r = f_live.result(timeout=5)         # the live request still served
    assert r.hit.shape[0] == len(h)
    assert get_registry().counter("serve.rejected").value(
        reason="deadline") >= 1


def test_deadline_enforced_at_dispatch():
    """The launch-time recheck: a request that expires between the queue
    sweep and the launch is failed, the rest of the batch serves."""
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off")
    t, h = _queries(forest, bank, 1)[0]
    live = PendingRetrieval(tree_ids=t, hashes=h, arrive_t=0.0)
    dead = PendingRetrieval(tree_ids=t, hashes=h, arrive_t=0.0,
                            deadline_t=0.5)
    assert eng._launch([live, dead], now=1.0) is True
    with pytest.raises(DeadlineExceeded):
        dead.future.result(timeout=5)
    assert live.future.result(timeout=5).hit.shape[0] == len(h)
    # a batch left with no live request launches nothing
    dead2 = PendingRetrieval(tree_ids=t, hashes=h, arrive_t=0.0,
                             deadline_t=0.5)
    assert eng._launch([dead2], now=1.0) is False
    with pytest.raises(DeadlineExceeded):
        dead2.future.result(timeout=5)


def test_default_timeout_applies_to_every_submit():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off", default_timeout=0.25)
    t, h = _queries(forest, bank, 1)[0]
    f = eng.submit(t, h)
    now[0] = 1.0
    eng.pump(now[0])
    with pytest.raises(DeadlineExceeded):
        f.result(timeout=5)


# ------------------------------------------------------------- shutdown

def test_stop_drains_then_submit_raises_engine_closed():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off")
    futs = [eng.submit(t, h) for t, h in _queries(forest, bank, 5)]
    eng.stop()
    for f in futs:                       # drain served everything queued
        assert f.done() and f.exception() is None
    with pytest.raises(EngineClosed):
        eng.submit([0], [0])
    assert get_registry().counter("serve.rejected").value(
        reason="closed") >= 1


def test_stop_fails_unlaunchable_pending_with_engine_closed(monkeypatch):
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off")
    futs = [eng.submit(t, h) for t, h in _queries(forest, bank, 3)]
    monkeypatch.setattr(eng, "flush", lambda *a, **k: 0)  # device is gone
    eng.close()                          # close() is the stop() alias
    for f in futs:
        assert f.done()
        with pytest.raises(EngineClosed):
            f.result()


def test_stop_with_dispatch_faults_still_resolves_everything():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off")
    futs = [eng.submit(t, h) for t, h in _queries(forest, bank, 4)]
    with inject(FaultPlan({"dispatch": 100})):
        eng.stop()
    for f in futs:
        assert f.done()
        with pytest.raises(InjectedFault):
            f.result()


# -------------------------------------------------------- oversized split

def test_oversized_submit_splits_and_concatenates():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off", max_batch=16)
    reqs = _queries(forest, bank, 40)
    tids = [t for ts, _ in reqs for t in ts]
    hs = [h for _, hss in reqs for h in hss]
    assert len(hs) > 16
    f = eng.submit(tids, hs)
    eng.flush(now[0])
    got = f.result(timeout=5)
    want = session.retrieve(tids, hs)
    assert got.hit.shape[0] == len(hs)
    np.testing.assert_array_equal(got.hit, np.asarray(want.hit))
    np.testing.assert_array_equal(got.locations, np.asarray(want.locations))
    np.testing.assert_array_equal(got.up, np.asarray(want.up))
    np.testing.assert_array_equal(got.down, np.asarray(want.down))


def test_oversized_chunk_failure_fails_the_aggregate():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off", max_batch=4)
    f = eng.submit([0] * 10, [0] * 10)          # 3 chunks
    with inject(FaultPlan({"dispatch": [1]})):
        eng.flush(now[0])
    assert f.done()
    with pytest.raises(InjectedFault):
        f.result()


# --------------------------------------------- maintenance fault domain

def test_prepare_fault_quarantines_then_full_restage_recovers():
    forest, bank, session = _session()
    coord = session.coord
    session.maint.queue_insert(0, "quarantined", [1])
    with inject(FaultPlan({"prepare": [0]})) as plan:
        with pytest.raises(InjectedFault):
            session.prepare_maintenance()
    assert plan.hits("prepare") == 1
    assert coord.dirty and coord.pending is None
    assert isinstance(coord.last_error, InjectedFault)
    # the fault fired before the maintain pass: bank and serving state
    # both still carry the pre-mutation content
    assert _state_equal(session.state, bank, forest)
    assert session.harvest() == 0                   # absorbs blocked
    # recovery without the plan: prepare stages a FULL plan (shadow was
    # invalidated), commit applies, and the state matches a fresh stage
    report = session.prepare_maintenance()
    assert report is not None
    assert coord.pending is not None and coord.pending.kind == "full"
    assert session.commit_maintenance()
    assert not coord.dirty
    assert coord.breaker.state == MaintenanceBreaker.CLOSED
    assert _state_equal(session.state, bank, forest)
    assert bank.lookup(0, int(hashing.hash_entities(["quarantined"])[0]))[0]


def test_commit_fault_rolls_back_to_served_state():
    forest, bank, session = _session()
    before = np.asarray(session.state.fingerprints).copy()
    session.maint.queue_insert(0, "late arrival", [1])
    session.prepare_maintenance()
    with inject(FaultPlan({"commit": [0]})):
        with pytest.raises(InjectedFault):
            session.commit_maintenance()
    # rollback: the session still serves the pre-commit state even
    # though the bank already advanced past it
    np.testing.assert_array_equal(np.asarray(session.state.fingerprints),
                                  before)
    assert session.coord.dirty
    session.prepare_maintenance()
    assert session.commit_maintenance()
    assert _state_equal(session.state, bank, forest)


def test_breaker_lifecycle_and_gauge():
    b = MaintenanceBreaker(threshold=2, cooldown=10.0, backoff=1.0)
    g = get_registry().gauge("maint.breaker_state")
    assert b.state == MaintenanceBreaker.CLOSED and b.allow(0.0)
    b.record_failure(0.0, "prepare")
    assert b.state == MaintenanceBreaker.CLOSED
    assert not b.allow(0.5) and b.allow(1.5)        # exponential backoff
    b.record_failure(2.0, "prepare")
    assert b.state == MaintenanceBreaker.OPEN and g.value() == 2
    assert not b.allow(11.0)                        # cooldown from t=2
    assert b.allow(12.5)                            # -> half-open probe
    assert b.state == MaintenanceBreaker.HALF_OPEN and g.value() == 1
    b.record_failure(13.0, "commit")                # probe failed
    assert b.state == MaintenanceBreaker.OPEN
    assert b.allow(23.5)
    b.record_success()
    assert b.state == MaintenanceBreaker.CLOSED and g.value() == 0
    assert get_registry().counter("maint.failures").value(
        phase="prepare") >= 2


def test_breaker_degrades_engine_to_serve_only_then_recovers():
    breaker = MaintenanceBreaker(threshold=1, cooldown=5.0, backoff=0.1)
    forest, bank, session = _session(breaker=breaker)
    now = [0.0]
    eng = _engine(session, now, commit_every=1)
    reqs = _queries(forest, bank, 6)
    session.maint.queue_insert(0, "blocked by breaker", [1])
    with inject(FaultPlan({"prepare": 100})):       # every prepare raises
        for i, (t, h) in enumerate(reqs[:3]):
            f = eng.submit(t, h)
            now[0] += 1.0
            eng.pump(now[0])
            assert f.result(timeout=5).hit.shape[0] == len(h)
    # one failure tripped the breaker: serve-only mode
    assert breaker.state == MaintenanceBreaker.OPEN
    assert session.coord.degraded
    assert isinstance(eng.last_maintenance_error, InjectedFault)
    # while open, pump never attempts maintenance (no plan active, so an
    # attempt would succeed and close the breaker — assert it stays open)
    f = eng.submit(*reqs[3])
    now[0] += 1.0
    eng.pump(now[0])
    f.result(timeout=5)
    assert breaker.state == MaintenanceBreaker.OPEN
    # past the cooldown the half-open probe succeeds and recovery lands
    now[0] += 10.0
    for t, h in reqs[4:]:
        f = eng.submit(t, h)
        now[0] += 1.0
        eng.pump(now[0])
        f.result(timeout=5)
    assert breaker.state == MaintenanceBreaker.CLOSED
    assert not session.coord.dirty
    assert _state_equal(session.state, bank, forest)
    eng.stop()


def test_dispatch_fault_fails_one_batch_not_the_engine():
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng = _engine(session, now, maintenance="off")
    reqs = _queries(forest, bank, 3)
    c_fail = get_registry().counter("serve.batch_failures")
    before = c_fail.value()
    results = []
    with inject(FaultPlan({"dispatch": [1]})) as plan:
        for t, h in reqs:
            f = eng.submit(t, h)
            now[0] += 1.0
            eng.pump(now[0])
            results.append(f)
    assert plan.hits("dispatch") == 1
    assert results[0].result(timeout=5).hit.shape[0] == len(reqs[0][1])
    with pytest.raises(InjectedFault):
        results[1].result(timeout=5)
    r2 = results[2].result(timeout=5)               # engine kept serving
    assert r2.hit.shape[0] == len(reqs[2][1])
    assert c_fail.value() == before + 1
    # outputs after the fault match an untouched reference session
    _, _, ref = _session(maint=False, forest=forest)
    want = ref.retrieve(*reqs[2])
    np.testing.assert_array_equal(r2.hit, np.asarray(want.hit))
    np.testing.assert_array_equal(r2.locations, np.asarray(want.locations))


# ------------------------------------------------ per-tenant fault domain

_RANGES = {"acme": (0, 2), "bravo": (2, 4)}


def test_tenant_fault_domain_isolates_victim():
    """A prepare fault while only the victim tenant has queued work trips
    the *victim's* breaker; the global breaker stays closed, the healthy
    tenant's maintenance keeps landing, and its answers stay
    bit-identical to a fault-free run of the same ops."""
    breaker = MaintenanceBreaker(threshold=1, cooldown=5.0, backoff=1.0)
    forest, bank, session = _session(breaker=breaker,
                                     registry=TenantRegistry(_RANGES))
    coord = session.coord
    session.maint.queue_insert(0, "victim write", [1])
    with inject(FaultPlan({"prepare": [0]})):
        with pytest.raises(InjectedFault):
            session.prepare_maintenance(now=0.0)
    # blame is attributed to the involved tenant, not the whole forest
    assert coord.degraded_tenants == ["acme"]
    assert coord.tenant_breakers["acme"].state == MaintenanceBreaker.OPEN
    assert "bravo" not in coord.tenant_breakers
    assert breaker.state == MaintenanceBreaker.CLOSED
    assert coord.allow(0.1)            # the global pump keeps preparing
    # dirty recovery flows with the victim's ops held back
    session.prepare_maintenance(now=1.0)
    session.commit_maintenance(now=1.0)
    assert not coord.dirty
    h_victim = int(hashing.hash_entities(["victim write"])[0])
    assert not bank.lookup(0, h_victim)[0]          # still held back
    # the healthy tenant's maintenance lands through the open window
    session.maint.queue_insert(2, "healthy write", [1])
    session.prepare_maintenance(now=2.0)
    session.commit_maintenance(now=2.0)
    h_healthy = int(hashing.hash_entities(["healthy write"])[0])
    assert bank.lookup(2, h_healthy)[0]
    assert _state_equal(session.state, bank, forest)
    # healthy answers bit-identical to a never-faulted run of the same op
    _, ref_bank, ref = _session(forest=forest)
    ref.maint.queue_insert(2, "healthy write", [1])
    ref.maintain()
    q = ([2, 3, 2], [h_healthy,
                     int(hashing.hash_entities(["entity 3_0"])[0]),
                     int(hashing.hash_entities(["entity 2_4"])[0])])
    got, want = session.retrieve(*q), ref.retrieve(*q)
    for n in ("hit", "locations", "up", "down"):
        np.testing.assert_array_equal(np.asarray(getattr(got, n)),
                                      np.asarray(getattr(want, n)))
    # past the cooldown the half-open probe releases the held ops and a
    # clean cycle closes the victim's breaker — full service restored
    session.prepare_maintenance(now=10.0)
    session.commit_maintenance(now=10.0)
    assert bank.lookup(0, h_victim)[0]
    assert coord.degraded_tenants == []
    assert coord.tenant_breakers["acme"].state == MaintenanceBreaker.CLOSED
    assert _state_equal(session.state, bank, forest)
    reg = get_registry()
    assert reg.counter("maint.failures").value(
        phase="prepare", tenant="acme") >= 1
    assert reg.gauge("tenant.breaker_state").value(tenant="acme") == 0


def test_breaker_half_open_recovery_under_repeated_commit_faults():
    """Pins the half-open protocol end to end at the coordinator level
    under repeated commit faults: open -> cooldown -> half-open probe
    whose commit faults -> open again -> second probe lands clean ->
    closed, with the queued mutation applied exactly once at the end.

    A successful prepare records a breaker success (pre-existing
    semantics: the closed-state failure streak resets every clean
    prepare), so commit faults trip the breaker through the threshold=1
    path and re-trip it straight from the probe cycle's failure."""
    breaker = MaintenanceBreaker(threshold=1, cooldown=5.0, backoff=1.0)
    forest, bank, session = _session(breaker=breaker)
    coord = session.coord
    session.maint.queue_insert(0, "slow landing", [1])
    with inject(FaultPlan({"commit": 2})):
        session.prepare_maintenance(now=0.0)
        with pytest.raises(InjectedFault):
            session.commit_maintenance(now=0.0)
        assert breaker.state == MaintenanceBreaker.OPEN
        assert coord.dirty
        assert not coord.allow(4.9)           # cooling down
        assert coord.allow(5.1)               # -> half-open probe window
        assert breaker.state == MaintenanceBreaker.HALF_OPEN
        session.prepare_maintenance(now=5.1)  # the probe's prepare is ok
        with pytest.raises(InjectedFault):
            session.commit_maintenance(now=5.1)   # ...but its commit isn't
        assert breaker.state == MaintenanceBreaker.OPEN   # probe failed
        assert not coord.allow(9.0)           # cooldown counts from t=5.1
    assert coord.allow(10.5)
    assert breaker.state == MaintenanceBreaker.HALF_OPEN
    session.prepare_maintenance(now=10.5)
    assert session.commit_maintenance(now=10.5)
    assert breaker.state == MaintenanceBreaker.CLOSED
    assert not coord.dirty
    assert bank.lookup(0, int(hashing.hash_entities(
        ["slow landing"])[0]))[0]
    assert _state_equal(session.state, bank, forest)


def test_tenant_lifecycle_fault_sites_fire_before_surgery():
    """Each lifecycle fault site fires *before* its state transition: an
    injected fault leaves bank, device state and registry residency
    exactly as served, and a clean retry completes the operation."""
    forest, bank, session = _session(registry=TenantRegistry(_RANGES))
    session.maintain()
    img = {n: getattr(bank, n).copy()
           for n in ("fingerprints", "heads", "tree_nb", "num_items")}
    with inject(FaultPlan({"evict": [0]})) as plan:
        with pytest.raises(InjectedFault):
            session.evict_tenant("acme")
    assert plan.hits("evict") == 1
    assert session.tenants.resident("acme")
    assert not session.maint.pinned.any()
    for n, want in img.items():
        np.testing.assert_array_equal(getattr(bank, n), want)
    assert _state_equal(session.state, bank, forest)
    session.evict_tenant("acme")                      # clean retry
    # reload: a fault leaves the tenant cold and pinned
    with inject(FaultPlan({"reload": [0]})):
        with pytest.raises(InjectedFault):
            session.reload_tenant("acme")
    assert not session.tenants.resident("acme")
    assert session.maint.pinned[0:2].all()
    session.reload_tenant("acme")
    assert session.tenants.resident("acme")
    assert _state_equal(session.state, bank, forest)
    # offboard shares the evict site; onboard has its own
    with inject(FaultPlan({"evict": [0]})):
        with pytest.raises(InjectedFault):
            session.offboard_tenant("bravo")
    assert session.tenants.resident("bravo")
    cold = session.offboard_tenant("bravo")
    with inject(FaultPlan({"onboard": [0]})):
        with pytest.raises(InjectedFault):
            session.onboard_tenant("bravo", cold)
    assert not session.tenants.resident("bravo")
    session.onboard_tenant("bravo", cold)
    assert session.tenants.resident("bravo")
    assert _state_equal(session.state, bank, forest)
