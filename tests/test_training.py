"""Training substrate: optimizer, accumulation, checkpointing, fault loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import HashTokenizer, PackedBatches, TextDataset, hospital_corpus
from repro.models import init_params
from repro.training import (AdamWConfig, LoopConfig, SimulatedPreemption,
                            TrainLoop, adamw_init, latest_step,
                            make_train_step, quantize_grads_int8, restore,
                            save, schedule_lr)


def _setup(arch="qwen2-0.5b", **cfg_kw):
    cfg = get_arch(arch).smoke().replace(**cfg_kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pipeline(cfg, batch=4, seq=32):
    corpus = hospital_corpus(num_trees=8)
    tok = HashTokenizer(cfg.vocab)
    ds = TextDataset(corpus.documents, tok)
    return PackedBatches(ds, batch_size=batch, seq_len=seq, prefetch=False)


def test_loss_decreases():
    cfg, params = _setup()
    pb = _pipeline(cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2,
                                                    total_steps=32)))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        b = {k: jnp.asarray(v) for k, v in pb.next_batch().items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_accumulation_equivalence():
    """mb=1 and mb=4 produce the same update (up to f32 accumulation)."""
    cfg, params = _setup()
    pb = _pipeline(cfg, batch=8)
    b = {k: jnp.asarray(v) for k, v in pb.next_batch().items()}
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    p1, _, m1 = make_train_step(cfg, ocfg, microbatches=1)(
        params, adamw_init(params), b)
    p4, _, m4 = make_train_step(cfg, ocfg, microbatches=4)(
        params, adamw_init(params), b)
    # loss is averaged over microbatches; token masks are uniform here
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule_lr(cfg, jnp.int32(0))) < 0.2
    assert float(schedule_lr(cfg, jnp.int32(10))) > 0.9
    assert float(schedule_lr(cfg, jnp.int32(99))) < 0.2


def test_int8_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    deq, err = quantize_grads_int8(g)
    # dequantized + residual reconstructs exactly
    np.testing.assert_allclose(np.asarray(deq["w"]) + np.asarray(err["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    rel = (np.abs(np.asarray(deq["w"] - g["w"])).max()
           / np.abs(np.asarray(g["w"])).max())
    assert rel < 0.01


def test_checkpoint_roundtrip_and_cleanup():
    cfg, params = _setup()
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        tree = {"params": params, "opt": opt._asdict()}
        for s in (1, 2, 3, 4):
            save(d, s, tree, extra={"pipeline": {"epoch": s, "cursor": 7}})
        assert latest_step(d) == 4
        got, step, extra = restore(d, tree)
        assert step == 4 and extra["pipeline"]["cursor"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        from repro.training import cleanup
        cleanup(d, keep_last=2)
        assert latest_step(d) == 4
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2


def test_preemption_resume_exact():
    """Preempt at step 3, resume, and land on the identical final state as
    an uninterrupted run (pipeline state travels in the checkpoint)."""
    cfg, params0 = _setup()
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6)
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    def run(ckpt_dir, interrupt):
        pb = _pipeline(cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        def batches():
            while True:
                yield {k: jnp.asarray(v) for k, v in pb.next_batch().items()}
        lc = LoopConfig(total_steps=6, ckpt_dir=ckpt_dir, ckpt_every=1,
                        log_every=100)
        loop = TrainLoop(lc, step_fn, params, opt, batches(), pipeline=pb,
                         log=lambda *_: None)
        if interrupt:
            try:
                loop.run(max_steps=3)
            except SimulatedPreemption:
                pass
            loop2 = TrainLoop(lc, step_fn, init_params(cfg, jax.random.PRNGKey(9)),
                              adamw_init(params), batches(), pipeline=pb,
                              log=lambda *_: None)
            loop2.run()
            return loop2.params
        loop.run()
        return loop.params

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        p_int = run(d1, interrupt=True)
        p_full = run(d2, interrupt=False)
    for a, b in zip(jax.tree.leaves(p_int), jax.tree.leaves(p_full)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)
