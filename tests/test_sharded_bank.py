"""Host-side bank-axis sharding: partition planning, FilterBank.shard
slicing, merged/packed layouts, and shard-routed maintenance — all pure
numpy, no device mesh needed (the shard_map path is covered by
tests/test_distributed.py subprocesses)."""
import numpy as np
import pytest

from repro.core import (MaintenanceEngine, ShardedMaintenanceEngine,
                        build_bank, build_forest, plan_partition)
from repro.core import hashing
from repro.core.cuckoo import NULL


def _bank(num_trees=12, entities_per_tree=10):
    forest = build_forest(
        [[(f"r{t}", f"e{t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])
    return forest, build_bank(forest)


# ------------------------------------------------------------- partition

def test_plan_partition_contiguous_balanced():
    w = np.asarray([5, 5, 5, 5, 1, 1, 1, 1], float)
    starts = plan_partition(w, 4)
    assert starts[0] == 0 and starts[-1] == w.size
    assert (np.diff(starts) >= 1).all()
    # balanced by weight: no shard exceeds the ideal share by more than
    # one tree's worth
    shares = [w[starts[d]:starts[d + 1]].sum() for d in range(4)]
    assert max(shares) <= w.sum() / 4 + w.max()


def test_plan_partition_equal_weights_split_evenly():
    starts = plan_partition(np.ones(16), 8)
    assert np.diff(starts).tolist() == [2] * 8


def test_plan_partition_zero_weights_and_errors():
    assert plan_partition(np.zeros(6), 3)[-1] == 6
    with pytest.raises(ValueError):
        plan_partition(np.ones(3), 4)      # fewer trees than shards
    with pytest.raises(ValueError):
        plan_partition(np.ones(3), 0)


# ----------------------------------------------------------------- shard

def test_shard_slices_answer_identically():
    forest, bank = _bank()
    sbank = bank.shard(4)
    assert sbank.num_trees == bank.num_trees
    assert (sbank.num_items == bank.num_items).all()
    for t in range(bank.num_trees):
        for i in range(10):
            name = f"e{t}_{i}"
            assert sbank.locate(t, name) == bank.locate(t, name)
            h = int(hashing.entity_hash(name))
            assert sbank.contains(t, h) == bank.contains(t, h)
    assert not sbank.contains(0, int(hashing.entity_hash("missing")))


def test_shard_merged_tables_match_original():
    _, bank = _bank()
    sbank = bank.shard(3)
    mf, mt, mh = sbank.merged_tables()
    # fingerprint/temperature slot layout is sliced, never rebuilt
    np.testing.assert_array_equal(mf, bank.fingerprints)
    np.testing.assert_array_equal(mt, bank.temperature)
    moff, mnb = sbank.merged_layout()
    np.testing.assert_array_equal(moff, bank.bucket_offsets)
    np.testing.assert_array_equal(mnb, bank.tree_nb)
    # heads are renumbered (merged rows) but walk to identical node lists
    occ = mf != hashing.EMPTY_FP
    assert (mh[occ] >= 0).all()
    for r, s in zip(*np.nonzero(occ)):
        assert sbank.walk_row(int(mh[r, s])) == \
            bank.walk_row(int(bank.heads[r, s]))
    assert (mh[~occ] == NULL).all()


def test_shard_row_base_and_walk_row():
    _, bank = _bank()
    sbank = bank.shard(4)
    base = sbank.shard_row_base()
    assert int(base[-1]) == bank.num_rows
    hit, row, _ = sbank.lookup(5, int(hashing.entity_hash("e5_3")))
    assert hit
    d, _ = sbank.owner(5)
    assert base[d] <= row < base[d + 1]
    assert sorted(sbank.walk_row(row)) == sorted(bank.locate(5, "e5_3"))


def test_shard_bad_partitions_rejected():
    _, bank = _bank(num_trees=6)
    with pytest.raises(ValueError):
        bank.shard(tree_starts=[0, 2, 4])          # does not cover T
    with pytest.raises(ValueError):
        bank.shard(tree_starts=[0, 3, 3, 6])       # empty shard
    with pytest.raises(ValueError):
        bank.shard()                               # neither arg


def test_packed_tables_geometry_and_padding():
    _, bank = _bank(num_trees=10)                  # uneven over 4 shards
    sbank = bank.shard(4)
    ap = sbank.arena_rows_per_shard
    assert 4 * ap > sbank.total_buckets            # padding really exists
    fps, temp, heads = sbank.packed_tables()
    assert fps.shape == (4 * ap, sbank.slots)
    for d, b in enumerate(sbank.banks):
        blk = fps[d * ap:(d + 1) * ap]
        np.testing.assert_array_equal(blk[:b.total_buckets],
                                      b.fingerprints)
        # padding rows hold only empty fingerprints / NULL heads
        assert (blk[b.total_buckets:] == hashing.EMPTY_FP).all()
        assert (heads[d * ap + b.total_buckets:(d + 1) * ap] == NULL).all()


# ----------------------------------------------------------- maintenance

def test_sharded_maintenance_routes_to_owner_only():
    _, bank = _bank()
    sbank = bank.shard(4)
    eng = ShardedMaintenanceEngine(sbank)
    target = 7
    owner, _ = sbank.owner(target)
    snaps = [b.fingerprints.tobytes() for b in sbank.banks]

    nodes = sorted(sbank.locate(target, f"e{target}_0"))
    eng.insert(target, "fresh entity", nodes)
    assert sbank.locate(target, "fresh entity") == nodes
    assert eng.delete(target, f"e{target}_0")
    assert sbank.locate(target, f"e{target}_0") == []
    for d, b in enumerate(sbank.banks):
        changed = b.fingerprints.tobytes() != snaps[d]
        assert changed == (d == owner)
    st = eng.stats
    assert st["inserted"] == 1 and st["deleted"] == 1

    with pytest.raises(ValueError):
        eng.queue_insert(sbank.num_trees, "x", [])  # out of range


def test_sharded_expand_tree_owner_only():
    _, bank = _bank()
    sbank = bank.shard(4)
    eng = ShardedMaintenanceEngine(sbank)
    hot = 2
    owner, lt = sbank.owner(hot)
    nb0 = [b.tree_nb.copy() for b in sbank.banks]
    assert eng.expand_tree(hot, force=True)
    for d, b in enumerate(sbank.banks):
        if d == owner:
            # only the hot TREE grew — even within the owning shard
            assert b.tree_nb[lt] == 2 * nb0[d][lt]
            assert (np.delete(b.tree_nb, lt)
                    == np.delete(nb0[d], lt)).all()
        else:
            assert np.array_equal(b.tree_nb, nb0[d])
    # answers survive the tree-local restage
    for i in range(10):
        assert sbank.locate(hot, f"e{hot}_{i}") == bank.locate(
            hot, f"e{hot}_{i}")
    # below-threshold request without force is a no-op
    assert not eng.expand_tree(hot)


def test_absorb_temperature_per_shard_baselines():
    _, bank = _bank(num_trees=10)                  # padded packed layout
    sbank = bank.shard(4)
    eng = ShardedMaintenanceEngine(sbank)
    fps, temp, heads = sbank.packed_tables()
    # bump two slots on different shards + poison every padding slot: the
    # harvest must count only owner-block deltas
    ap = sbank.arena_rows_per_shard
    occ = fps != hashing.EMPTY_FP
    rows, slots = np.nonzero(occ)
    r0, s0 = int(rows[0]), int(slots[0])           # first shard's block
    temp[r0, s0] += 3
    r1, s1 = int(rows[-1]), int(slots[-1])         # last shard's block
    assert r0 // ap != r1 // ap                    # really two shards
    temp[r1, s1] += 2
    in_block = np.zeros(fps.shape, bool)
    for d, b in enumerate(sbank.banks):
        in_block[d * ap:d * ap + b.total_buckets] = True
    temp[~in_block] += 100                         # must be ignored
    assert eng.absorb(temp) == 5
    assert sum(int(b.temperature.sum()) for b in sbank.banks) == 5
    # second absorb of the identical state: zero new bumps
    assert eng.absorb(temp) == 0
    with pytest.raises(ValueError):
        eng.absorb(np.zeros((1, 2, 3), np.int32))  # stale layout


def test_shard_drops_tombstoned_rows():
    """A maintained bank's dead CSR rows must not cross into the shards:
    the per-shard engines rebuild liveness from slots, so a dangling row
    would resurrect as a phantom hash-0 entry on the next restage."""
    _, bank = _bank()
    glob = MaintenanceEngine(bank)
    assert glob.delete(3, "e3_0")              # tombstones the CSR row
    dead_rows = glob.num_dead_rows
    assert dead_rows == 1
    sbank = bank.shard(4)
    assert sbank.num_rows == bank.num_rows - dead_rows
    eng = ShardedMaintenanceEngine(sbank)
    items_before = int(sbank.num_items.sum())
    assert eng.expand_tree(3, force=True)      # owner-local restage
    assert not sbank.contains(3, 0)            # no phantom hash-0 entry
    assert sbank.locate(3, "e3_0") == []
    assert int(sbank.num_items.sum()) == items_before
    # the surviving entities all still answer
    for i in range(1, 10):
        assert sorted(sbank.locate(3, f"e3_{i}")) == \
            sorted(bank.locate(3, f"e3_{i}"))


def test_sharded_maintenance_matches_global_engine():
    """The same op sequence through a global MaintenanceEngine and a
    sharded one ends in identically answering banks."""
    forest, bank_a = _bank()
    _, bank_b = _bank()
    glob = MaintenanceEngine(bank_a)
    shrd = ShardedMaintenanceEngine(bank_b.shard(3))
    ops = [("del", 1, "e1_0"), ("del", 8, "e8_5"),
           ("ins", 1, "alpha"), ("ins", 11, "beta"), ("del", 1, "alpha")]
    for kind, t, name in ops:
        if kind == "ins":
            nodes = sorted(bank_a.locate(t, f"e{t}_1"))
            glob.queue_insert(t, name, nodes)
            shrd.queue_insert(t, name, nodes)
        else:
            glob.queue_delete(t, name)
            shrd.queue_delete(t, name)
    glob.maintain()
    shrd.maintain()
    for t in range(bank_a.num_trees):
        for name in [f"e{t}_{i}" for i in range(10)] + ["alpha", "beta"]:
            assert sorted(glob.bank.locate(t, name)) == \
                sorted(shrd.sbank.locate(t, name)), (t, name)
