"""Observability layer: registry semantics, exporters, tracing, the
recompile sentinel (injected shape-instability + healthy padded churn),
and the pure-JSON packing_stats contract."""
import json
import threading

import numpy as np
import pytest

import repro.core.maintenance as maintenance_mod
from repro.core import (CFTDeviceState, MaintenanceEngine,
                        ShardedMaintenanceEngine, build_bank, build_forest,
                        estimate_fpr)
from repro.core import hashing
from repro.obs import (HotPathRecompileError, MetricsRegistry,
                       PeriodicLogger, RecompileSentinel, Tracer,
                       get_registry, state_shapes)
from repro.serving import AsyncServeEngine, RetrievalSession


def _forest(num_trees=4, entities_per_tree=10):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _session(maint=True, forest=None):
    forest = forest or _forest()
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    if maint:
        session.attach_maintenance(MaintenanceEngine(bank), forest)
    return forest, bank, session


# --------------------------------------------------------------- registry

def test_counter_gauge_histogram_basics():
    r = MetricsRegistry()
    c = r.counter("t.count")
    c.inc()
    c.inc(4)
    assert c.value() == 5
    c.inc(bucket=32)
    c.inc(2, bucket=64)
    assert c.value(bucket=32) == 1 and c.value(bucket=64) == 2
    assert c.value() == 5                      # unlabeled cell untouched

    g = r.gauge("t.gauge")
    g.set(7)
    g.set(3)
    g.add(2)
    assert g.value() == 5

    h = r.histogram("t.lat_s")
    for v in (1e-4, 2e-4, 4e-4, 1e-3, 1e-2):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["min"] == pytest.approx(1e-4)
    assert s["max"] == pytest.approx(1e-2)
    # log2 buckets: quantiles carry <= 2x resolution around the truth
    assert 2e-4 <= s["p50"] <= 8e-4
    assert s["p99"] == pytest.approx(1e-2)

    # get-or-create: same name -> same object; kind conflicts fail loudly
    assert r.counter("t.count") is c
    with pytest.raises(TypeError):
        r.gauge("t.count")


def test_disabled_registry_mutates_nothing():
    r = MetricsRegistry(enabled=False)
    c = r.counter("t.c")
    c.inc(100)
    r.gauge("t.g").set(5)
    r.histogram("t.h").observe(1.0)
    assert c.value() == 0
    snap = r.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"]["t.h"]["count"] == 0
    # spans become the shared no-op while disabled
    t = Tracer(r)
    sp = t.span("t.span")
    with sp.stage("x"):
        pass
    sp.end()
    assert t.recent() == []
    r.enable()
    c.inc()
    assert c.value() == 1


def test_registry_thread_safety_exact_totals():
    r = MetricsRegistry()
    c = r.counter("t.racy")
    h = r.histogram("t.racy_s")
    n_threads, per = 8, 2000

    def worker():
        for _ in range(per):
            c.inc()
            h.observe(1e-3)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == n_threads * per
    assert h.summary()["count"] == n_threads * per


def test_snapshot_json_round_trip_and_prometheus_completeness():
    r = MetricsRegistry()
    r.counter("serve.batches").inc(3)
    r.counter("serve.batch_bucket").inc(bucket=32)
    r.gauge("serve.compile_cache_size").set(5)
    r.histogram("serve.dispatch_s").observe(2e-3)
    r.histogram("t.empty")                     # registered, no samples

    snap = r.snapshot()
    assert snap == json.loads(json.dumps(snap))   # round-trips untouched

    text = r.to_prometheus()
    # every registered metric emits (counter -> _total, labels quoted)
    assert "serve_batches_total 3" in text
    assert 'serve_batch_bucket_total{bucket="32"} 1' in text
    assert "serve_compile_cache_size 5" in text
    assert 'serve_dispatch_s{quantile="0.50"}' in text
    assert "serve_dispatch_s_count 1" in text
    assert "t_empty_count 0" in text
    for name in r.names():
        assert name.replace(".", "_") in text


def test_periodic_logger_ships_snapshots():
    r = MetricsRegistry()
    r.counter("t.c").inc()
    lines = []
    log = PeriodicLogger(r, interval=0.01, sink=lines.append)
    with log:
        import time
        time.sleep(0.05)
    assert lines                               # at least the stop() flush
    assert json.loads(lines[-1])["counters"]["t.c"] == 1


# ---------------------------------------------------------------- tracing

def test_tracer_spans_aggregate_into_histograms():
    r = MetricsRegistry()
    t = Tracer(r)
    with t.span("serve.batch", bucket=32) as sp:
        with sp.stage("dispatch"):
            pass
        sp.add_stage("coalesce", 0.25)
    spans = t.recent()
    assert len(spans) == 1
    assert spans[0]["attrs"] == {"bucket": 32}
    assert [s["stage"] for s in spans[0]["stages"]] == ["dispatch",
                                                        "coalesce"]
    assert r.histogram("trace.serve.batch").summary()["count"] == 1
    s = r.histogram("trace.serve.batch.coalesce").summary()
    assert s["count"] == 1 and s["min"] == pytest.approx(0.25)
    assert json.dumps(spans)                   # ring entries are JSON


def test_span_exception_path_records_stage_and_propagates():
    """A raise inside a staged span (the dispatch-fault path) must not
    swallow the exception — and the stage/span histograms still record,
    so fault-window latencies show up in the same telemetry as healthy
    ones."""
    r = MetricsRegistry()
    t = Tracer(r)
    with pytest.raises(KeyError):
        with t.span("serve.batch", bucket=8) as sp:
            with sp.stage("dispatch"):
                raise KeyError("boom")
    assert r.histogram("trace.serve.batch").summary()["count"] == 1
    assert r.histogram("trace.serve.batch.dispatch").summary()["count"] == 1
    spans = t.recent()
    assert len(spans) == 1
    assert [s["stage"] for s in spans[0]["stages"]] == ["dispatch"]
    # an explicit error attribute (what _launch sets) rides the ring
    with pytest.raises(ValueError):
        with t.span("serve.batch") as sp:
            try:
                raise ValueError("boom")
            except ValueError as exc:
                sp.set(error=type(exc).__name__)
                raise
    assert t.recent()[-1]["attrs"] == {"error": "ValueError"}


def test_disabled_registry_exception_path_stays_silent():
    """With metrics off, the error path must cost nothing and record
    nothing — while still re-raising."""
    r = MetricsRegistry(enabled=False)
    t = Tracer(r)
    c = r.counter("t.err")
    with pytest.raises(ValueError):
        with t.span("serve.batch") as sp:
            with sp.stage("dispatch"):
                c.inc(reason="x")              # the error-path counter
                raise ValueError("boom")
    assert t.recent() == []
    assert c.value(reason="x") == 0
    snap = r.snapshot()
    assert snap["counters"] == {} and "trace.serve.batch" \
        not in snap["histograms"]


# --------------------------------------------------------------- sentinel

def test_sentinel_watch_check_rebaseline_and_arm():
    import jax
    import jax.numpy as jnp
    r = MetricsRegistry()
    s = RecompileSentinel(r)
    f = jax.jit(lambda x: x * 2)
    if not s.watch("f", f):
        pytest.skip("backend does not expose the jit cache size")
    f(jnp.ones(2))
    assert s.check() == {"f": 1}
    assert s.recompiles == 1
    assert s.check() == {}                     # re-baselined
    s.rebaseline()
    f(jnp.ones(3))
    s.arm()
    with pytest.raises(HotPathRecompileError):
        s.check()
    s.disarm()
    # an expected geometry change forgives exactly one growth
    s.allow_next()
    f(jnp.ones(4))
    assert s.check() == {}
    assert s.recompiles == 2                   # the armed one counted too
    f(jnp.ones(5))
    assert s.check() == {"f": 1}               # forgiveness was one-shot


def test_sentinel_commit_shape_classification():
    r = MetricsRegistry()
    s = RecompileSentinel(r)
    a = {"fingerprints": (8, 4), "csr_offsets": (256,)}
    b = {"fingerprints": (16, 4), "csr_offsets": (256,)}
    assert s.note_commit("delta", a, dict(a)) == []
    assert s.note_commit("segment", a, b) == ["fingerprints"]
    assert s.note_commit("delta", a, b) == ["fingerprints"]   # counts only
    c = r.counter("maint.commit_shape_changes")
    assert c.value(expected="true", kind="segment") == 1
    assert c.value(expected="false", kind="delta") == 1
    s.arm()
    with pytest.raises(HotPathRecompileError):
        s.note_commit("delta", a, b)
    s.note_commit("full", a, b)                # expected kinds never raise


def _pump_through_commit(eng, session, reqs, now):
    """Two deterministic pumps: prepare under batch 1, commit after
    batch 2 (commit_every=2)."""
    eng.submit(*reqs[0]); now[0] += 1; eng.pump(now[0])
    assert session.coord.deferring
    eng.submit(*reqs[1]); now[0] += 1; eng.pump(now[0])
    assert not session.coord.deferring


def test_sentinel_catches_unpadded_csr_commit(monkeypatch):
    """The PR 6 pathology, injected: bypassing pad_csr stages a CSR at
    its raw length, the delta commit changes the committed shape, and
    the next dispatch recompiles the hot path — all of which the
    sentinel must report."""
    forest, bank, session = _session(maint=True)
    hashes = hashing.hash_entities(forest.entity_names)
    reqs = [([int(bank.row_tree[i])], [int(hashes[bank.row_entity[i]])])
            for i in range(4)]
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                           min_bucket=4, commit_every=2, commit_deadline=1e9,
                           clock=lambda: now[0], maintenance="inline")
    eng.warmup()
    if session.compile_cache_size() < 0:
        pytest.skip("backend does not expose the jit cache size")
    assert eng.hot_recompiles == 0

    monkeypatch.setattr(
        maintenance_mod, "pad_csr",
        lambda off, nodes, chunk=256: (np.asarray(off, np.int32),
                                       np.asarray(nodes, np.int32)))
    session.maint.queue_insert(0, "unpadded entity", [1])
    before = state_shapes(session.state)
    _pump_through_commit(eng, session, reqs, now)
    after = state_shapes(session.state)
    assert before["csr_nodes"] != after["csr_nodes"]   # the injected leak
    c = session.metrics.counter("maint.commit_shape_changes")
    assert c.value(expected="false", kind="delta") >= 1

    # the next batch pays the recompile; the sentinel attributes it
    eng.submit(*reqs[2]); now[0] += 1; eng.pump(now[0])
    assert eng.hot_recompiles >= 1


def test_armed_sentinel_fails_loudly_on_unpadded_commit(monkeypatch):
    forest, bank, session = _session(maint=True)
    hashes = hashing.hash_entities(forest.entity_names)
    reqs = [([int(bank.row_tree[i])], [int(hashes[bank.row_entity[i]])])
            for i in range(4)]
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                           min_bucket=4, commit_every=2, commit_deadline=1e9,
                           clock=lambda: now[0], maintenance="inline")
    eng.warmup()
    monkeypatch.setattr(
        maintenance_mod, "pad_csr",
        lambda off, nodes, chunk=256: (np.asarray(off, np.int32),
                                       np.asarray(nodes, np.int32)))
    session.sentinel.arm()
    session.maint.queue_insert(0, "loud entity", [1])
    with pytest.raises(HotPathRecompileError):
        _pump_through_commit(eng, session, reqs, now)


def test_padded_churn_never_recompiles():
    """The healthy path: inserts/deletes through the normal pad_csr
    staging keep every committed shape stable — zero hot-path
    recompiles across the whole churn schedule."""
    forest, bank, session = _session(maint=True)
    hashes = hashing.hash_entities(forest.entity_names)
    nrows = len(bank.row_entity)
    reqs = [([int(bank.row_tree[i % nrows])],
             [int(hashes[bank.row_entity[i % nrows]])])
            for i in range(12)]
    now = [0.0]
    eng = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                           min_bucket=4, commit_every=2, commit_deadline=1e9,
                           clock=lambda: now[0], maintenance="inline")
    eng.warmup()
    if session.compile_cache_size() < 0:
        pytest.skip("backend does not expose the jit cache size")
    baseline = session.compile_cache_size()
    session.sentinel.arm()                     # any recompile is fatal
    for i, (t, h) in enumerate(reqs):
        if i % 3 == 0:
            session.maint.queue_insert(i % 4, f"churn {i}", [1])
        if i % 3 == 2 and i >= 2:              # delete what i-2 inserted
            session.maint.queue_delete((i - 2) % 4, f"churn {i - 2}")
        eng.submit(t, h)
        now[0] += 1
        eng.pump(now[0])
    assert eng.stats.commits >= 2
    assert eng.hot_recompiles == 0
    assert session.compile_cache_size() == baseline


# ----------------------------------------------------------- packing_stats

def _assert_pure_json(stats):
    assert json.loads(json.dumps(stats)) == stats
    for key in ("load", "tree_nb", "ideal_nb", "est_fpr"):
        assert isinstance(stats[key], list)
        assert all(type(x) in (int, float) for x in stats[key])
    for key in ("arena_rows", "ideal_rows", "dead_rows"):
        assert type(stats[key]) is int
    assert type(stats["overprovision"]) is float


def test_packing_stats_pure_python_replicated_and_sharded():
    forest = _forest(num_trees=6)
    bank = build_bank(forest)
    eng = MaintenanceEngine(bank)
    stats = eng.packing_stats()
    _assert_pure_json(stats)
    assert len(stats["est_fpr"]) == bank.num_trees

    sbank = build_bank(_forest(num_trees=6)).shard(2)
    seng = ShardedMaintenanceEngine(sbank)
    sstats = seng.packing_stats()
    _assert_pure_json(sstats)
    assert len(sstats["load"]) == 6            # global tree order
    assert sstats["arena_rows"] == stats["arena_rows"]


def test_estimate_fpr_formula_and_monotonicity():
    assert estimate_fpr(0.0, 4) == 0.0
    lo, hi = estimate_fpr(0.25, 4), estimate_fpr(0.95, 4)
    assert 0.0 < lo < hi < 1.0
    # matches the closed form at a spot value
    p = 1.0 / (2 ** hashing.FP_BITS - 1)
    want = 1.0 - (1.0 - p) ** (2 * 4 * 0.5)
    assert estimate_fpr(0.5, 4) == pytest.approx(want)
    arr = estimate_fpr(np.array([0.1, 0.9]), 4)
    assert arr.shape == (2,) and arr[0] < arr[1]
    # per-tree estimates ride in packing_stats (the ROADMAP's surface)
    bank = build_bank(_forest())
    stats = MaintenanceEngine(bank).packing_stats()
    np.testing.assert_allclose(
        stats["est_fpr"], estimate_fpr(bank.load_factors, bank.slots))


# ----------------------------------------------------- engine integration

def test_async_engine_stats_are_registry_deltas():
    """Two sequential engines on the shared process registry must not
    see each other's counts (the compat shim subtracts its baseline)."""
    forest, bank, session = _session(maint=False)
    now = [0.0]
    eng1 = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                            min_bucket=4, clock=lambda: now[0],
                            maintenance="off")
    hashes = hashing.hash_entities(forest.entity_names)
    req = ([int(bank.row_tree[0])], [int(hashes[bank.row_entity[0]])])
    eng1.submit(*req); now[0] += 1; eng1.pump(now[0])
    assert eng1.stats.batches == 1 and eng1.stats.requests == 1

    eng2 = AsyncServeEngine(session, latency_budget=0.0, max_batch=32,
                            min_bucket=4, clock=lambda: now[0],
                            maintenance="off")
    assert eng2.stats.batches == 0             # baseline excludes eng1
    eng2.submit(*req); now[0] += 1; eng2.pump(now[0])
    assert eng2.stats.batches == 1
    assert eng1.stats.batches == 2             # eng1 keeps counting on
    assert eng2.stats.bucket_histogram == {4: 1}
    # the registry itself carries the process-wide compile gauge
    assert (get_registry().gauge("serve.compile_cache_size").value()
            == session.compile_cache_size())
