"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_forest, build_index
from repro.core import hashing
from repro.kernels.cuckoo_lookup import cuckoo_lookup, cuckoo_lookup_ref
from repro.kernels.decode_attention import (combine_partial_attention,
                                            decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.linear_scan import linear_scan, linear_scan_ref

RNG = np.random.default_rng(42)


# ------------------------------------------------------------ cuckoo lookup

@pytest.mark.parametrize("num_buckets,n_entities,batch",
                         [(64, 100, 16), (256, 500, 130), (1024, 3000, 256),
                          (2048, 5000, 97)])
def test_cuckoo_lookup_sweep(num_buckets, n_entities, batch):
    trees = [[(f"r{t}", f"e{t}_{i}") for i in range(n_entities // 40)]
             for t in range(40)]
    forest = build_forest(trees)
    idx = build_index(forest, num_buckets=num_buckets)
    t = idx.filter.tables()
    fps, heads = jnp.asarray(t.fingerprints), jnp.asarray(t.heads)
    names = ([forest.entity_names[i % forest.num_entities]
              for i in range(batch - 10)] + [f"miss{i}" for i in range(10)])
    h = jnp.asarray(hashing.hash_entities(names))
    ref = cuckoo_lookup_ref(fps, heads, h)
    ker = cuckoo_lookup(fps, heads, h, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(ker.hit))
    np.testing.assert_array_equal(np.asarray(ref.head), np.asarray(ker.head))
    m = np.asarray(ref.hit)
    np.testing.assert_array_equal(np.asarray(ref.bucket)[m],
                                  np.asarray(ker.bucket)[m])
    np.testing.assert_array_equal(np.asarray(ref.slot)[m],
                                  np.asarray(ker.slot)[m])


# ---------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,hq,hkv,lq,lkv,d", [
    (1, 4, 4, 128, 128, 64),      # MHA, tile-aligned
    (2, 8, 2, 256, 256, 64),      # GQA 4:1
    (1, 6, 2, 200, 200, 32),      # unaligned length
    (2, 4, 1, 384, 384, 128),     # MQA, head_dim 128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, lq, lkv, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, lq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, lkv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, lkv, d)), dtype)
    out = flash_attention(q, k, v, True, None, True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grads():
    b, hq, hkv, l, d = 2, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, l, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, l, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, l, d)), jnp.float32)
    gk = jax.grad(lambda *a: jnp.sum(jnp.sin(
        flash_attention(*a, True, None, True))), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(jnp.sin(
        attention_ref(*a, causal=True))), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5)


# --------------------------------------------------------- decode attention

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 8, 2, 549, 64), (1, 14, 2, 1024, 64), (4, 4, 4, 300, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, s, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype)
    lens = jnp.asarray(RNG.integers(1, s + 1, size=(b,)), jnp.int32)
    out = decode_attention(q, k, v, lens, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decoding_combine():
    """Sequence-sharded partial attention == monolithic (long_500k path)."""
    b, hq, hkv, s, d = 2, 8, 2, 768, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    lens = jnp.asarray([s, 500], jnp.int32)
    ref = decode_attention_ref(q, k, v, lens)
    shards = 3
    outs, lses = [], []
    for i in range(shards):
        lo, hi = i * s // shards, (i + 1) * s // shards
        local = jnp.clip(lens - lo, 0, hi - lo)
        o, l = decode_attention(q, k[:, :, lo:hi], v[:, :, lo:hi], local,
                                interpret=True, return_lse=True)
        outs.append(o)
        lses.append(l)
    combined = combine_partial_attention(jnp.stack(outs), jnp.stack(lses))
    np.testing.assert_allclose(combined, ref, atol=3e-5, rtol=3e-5)


# -------------------------------------------------------------- linear scan

@pytest.mark.parametrize("b,h,l,dk,dv", [
    (1, 2, 64, 16, 16), (2, 3, 273, 32, 48), (1, 4, 512, 64, 64),
])
@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("decay_scale", [0.05, 1.0, 8.0])
def test_linear_scan_sweep(b, h, l, dk, dv, inclusive, decay_scale):
    q = jnp.asarray(RNG.normal(size=(b, h, l, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, l, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, l, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(RNG.normal(size=(b, h, l, dk))) * decay_scale,
                    jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(b, h, dk, dv)), jnp.float32)
    out_k, s_k = linear_scan(q, k, v, g, s0, inclusive=inclusive,
                             interpret=True)
    out_r, s_r = linear_scan_ref(q, k, v, g, s0, inclusive=inclusive)
    np.testing.assert_allclose(out_k, out_r, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s_k, s_r, atol=2e-3, rtol=2e-3)


def test_linear_scan_bf16():
    b, h, l, dk, dv = 1, 2, 128, 32, 32
    q = jnp.asarray(RNG.normal(size=(b, h, l, dk)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, h, l, dk)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, h, l, dv)), jnp.bfloat16)
    g = jnp.asarray(-np.abs(RNG.normal(size=(b, h, l, dk))) * 0.1,
                    jnp.float32)
    out_k, s_k = linear_scan(q, k, v, g, None, interpret=True)
    out_r, s_r = linear_scan_ref(q, k, v, g, None)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=5e-2, rtol=5e-2)
