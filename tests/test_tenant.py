"""Multi-tenant forest serving: the tenant -> tree-range registry,
per-tenant admission quotas and fair coalescing, cold-tenant eviction
with bit-exact reload, pinned-range maintenance guards, per-tenant
snapshots, and the tenant-aligned shard planner.

Fast tier — everything here is replicated (single device) and
clock-free; the sharded evict/reload round-trip and the chaos-grade
isolation proofs live in ``test_distributed.py`` / ``test_faults.py``.
"""
import os

import numpy as np
import pytest

from repro.core import (CFTDeviceState, ColdTenant, MaintenanceEngine,
                        TenantRegistry, build_bank, build_forest,
                        list_tenants, load_tenant, plan_partition,
                        plan_tenant_partition, save_tenant)
from repro.core import hashing
from repro.core.bank import _ARENA_TABLES
from repro.obs import get_registry
from repro.serving import (AsyncServeEngine, EngineOverloaded, MicroBatcher,
                           PendingRetrieval, RAGPipeline, RetrievalSession,
                           TenantEvicted)


def _forest(num_trees=4, entities_per_tree=8):
    return build_forest(
        [[(f"root {t}", f"entity {t}_{i}") for i in range(entities_per_tree)]
         for t in range(num_trees)])


def _session(ranges, maint=True):
    forest = _forest()
    bank = build_bank(forest)
    session = RetrievalSession()
    session.attach(CFTDeviceState.from_bank(bank, forest))
    if maint:
        session.attach_maintenance(MaintenanceEngine(bank), forest,
                                   registry=TenantRegistry(ranges))
    else:
        session.attach_tenants(TenantRegistry(ranges))
    return forest, bank, session


def _tenant_queries(forest, bank, lo, hi):
    """One (tree_ids, hashes) batch covering every entity of trees
    ``[lo, hi)`` — all present, so every query hits while resident."""
    hashes = hashing.hash_entities(forest.entity_names)
    rows = [r for r in range(len(bank.row_entity))
            if lo <= int(bank.row_tree[r]) < hi]
    return ([int(bank.row_tree[r]) for r in rows],
            [int(hashes[bank.row_entity[r]]) for r in rows])


def _answers(session, q):
    r = session.retrieve(*q)
    return {n: np.asarray(getattr(r, n)).copy()
            for n in ("hit", "locations", "up", "down")}


def _bank_image(bank):
    img = {n: getattr(bank, n).copy() for n in _ARENA_TABLES}
    img["tree_nb"] = bank.tree_nb.copy()
    img["num_items"] = bank.num_items.copy()
    img["bucket_offsets"] = bank.bucket_offsets.copy()
    return img


def _assert_bank_equals(bank, img, victim=None):
    """Bank content matches the pre-eviction image bit-for-bit.

    ``temperature`` is serving feedback — co-resident tenants that kept
    serving during the victim's cold window legitimately advance it — so
    it is compared only over the victim's arena range (restored exactly
    from the cold copy) when a ``(lo, hi)`` tree range is given."""
    for n, want in img.items():
        got = getattr(bank, n)
        assert got.shape == want.shape, n
        if n == "temperature":
            if victim is not None:
                alo = int(img["bucket_offsets"][victim[0]])
                ahi = int(img["bucket_offsets"][victim[1]])
                np.testing.assert_array_equal(got[alo:ahi], want[alo:ahi])
            continue
        assert np.array_equal(got, want), n


def _assert_same(got, want):
    for n in ("hit", "locations", "up", "down"):
        np.testing.assert_array_equal(got[n], want[n])


RANGES = {"acme": (0, 2), "bravo": (2, 4)}


# ------------------------------------------------------------- registry

def test_registry_lookup_and_residency():
    reg = TenantRegistry(RANGES)
    assert reg.names == ["acme", "bravo"]
    assert reg.trees("acme") == (0, 2) and reg.trees("bravo") == (2, 4)
    assert [reg.tenant_of(t) for t in range(4)] == \
        ["acme", "acme", "bravo", "bravo"]
    assert reg.tenant_of(99) is None
    assert reg.tenant_of_batch([2, 3, 2]) == "bravo"
    assert reg.tenant_of_batch([]) is None
    with pytest.raises(ValueError, match="spans tenants"):
        reg.tenant_of_batch([1, 2])
    assert reg.resident("acme") and not reg.any_cold
    with pytest.raises(KeyError):
        reg.resident("nobody")
    # tuple-list construction form and validation
    assert TenantRegistry([("b", 2, 4), ("a", 0, 2)]).names == ["a", "b"]
    with pytest.raises(ValueError, match="overlaps"):
        TenantRegistry({"a": (0, 3), "b": (2, 4)})
    with pytest.raises(ValueError, match="bad range"):
        TenantRegistry({"a": (3, 3)})


# ------------------------------------------------- evict/reload lifecycle

def test_evict_then_reload_is_bit_exact():
    forest, bank, session = _session(RANGES)
    qa = _tenant_queries(forest, bank, 0, 2)
    qb = _tenant_queries(forest, bank, 2, 4)
    want_a, want_b = _answers(session, qa), _answers(session, qb)
    assert want_a["hit"].all() and want_b["hit"].all()
    session.maintain()          # absorb the baseline temperature bumps
    img = _bank_image(bank)

    cold = session.evict_tenant("acme")
    assert isinstance(cold, ColdTenant)
    assert (cold.lo, cold.hi) == (0, 2) and cold.arena_rows > 0
    assert not session.tenants.resident("acme")
    assert session.tenants.cold("acme") is cold and session.tenants.any_cold
    # the victim's queries miss safely; the co-resident tenant is
    # byte-identical to its pre-eviction answers
    assert not _answers(session, qa)["hit"].any()
    _assert_same(_answers(session, qb), want_b)
    # the cold range is pinned: mutations reject at queue time, CSR
    # compaction stays off bank-wide (cold heads reference live rows)
    assert session.maint.pinned[0:2].all()
    assert not session.maint.pinned[2:4].any()
    with pytest.raises(ValueError, match="pinned"):
        session.maint.queue_insert(0, "late", [1])
    with pytest.raises(ValueError, match="pinned"):
        session.maint.queue_delete(1, "late")
    assert session.maint.maybe_compact() is False

    session.reload_tenant("acme")
    assert session.tenants.resident("acme")
    assert not session.maint.pinned.any()
    _assert_bank_equals(bank, img, victim=(0, 2))   # host: bit-exact
    want = CFTDeviceState.from_bank(bank, forest)   # device: bit-exact
    for n in ("fingerprints", "temperature", "heads", "bucket_offsets",
              "tree_nb", "csr_offsets", "csr_nodes"):
        np.testing.assert_array_equal(np.asarray(getattr(session.state, n)),
                                      np.asarray(getattr(want, n)))
    _assert_same(_answers(session, qa), want_a)
    _assert_same(_answers(session, qb), want_b)
    reg = get_registry()
    assert reg.counter("tenant.evictions").value(tenant="acme") >= 1
    assert reg.counter("tenant.reloads").value(tenant="acme") >= 1


def test_evict_survives_pending_mutations_and_double_evict_raises():
    forest, bank, session = _session(RANGES)
    # queued work flushes through maintain() before the surgery, so the
    # cold copy carries it and the round trip keeps it
    session.maint.queue_insert(1, "pre-evict arrival", [1])
    session.evict_tenant("acme")
    with pytest.raises(ValueError, match="not resident"):
        session.evict_tenant("acme")
    session.reload_tenant("acme")
    h = int(hashing.hash_entities(["pre-evict arrival"])[0])
    assert _answers(session, ([1], [h]))["hit"].all()


def test_offboard_then_onboard_round_trip():
    forest, bank, session = _session(RANGES)
    qb = _tenant_queries(forest, bank, 2, 4)
    want_b = _answers(session, qb)
    session.maintain()
    img = _bank_image(bank)
    cold = session.offboard_tenant("bravo")
    assert not session.tenants.resident("bravo")
    assert session.tenants.cold("bravo") is None    # registry dropped it
    assert not _answers(session, qb)["hit"].any()
    # the tree range stays allocated and empty; other tenants unaffected
    qa = _tenant_queries(forest, bank, 0, 2)
    assert _answers(session, qa)["hit"].all()
    session.onboard_tenant("bravo", cold)
    assert session.tenants.resident("bravo")
    _assert_bank_equals(bank, img, victim=(2, 4))
    _assert_same(_answers(session, qb), want_b)
    with pytest.raises(ValueError, match="already resident"):
        session.onboard_tenant("bravo", cold)


# ------------------------------------------------------ tenant snapshots

def test_tenant_snapshot_round_trip(tmp_path):
    forest, bank, session = _session(RANGES)
    qa = _tenant_queries(forest, bank, 0, 2)
    want_a = _answers(session, qa)
    cold = session.offboard_tenant("acme")
    save_tenant(str(tmp_path), cold)
    assert list_tenants(str(tmp_path)) == ["acme"]
    loaded = load_tenant(str(tmp_path), "acme")
    assert (loaded.name, loaded.lo, loaded.hi) == ("acme", 0, 2)
    np.testing.assert_array_equal(loaded.tree_nb, cold.tree_nb)
    np.testing.assert_array_equal(loaded.num_items, cold.num_items)
    for n in _ARENA_TABLES:
        np.testing.assert_array_equal(loaded.tables[n], cold.tables[n])
    # onboarding from the restored copy serves the original answers
    session.onboard_tenant("acme", loaded)
    _assert_same(_answers(session, qa), want_a)
    assert get_registry().counter("snapshot.tenants_saved").value(
        tenant="acme") >= 1
    with pytest.raises(FileNotFoundError):
        load_tenant(str(tmp_path), "nobody")


def test_cleanup_keeps_tenant_dirs(tmp_path):
    forest, bank, session = _session(RANGES)
    cold = session.offboard_tenant("acme")
    save_tenant(str(tmp_path), cold)
    os.makedirs(tmp_path / "tmp.tenant.ghost")
    os.makedirs(tmp_path / "tmp.7")
    from repro.core import cleanup_snapshots
    cleanup_snapshots(str(tmp_path), keep_last=1)
    assert list_tenants(str(tmp_path)) == ["acme"]   # survives the sweep
    left = sorted(os.listdir(tmp_path))
    assert not any(d.startswith("tmp.") for d in left)


# -------------------------------------------- admission quotas + fairness

def _quota_engine(session, now, **kw):
    kw.setdefault("latency_budget", 0.5)
    kw.setdefault("max_batch", 32)
    kw.setdefault("min_bucket", 4)
    kw.setdefault("maintenance", "off")
    return AsyncServeEngine(session, clock=lambda: now[0], **kw)


def test_per_tenant_quota_isolates_overload():
    forest, bank, session = _session(RANGES, maint=False)
    now = [0.0]
    eng = _quota_engine(session, now, tenant_quota=2, max_queue_requests=16)
    reg = get_registry()
    before = reg.counter("serve.rejected").value(reason="overload",
                                                 tenant="acme")
    # the tenant resolves from the batch's trees — no explicit label
    f1 = eng.submit([0], [0])
    f2 = eng.submit([1], [0])
    with pytest.raises(EngineOverloaded) as ei:
        eng.submit([0], [0])
    assert ei.value.tenant == "acme"
    assert ei.value.pending == 2 and ei.value.limit == 2
    assert reg.counter("serve.rejected").value(
        reason="overload", tenant="acme") == before + 1
    # acme's burst never touches bravo's share
    f3 = eng.submit([2], [0])
    eng.flush(now[0])
    for f in (f1, f2, f3):
        assert f.result(timeout=5).hit.shape[0] == 1
    # queue drained -> acme admits again
    eng.submit([0], [0])
    eng.flush(now[0])
    assert reg.counter("serve.tenant_queries").value(tenant="acme") >= 3
    eng.stop()


def test_default_quota_splits_global_bound():
    forest, bank, session = _session(RANGES, maint=False)
    now = [0.0]
    # 8 requests / 2 tenants -> 4 each without any explicit quota
    eng = _quota_engine(session, now, max_queue_requests=8)
    assert eng._quota_for("acme") == 4
    for _ in range(4):
        eng.submit([0], [0])
    with pytest.raises(EngineOverloaded) as ei:
        eng.submit([1], [0])
    assert ei.value.tenant == "acme" and ei.value.limit == 4
    eng.submit([3], [0])                    # bravo still admits
    eng.flush(now[0])
    eng.stop()


def test_evicted_tenant_sheds_with_tenant_evicted():
    forest, bank, session = _session(RANGES)
    now = [0.0]
    eng = _quota_engine(session, now, maintenance="inline")
    session.evict_tenant("acme")
    with pytest.raises(TenantEvicted) as ei:
        eng.submit([0], [0])
    assert ei.value.tenant == "acme"
    assert isinstance(ei.value, RuntimeError)
    assert get_registry().counter("serve.rejected").value(
        reason="evicted", tenant="acme") >= 1
    f = eng.submit([2], [0])                # the resident tenant serves
    eng.flush(now[0])
    assert f.result(timeout=5).hit.shape[0] == 1
    session.reload_tenant("acme")
    f = eng.submit([0], [0])
    eng.flush(now[0])
    assert f.result(timeout=5).hit.shape[0] == 1
    eng.stop()


def test_pop_is_tenant_fair_round_robin():
    mb = MicroBatcher(max_batch=4, min_bucket=2)

    def req(tenant, tag):
        return PendingRetrieval(tree_ids=[0], hashes=[tag], arrive_t=0.0,
                                tenant=tenant)

    # a monopolizing burst from one tenant, one late request from another
    for i in range(5):
        mb.add(req("acme", i))
    mb.add(req("bravo", 100))
    assert mb.pending_for("acme") == 5 and mb.pending_for("bravo") == 1
    batch = mb.pop()
    # round-robin: bravo rides the first batch despite arriving last;
    # per-tenant FIFO order is preserved
    assert [(r.tenant, r.hashes[0]) for r in batch] == \
        [("acme", 0), ("bravo", 100), ("acme", 1), ("acme", 2)]
    assert [(r.tenant, r.hashes[0]) for r in mb.pop()] == \
        [("acme", 3), ("acme", 4)]
    assert len(mb) == 0 and mb.pending_for("acme") == 0
    # single-tenant queues keep the legacy FIFO-prefix behavior
    for i in range(3):
        mb.add(req(None, i))
    assert [r.hashes[0] for r in mb.pop()] == [0, 1, 2]


# ------------------------------------------------------------- pipeline

class _Corpus:
    trees = [[("root a", "child a1"), ("root a", "child a2")],
             [("root b", "child b1")]]


def test_rag_pipeline_wires_tenants():
    rag = RAGPipeline(_Corpus(), engine=None, use_bank=True,
                      tenants={"a": (0, 1), "b": (1, 2)})
    assert isinstance(rag.tenants, TenantRegistry)
    assert rag.session.tenants is rag.tenants
    assert rag.session.coord.registry is rag.tenants
    base = rag.answer("tell me about child b1").prompt
    rag.session.evict_tenant("a")
    assert rag.answer("tell me about child b1").prompt == base
    rag.session.reload_tenant("a")
    assert rag.answer("tell me about child a1").prompt


def test_rag_pipeline_startup_sweeps_orphan_tmp(tmp_path):
    """Satellite: a crash mid-snapshot leaves a ``tmp.*`` dir behind;
    pipeline startup sweeps it even with pruning effectively off."""
    orphan = tmp_path / "tmp.42"
    os.makedirs(orphan)
    (orphan / "junk.npy").write_bytes(b"\x00" * 16)
    orphan2 = tmp_path / "tmp.tenant.ghost"
    os.makedirs(orphan2)
    rag = RAGPipeline(_Corpus(), engine=None, use_bank=True,
                      snapshot_dir=str(tmp_path), snapshot_keep=0)
    assert not orphan.exists() and not orphan2.exists()
    assert rag.restored_step is None


# ------------------------------------------------- tenant-aligned shards

def test_plan_tenant_partition_never_splits_a_tenant():
    reg = TenantRegistry({"a": (0, 3), "b": (3, 8)})
    # heavily skewed weights would put the naive quantile cut inside b
    w = np.asarray([1, 1, 1, 1, 1, 1, 50, 50], np.float64)
    naive = plan_partition(w, 2)
    assert 3 < int(naive[1]) < 8                     # would split b
    starts = plan_tenant_partition(w, reg, 2)
    assert starts[0] == 0 and starts[-1] == 8
    cuts = set(int(s) for s in starts)
    for name in reg.names:
        lo, hi = reg.trees(name)
        owner = {d for d in range(2)
                 if max(lo, int(starts[d])) < min(hi, int(starts[d + 1]))}
        assert len(owner) == 1, f"tenant {name} straddles shards"
        assert all(not (lo < c < hi) for c in cuts)
    # single-tree tenants leave every boundary available: the plan
    # degrades to the plain weight-balanced planner
    fine = TenantRegistry({f"t{i}": (i, i + 1) for i in range(8)})
    np.testing.assert_array_equal(
        plan_tenant_partition(np.ones(8), fine, 4),
        plan_partition(np.ones(8), 4))
