"""FilterBank: bulk build, per-tree routing, vmapped + Pallas lookups."""
import jax.numpy as jnp
import numpy as np

from repro.core import (CFTDeviceState, build_bank, build_forest,
                        lookup_batch, lookup_batch_bank,
                        lookup_batch_ragged, lookup_batch_trees,
                        retrieve_device)
from repro.core import hashing
from repro.data import hospital_corpus
from repro.kernels.cuckoo_lookup import (cuckoo_lookup_bank,
                                         cuckoo_lookup_trees)


def _forest(num_trees=16, shared=True):
    trees = [[(f"root {t}", f"entity {t}_{i}") for i in range(12)]
             for t in range(num_trees)]
    if shared:
        for t in range(num_trees):          # one entity spanning all trees
            trees[t].append((f"root {t}", "shared entity"))
    return build_forest(trees)


def test_round_trip_every_row():
    """Every inserted (tree, entity) resolves in its own tree with its own
    CSR row and entity-id payload."""
    forest = _forest()
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    for r in range(bank.num_rows):
        t, e = int(bank.row_tree[r]), int(bank.row_entity[r])
        hit, row, eid = bank.lookup(t, int(hashes[e]))
        assert hit and row == r and eid == e
        nodes = bank.walk_row(r)
        assert nodes and all(int(forest.tree_id[nd]) == t for nd in nodes)
        assert all(int(forest.entity_id[nd]) == e for nd in nodes)


def test_no_cross_tree_leakage():
    """Probing a tree that doesn't hold the entity must (almost) always
    miss — residual hits are fingerprint collisions at the filter's
    documented ~0.1% rate — and even a collision can only return rows of
    the probed tree, so foreign locations never leak."""
    forest = _forest(num_trees=8, shared=False)
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    cross = probes = 0
    for r in range(bank.num_rows):
        home = int(bank.row_tree[r])
        h = int(hashes[int(bank.row_entity[r])])
        for t in range(bank.num_trees):
            if t == home:
                continue
            probes += 1
            hit, row, _ = bank.lookup(t, h)
            if hit:
                cross += 1
                assert int(bank.row_tree[row]) == t   # only local rows
                assert all(int(forest.tree_id[nd]) == t
                           for nd in bank.walk_row(row))
    assert cross / probes < 0.01


def test_bulk_build_equals_sequential_insert():
    """The vectorized bulk path and the per-item scalar path must agree on
    membership, payloads, and per-tree item counts."""
    corpus = hospital_corpus(num_trees=30)
    forest = build_forest(corpus.trees)
    bulk = build_bank(forest, bulk=True)
    seq = build_bank(forest, bulk=False)
    assert np.array_equal(bulk.tree_nb, seq.tree_nb)
    assert np.array_equal(bulk.bucket_offsets, seq.bucket_offsets)
    assert np.array_equal(bulk.num_items, seq.num_items)
    assert bulk.build_stats["evicted"] <= bulk.build_stats["items"] // 10
    hashes = hashing.hash_entities(forest.entity_names)
    for r in range(bulk.num_rows):
        t = int(bulk.row_tree[r])
        h = int(hashes[int(bulk.row_entity[r])])
        assert bulk.lookup(t, h) == seq.lookup(t, h)
    occ_b = np.add.reduceat((bulk.fingerprints
                             != hashing.EMPTY_FP).sum(axis=1),
                            bulk.bucket_offsets[:-1])
    occ_s = np.add.reduceat((seq.fingerprints
                             != hashing.EMPTY_FP).sum(axis=1),
                            seq.bucket_offsets[:-1])
    assert np.array_equal(occ_b, occ_s)


def test_routed_lookup_matches_host():
    forest = _forest()
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid = np.concatenate([bank.row_tree,
                          np.zeros(16, np.int32)]).astype(np.int32)
    hh = np.concatenate([hashes[bank.row_entity],
                         hashing.hash_entities([f"missing {i}"
                                                for i in range(16)])])
    res = lookup_batch_ragged(jnp.asarray(bank.fingerprints),
                              jnp.asarray(bank.heads),
                              jnp.asarray(
                                  bank.bucket_offsets.astype(np.int32)),
                              jnp.asarray(bank.tree_nb),
                              jnp.asarray(tid), jnp.asarray(hh))
    for i in range(tid.shape[0]):
        hit, row, _ = bank.lookup(int(tid[i]), int(hh[i]))
        assert bool(res.hit[i]) == hit
        if hit:
            assert int(res.head[i]) == row


def test_vmapped_lookup_matches_per_tree_reference():
    """lookup_batch_trees == looping lookup_batch over each tree's table."""
    forest = _forest()
    bank = build_bank(forest)
    names = [[f"entity {t}_{i}" for i in range(12)] + ["missing x", "shared entity"]
             for t in range(bank.num_trees)]
    hb = jnp.stack([jnp.asarray(hashing.hash_entities(ns)) for ns in names])
    df, _, dh = bank.dense_tables()         # uniform forest -> dense view
    fps, heads = jnp.asarray(df), jnp.asarray(dh)
    got = lookup_batch_trees(fps, heads, hb)
    ker = cuckoo_lookup_trees(fps, heads, hb, interpret=True)
    for t in range(bank.num_trees):
        ref = lookup_batch(fps[t], heads[t], hb[t])
        m = np.asarray(ref.hit)
        for field in ("hit", "head"):
            np.testing.assert_array_equal(np.asarray(getattr(got, field)[t]),
                                          np.asarray(getattr(ref, field)))
            np.testing.assert_array_equal(np.asarray(getattr(ker, field)[t]),
                                          np.asarray(getattr(ref, field)))
        for field in ("bucket", "slot"):      # defined only on hits
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field)[t])[m],
                np.asarray(getattr(ref, field))[m])
            np.testing.assert_array_equal(
                np.asarray(getattr(ker, field)[t])[m],
                np.asarray(getattr(ref, field))[m])


def test_pallas_bank_kernel_matches_reference():
    forest = _forest()
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid = jnp.asarray(bank.row_tree.astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity])
    df, _, dh = bank.dense_tables()
    fps, heads = jnp.asarray(df), jnp.asarray(dh)
    ref = lookup_batch_bank(fps, heads, tid, hh)
    ker = cuckoo_lookup_bank(fps, heads, tid, hh, interpret=True)
    for field in ("hit", "head", "bucket", "slot"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, field)),
                                      np.asarray(getattr(ker, field)))


def test_pallas_bank_kernel_tree_tiled_matches_single_block():
    """The tree-axis-tiled grid must be bit-identical to the single-VMEM-
    block kernel on every lane (hits AND misses), for tile sizes that do
    and do not divide T."""
    forest = _forest(num_trees=12)
    bank = build_bank(forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid = np.concatenate([bank.row_tree,
                          np.full(24, 5, np.int32)]).astype(np.int32)
    hh = np.concatenate([hashes[bank.row_entity],
                         hashing.hash_entities([f"missing {i}"
                                                for i in range(24)])])
    df, _, dh = bank.dense_tables()
    fps, heads = jnp.asarray(df), jnp.asarray(dh)
    tid_j, hh_j = jnp.asarray(tid), jnp.asarray(hh)
    ref = lookup_batch_bank(fps, heads, tid_j, hh_j)
    m = np.asarray(ref.hit)
    base = cuckoo_lookup_bank(fps, heads, tid_j, hh_j, interpret=True,
                              tree_tile=0)
    for tt in (1, 4, 5, 12, -1):   # 5 does not divide T=12 -> pad path
        ker = cuckoo_lookup_bank(fps, heads, tid_j, hh_j, interpret=True,
                                 tree_tile=tt)
        for field in ("hit", "head", "bucket", "slot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ker, field)),
                np.asarray(getattr(base, field)),
                err_msg=f"tree_tile={tt} {field}")
        np.testing.assert_array_equal(np.asarray(ker.hit), m)
        np.testing.assert_array_equal(np.asarray(ker.head),
                                      np.asarray(ref.head))
        for field in ("bucket", "slot"):       # defined only on hits
            np.testing.assert_array_equal(
                np.asarray(getattr(ker, field))[m],
                np.asarray(getattr(ref, field))[m])


def test_bank_auto_tiling_threshold():
    """Auto selection keeps small banks single-block and tiles big ones;
    both answer identically to the jnp reference."""
    from repro.kernels.cuckoo_lookup.ops import (SINGLE_BLOCK_MAX_ROWS,
                                                 _pick_tree_tile)
    assert _pick_tree_tile(4, 64) == 0
    assert _pick_tree_tile(SINGLE_BLOCK_MAX_ROWS, 16) >= 1
    assert _pick_tree_tile(64, 2 * SINGLE_BLOCK_MAX_ROWS) == 1


def test_absorb_temperature_replaces_handrolled_writeback():
    forest = _forest(num_trees=4)
    bank = build_bank(forest)
    state = CFTDeviceState.from_bank(bank, forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid = jnp.asarray(bank.row_tree[:8].astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity[:8]])
    out = retrieve_device(state, hh, query_trees=tid)
    bumps = bank.absorb_temperature(state.with_temperature(out.temperature))
    assert bumps == 8
    np.testing.assert_array_equal(bank.temperature,
                                  np.asarray(out.temperature))
    # shape mismatch (stale layout after an expand) must be loud
    try:
        bank.absorb_temperature(np.zeros((1, 2, 3), np.int32))
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_retrieve_device_routes_to_queried_tree():
    forest = _forest()
    bank = build_bank(forest)
    state = CFTDeviceState.from_bank(bank, forest)
    hashes = hashing.hash_entities(forest.entity_names)
    tid = jnp.asarray(bank.row_tree.astype(np.int32))
    hh = jnp.asarray(hashes[bank.row_entity])
    out = retrieve_device(state, hh, query_trees=tid, max_locs=4, n=3)
    assert bool(out.hit.all())
    for r in range(bank.num_rows):
        got = [int(v) for v in np.asarray(out.locations[r]) if v >= 0]
        want = bank.walk_row(r)[:4]
        assert got == want
        # every location stays inside the queried tree
        assert all(int(forest.tree_id[nd]) == int(bank.row_tree[r])
                   for nd in got)


def test_shared_entity_isolated_per_tree():
    """An entity present in every tree yields only the queried tree's
    nodes — the cross-tree locations stay invisible to a routed query."""
    forest = _forest(num_trees=6, shared=True)
    bank = build_bank(forest)
    h = int(hashing.entity_hash("shared entity"))
    eid = forest.name_to_id["shared entity"]
    all_nodes = {t: [nd for tt, nd in forest.entity_locations[eid]
                     if tt == t] for t in range(6)}
    for t in range(6):
        hit, row, got_eid = bank.lookup(t, h)
        assert hit and got_eid == eid
        assert bank.walk_row(row) == all_nodes[t]
