"""Serving engine + end-to-end CFT-RAG pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import HashTokenizer, hospital_corpus
from repro.models import init_params
from repro.serving import RAGPipeline, Request, ServeEngine, kv_cache_bytes


def _engine(cache=128, batch=2):
    cfg = get_arch("qwen2-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, cache_size=cache, batch_size=batch)


def test_generate_shapes_and_determinism():
    cfg, eng = _engine()
    toks = jnp.asarray(np.random.default_rng(0).integers(4, cfg.vocab,
                                                         (2, 16)), jnp.int32)
    out1 = eng.generate({"tokens": toks}, max_new_tokens=5)
    out2 = eng.generate({"tokens": toks}, max_new_tokens=5)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)      # greedy => deterministic


def test_scheduler_truncation_and_batching():
    cfg, eng = _engine(cache=64, batch=2)
    reqs = [Request(prompt_ids=list(range(4, 200)), max_new_tokens=4),
            Request(prompt_ids=list(range(4, 20)), max_new_tokens=4),
            Request(prompt_ids=list(range(4, 40)), max_new_tokens=4)]
    done = eng.serve(reqs)
    assert len(done) == 3
    assert all(len(r.out_ids) == 4 for r in done)
    assert len(done[0].prompt_ids) <= 60           # truncated to window


def test_rag_end_to_end_and_accuracy_proxy():
    corpus = hospital_corpus(num_trees=12, num_queries=6)
    cfg, eng = _engine(cache=128)
    rag = RAGPipeline(corpus, eng, tokenizer=HashTokenizer(cfg.vocab),
                      num_buckets=512)
    ans = rag.answer(corpus.queries[0], max_new_tokens=4)
    assert ans.entities and ans.context and len(ans.output_ids) == 4
    assert "upward hierarchical relationship" in ans.context or \
           "downward hierarchical relationship" in ans.context
    acc = rag.retrieval_accuracy(corpus.queries, corpus.query_entities)
    assert acc == 1.0                              # paper: same Acc as naive


def test_rag_device_lookup_path_matches_host():
    corpus = hospital_corpus(num_trees=10, num_queries=4)
    rag_h = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024),
                        num_buckets=512)
    rag_d = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024),
                        num_buckets=512, use_device_lookup=True)
    for q in corpus.queries:
        a = rag_h.retrieve(q)
        b = rag_d.retrieve(q)
        assert a.entities == b.entities
        # same entities mentioned in both context renderings
        for e in a.entities:
            assert (e in a.context) == (e in b.context)


def test_engine_tree_routed_retrieval():
    """Engine serves (tree_id, hash) query batches against a bank state."""
    from repro.core import CFTDeviceState, build_bank, build_forest
    from repro.core import hashing
    corpus = hospital_corpus(num_trees=8)
    forest = build_forest(corpus.trees)
    bank = build_bank(forest)
    _, eng = _engine()
    eng.attach_retrieval(CFTDeviceState.from_bank(bank, forest),
                         max_locs=4, batch_pad=32)
    hashes = hashing.hash_entities(forest.entity_names)
    tree_ids = bank.row_tree[:48].tolist()
    qh = [int(hashes[int(e)]) for e in bank.row_entity[:48]]
    out = eng.retrieve(tree_ids, qh)
    assert out.hit.shape == (48,) and bool(out.hit.all())
    for r in range(48):
        got = [int(v) for v in np.asarray(out.locations[r]) if v >= 0]
        assert got == bank.walk_row(r)[:4]
    # temperature threads back into engine state across calls
    t0 = int(np.asarray(out.temperature).sum())
    out2 = eng.retrieve(tree_ids, qh)
    assert int(np.asarray(out2.temperature).sum()) >= t0 + 48


def test_rag_bank_mode_scoped_and_global():
    corpus = hospital_corpus(num_trees=8, num_queries=4)
    rag = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024),
                      use_bank=True)
    host = RAGPipeline(corpus, None, tokenizer=HashTokenizer(1024))
    for q in corpus.queries:
        a = host.retrieve(q)
        b = rag.retrieve(q)                      # global: fan out over trees
        assert a.entities == b.entities
        for e in a.entities:
            assert (e in a.context) == (e in b.context)
        scoped = rag.retrieve(q, tree_scope=0)   # routed to one tree
        assert scoped.entities == a.entities


def test_kv_cache_sizing():
    cfg = get_arch("yi-34b")
    by = kv_cache_bytes(cfg, batch=128, cache_size=32768)
    # 2 * 60L * 128B * 8kv * 32768 * 128hd * 2bytes
    assert by == 2 * 60 * 128 * 8 * 32768 * 128 * 2
