"""Version-compatibility shims for the pinned accelerator stack."""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5 exposes it at top level
    shard_map = jax.shard_map
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map


def register_compile_listener(fn) -> bool:
    """Best-effort hook into the runtime's compile telemetry.

    Registers ``fn(event_name, duration_s)`` for backend-compile events
    via ``jax.monitoring`` (fired once per new-shape XLA compilation,
    silent on jit cache hits).  Returns True when the hook landed, False
    on stacks without the monitoring API — callers must treat the
    listener as advisory (the recompile sentinel's jit-cache-size
    counting works either way).  There is no targeted unregister in the
    supported jax range, so register exactly one process-wide listener
    and fan out behind it; never call ``clear_event_listeners`` (it
    would drop listeners owned by other libraries too).
    """
    try:
        from jax import monitoring
        register = monitoring.register_event_duration_secs_listener
    except (ImportError, AttributeError):
        return False

    def _listener(event: str, duration: float, **kw) -> None:
        if "backend_compile" in event:
            fn(event, duration)

    try:
        register(_listener)
    except Exception:                             # pragma: no cover
        return False
    return True


__all__ = ["shard_map", "register_compile_listener"]
