"""Version-compatibility shims for the pinned accelerator stack."""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5 exposes it at top level
    shard_map = jax.shard_map
except AttributeError:                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
