"""Training driver (CPU-scale end-to-end; same code path the pod run uses).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..data import HashTokenizer, PackedBatches, TextDataset, hospital_corpus
from ..models import init_params
from ..training import (AdamWConfig, LoopConfig, TrainLoop, adamw_init,
                        make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--trees", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))

    corpus = hospital_corpus(num_trees=args.trees)
    tok = HashTokenizer(cfg.vocab)
    ds = TextDataset(corpus.documents, tok)
    pb = PackedBatches(ds, batch_size=args.batch, seq_len=args.seq)

    def batches():
        for b in pb:
            extra = {}
            if cfg.family == "encdec":
                extra["frames"] = jnp.zeros(
                    (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
            if cfg.family == "vlm" and cfg.num_patches:
                extra["patches"] = jnp.zeros(
                    (args.batch, cfg.num_patches, cfg.frontend_dim),
                    jnp.float32)
            yield {**{k: jnp.asarray(v) for k, v in b.items()}, **extra}

    loop = TrainLoop(LoopConfig(total_steps=args.steps,
                                ckpt_dir=args.ckpt_dir,
                                ckpt_every=args.ckpt_every),
                     step_fn, params, opt_state, batches(), pipeline=pb)
    metrics = loop.run()
    print(f"done at step {loop.step}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
