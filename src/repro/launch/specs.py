"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

Weak-type-correct, shardable, zero-allocation stand-ins — the dry-run lowers
against these.  Modality frontends are STUBS: the specs provide precomputed
patch/frame embeddings (assignment brief).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import lm

S = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text = s
    out: Dict[str, Any] = {}
    if cfg.family == "vlm" and cfg.num_patches:
        text = s - cfg.num_patches            # early fusion keeps total = s
        out["patches"] = S((b, cfg.num_patches, cfg.frontend_dim),
                           jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = S((b, cfg.num_patches, cfg.d_model), jnp.float32)
    out["tokens"] = S((b, text), jnp.int32)
    out["labels"] = S((b, text), jnp.int32)
    out["mask"] = S((b, text), jnp.float32)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text = s
    out: Dict[str, Any] = {}
    if cfg.family == "vlm" and cfg.num_patches:
        text = s - cfg.num_patches
        out["patches"] = S((b, cfg.num_patches, cfg.frontend_dim),
                           jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = S((b, cfg.num_patches, cfg.d_model), jnp.float32)
    out["tokens"] = S((b, text), jnp.int32)
    return out


def decode_token_specs(shape: ShapeConfig) -> Any:
    return S((shape.global_batch, 1), jnp.int32)


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Abstract decode state with a cache of seq_len (one new token against
    a seq_len KV cache — the assigned decode semantics)."""
    b = shape.global_batch

    if cfg.family == "encdec":
        frames = S((b, cfg.num_patches, cfg.d_model), jnp.float32)
        return jax.eval_shape(
            lambda p, f: lm.init_decode_state(cfg, p, b, shape.seq_len,
                                              batch={"frames": f}),
            lm.abstract_params(cfg), frames)
    return jax.eval_shape(
        lambda p: lm.init_decode_state(cfg, p, b, shape.seq_len),
        lm.abstract_params(cfg))


def params_specs(cfg: ModelConfig) -> Any:
    return lm.abstract_params(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """The full lowering signature for a cell, keyed by step kind."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    return {"tokens": decode_token_specs(shape),
            "state": decode_state_specs(cfg, shape)}
