"""RAG serving driver: build the CFT index over a corpus and answer queries.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --trees 100 --queries 4
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_arch
from ..data import HashTokenizer, hospital_corpus
from ..models import init_params
from ..serving import RAGPipeline, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--device-lookup", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = hospital_corpus(num_trees=args.trees, num_queries=args.queries)
    engine = ServeEngine(cfg, params, cache_size=args.cache)
    rag = RAGPipeline(corpus, engine, tokenizer=HashTokenizer(cfg.vocab),
                      use_device_lookup=args.device_lookup)

    for q in corpus.queries[:args.queries]:
        t0 = time.perf_counter()
        ans = rag.answer(q, max_new_tokens=args.max_new)
        dt = time.perf_counter() - t0
        print(f"\nQ: {q[:90]}...")
        print(f"  entities: {ans.entities}")
        print(f"  context:  {ans.context.splitlines()[:2]} ...")
        print(f"  out ids:  {ans.output_ids}  ({dt*1e3:.0f} ms)")
    acc = rag.retrieval_accuracy(corpus.queries[:args.queries],
                                 corpus.query_entities[:args.queries])
    print(f"\nretrieval accuracy proxy: {acc:.4f}")


if __name__ == "__main__":
    main()
