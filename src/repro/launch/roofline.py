"""Roofline analysis from dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:
    compute    = HLO_FLOPs_per_chip / 197e12            [s]
    memory     = HLO_bytes_per_chip / 819e9             [s]
    collective = collective_bytes_per_chip / 50e9       [s]

FLOPs/bytes/collective-bytes come from launch.hlo_analysis (trip-count-aware
parse of the per-device SPMD program; raw ``cost_analysis`` counts while
bodies once and is recorded alongside as ``cost_raw`` for reference).

MODEL_FLOPS = 6·N·D (train, dense N) / 6·N_active·D (train, MoE) /
2·N_active·D (inference) — the ratio MODEL_FLOPS / (HLO_FLOPs x chips)
exposes remat recompute, capacity-factor slack, and dispatch overhead.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List

PEAK_FLOPS = 197e12           # bf16 / chip
HBM_BW = 819e9                # B/s / chip
ICI_BW = 50e9                 # B/s / link (per chip, one direction)

# re-export for backwards compatibility with early artifacts
from .hlo_analysis import analyze as hlo_analyze   # noqa: E402,F401


def tokens_for(kind: str, seq: int, batch: int) -> int:
    return batch * (1 if kind == "decode" else seq)


def analyze_record(rec: Dict[str, Any]) -> Dict[str, Any]:
    from ..configs import SHAPES
    flops = rec["flops"]
    bytes_accessed = rec["bytes_accessed"]
    coll = rec["collectives"]["total_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_accessed / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bound = max(terms, key=terms.get)

    shape = SHAPES[rec["shape"]]
    toks = tokens_for(rec["kind"], shape.seq_len, shape.global_batch)
    n = rec["active_params"]
    per_tok = 6 * n if rec["kind"] == "train" else 2 * n
    model_flops = per_tok * toks
    hlo_global = flops * rec["devices"]
    dominant = max(terms.values())
    ideal = model_flops / (rec["devices"] * PEAK_FLOPS)
    t_mem_adj = kernel_adjusted_memory(rec)
    dominant_adj = max(t_comp, t_mem_adj, t_coll)
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind", "devices")},
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bound": bound,
        "useful_flops_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "roofline_fraction": (ideal / dominant) if dominant else 0.0,
        "t_memory_kerneladj_s": t_mem_adj,
        "roofline_fraction_kerneladj": (ideal / dominant_adj
                                        if dominant_adj else 0.0),
        "step_lower_bound_s": dominant,
        "model_flops": model_flops,
        "hbm_gib_per_dev": (rec["memory"].get("argument_size", 0)
                            + rec["memory"].get("temp_size", 0)) / 2**30,
    }


def kernel_adjusted_memory(rec: Dict[str, Any]) -> float:
    """ESTIMATED memory term with the Pallas kernels in place of the
    jnp-lowered attention/linear-scan regions.

    The XLA-only lowering materializes O(L^2) attention score tensors and
    (C,C,Dk) chunk pair tensors in HBM; on TPU the flash_attention /
    linear_scan kernels hold them in VMEM.  This subtracts the analytic
    traffic of those tensors (3 elementwise touches x passes) and keeps
    everything else from the measured HLO.  Marked as an estimate in the
    report — the measured term is the XLA-only baseline."""
    from ..configs import SHAPES, get_arch
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    dev = rec["devices"]
    b, l = shape.global_batch, shape.seq_len
    passes = 4.0 if rec["kind"] == "train" else 1.0    # fwd+remat+bwd(2)
    touches = 3.0                                      # write+mask+read
    saved = 0.0
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        if rec["kind"] == "decode":
            saved = (b * cfg.n_heads * l * 4.0) * touches * cfg.n_layers
        else:
            # blocked attention: total score elements = B * Hq * L^2 / 2
            saved = (b * cfg.n_heads * l * l / 2 * 4.0) * touches * passes \
                * cfg.n_layers
    if cfg.family in ("rwkv", "mamba_hybrid") and rec["kind"] != "decode":
        from ..kernels.linear_scan.kernel import CHUNK
        heads = cfg.n_heads if cfg.family == "rwkv" else cfg.ssm_heads
        dk = cfg.resolved_head_dim if cfg.family == "rwkv" else cfg.ssm_state
        layers = cfg.n_layers
        saved += (b * heads * l * CHUNK * dk * 4.0) * touches * passes \
            * layers
        if cfg.family == "mamba_hybrid":
            # broadcast B/C/decay tensors (B, L, H, N) x 3, fused in-kernel
            saved += (b * l * heads * dk * 4.0) * 3 * touches * passes \
                * layers
    t_mem = rec["bytes_accessed"] / HBM_BW
    return max(t_mem - saved / dev / HBM_BW, 0.05 * t_mem)


def analyze_dir(art_dir: str) -> List[Dict[str, Any]]:
    rows = []
    for fn in sorted(os.listdir(art_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(art_dir, fn)) as f:
            rec = json.load(f)
        rows.append(analyze_record(rec))
    return rows


def print_table(rows: Iterable[Dict[str, Any]]) -> None:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'bound':10s} "
           f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'useful':>7s} "
           f"{'roofl%':>7s} {'kadj%':>7s} {'HBM GiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['bound']:10s} {r['t_compute_s']:9.2e} "
              f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
              f"{r['useful_flops_ratio']:7.2f} "
              f"{100*r['roofline_fraction']:6.1f}% "
              f"{100*r['roofline_fraction_kerneladj']:6.1f}% "
              f"{r['hbm_gib_per_dev']:8.2f}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = analyze_dir(args.art)
    print_table(rows)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
