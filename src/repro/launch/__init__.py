"""Launch layer: meshes, sharding rules, dry-run, drivers.

NOTE: ``launch.dryrun`` sets XLA_FLAGS at import — import it only in a
dedicated process (the CLI), never from tests or benchmarks.
"""
from .mesh import data_axes, make_production_mesh, make_test_mesh

__all__ = ["data_axes", "make_production_mesh", "make_test_mesh"]
