"""PartitionSpec rules: TP + FSDP (2D-sharded params), EP for experts,
batch-DP over (pod, data), sequence-sharded KV caches for decode.

Parameter rule set (path-name keyed; stacked scan dims are leading and left
unsharded):

  embed (V, D)                      -> ("model", fsdp)   vocab TP + FSDP
  lm_head w (D, V)                  -> (fsdp, "model")
  up-projections  w[q|k|v], gate/up,
  in_proj, w_a, patch_proj (D, F)   -> (fsdp, "model")   megatron column
  down-projections wo, down,
  out_proj, w_b (F, D)              -> ("model", fsdp)   megatron row
  MoE w_gate/w_up (E, D, F)         -> ("model", fsdp, None)  EP + FSDP
  MoE w_down (E, F, D)              -> ("model", None, fsdp)
  rank-1 / scalars / small leaves   -> replicated

Every optimizer moment / gradient mirrors its parameter, so the heaviest
tensors are always 2D-sharded: a 123B AdamW state is ~6.7 GB/chip on one
pod.  (fsdp = ("data",) single-pod, ("pod","data") when the pod axis
exists — cross-pod FSDP keeps 400B-class models inside v5e HBM.)

Cache rules (decode): batch over data when batch > 1; cache SEQUENCE over
"model" (GSPMD then emits the flash-decoding pattern: tiny per-layer
all-reduces of out/lse instead of huge score reductions).  long_500k
(batch=1) shards the sequence over BOTH axes.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig

_UP_PAT = re.compile(
    r"(wq|wk|wv|wg|wr|gate|up|in_proj|w_a|patch_proj|router)\W*\]?\[?'?w'?\]?$")
_DOWN_PAT = re.compile(r"(wo|down|out_proj|w_b)\W*\]?\[?'?w'?\]?$")


def _fsdp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    fsdp = _fsdp(mesh)
    r = len(shape)
    lead = (None,) * (r - 2)
    if r < 2 or min(shape[-2:]) < 64:          # norms, biases, small leaves
        return P()
    if "router" in path:                       # replicated: shard_map MoE
        return P()                             # reads it unsharded

    # MoE experts: (..., E, D, F) / (..., E, F, D)
    if "w_gate" in path or "w_up" in path:
        e_lead = (None,) * (r - 3)
        ep = "model" if _fits(shape[-3], mesh, "model") else None
        dp = fsdp if _fits(shape[-2], mesh, fsdp) else None
        return P(*e_lead, ep, dp, None)
    if "w_down" in path:
        e_lead = (None,) * (r - 3)
        ep = "model" if _fits(shape[-3], mesh, "model") else None
        dp = fsdp if _fits(shape[-1], mesh, fsdp) else None
        return P(*e_lead, ep, None, dp)
    if "embed" in path:                        # (V, D)
        tp = "model" if _fits(shape[-2], mesh, "model") else None
        dp = fsdp if _fits(shape[-1], mesh, fsdp) else None
        return P(tp, dp)
    if "lm_head" in path:                      # (D, V)
        dp = fsdp if _fits(shape[-2], mesh, fsdp) else None
        tp = "model" if _fits(shape[-1], mesh, "model") else None
        return P(*lead, dp, tp)
    if _DOWN_PAT.search(path):                 # (F, D) row-parallel
        tp = "model" if _fits(shape[-2], mesh, "model") else None
        dp = fsdp if _fits(shape[-1], mesh, fsdp) else None
        return P(*lead, tp, dp)
    # default / column-parallel: (D, F)
    dp = fsdp if _fits(shape[-2], mesh, fsdp) else None
    tp = "model" if _fits(shape[-1], mesh, "model") else None
    return P(*lead, dp, tp)


def params_shardings(mesh: Mesh, params_abs: Any) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abs)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append(NamedSharding(mesh, param_spec(mesh, name, leaf.shape)))
    return treedef.unflatten(out)


def opt_shardings(mesh: Mesh, opt_abs: Any, params_sh: Any) -> Any:
    """AdamW m/v mirror params; step scalar replicated."""
    rep = NamedSharding(mesh, P())
    return type(opt_abs)(step=rep, m=params_sh,
                         v=jax.tree.map(lambda s: s, params_sh))


# ------------------------------------------------------------------- batch

def batch_shardings(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig,
                    batch_abs: Any) -> Any:
    dp = _fsdp(mesh)
    bsz = shape.global_batch

    def spec(leaf):
        b_axis = dp if bsz % _axis_size(mesh, dp) == 0 else (
            "data" if bsz % _axis_size(mesh, "data") == 0 else None)
        return NamedSharding(mesh, P(b_axis, *(None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch_abs)


# ------------------------------------------------------------------- state

def state_shardings(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig,
                    state_abs: Any) -> Any:
    """Decode-state shardings (see module docstring)."""
    dp = _fsdp(mesh)
    b = shape.global_batch
    # batch axis preference: full (pod, data) when divisible — matching the
    # token sharding (a "data"-only cache forced a reshard every decode
    # step on the 2-pod mesh); then "data"; else unsharded (long_500k)
    b_ax = (dp if b % _axis_size(mesh, dp) == 0 else
            ("data" if b % _axis_size(mesh, "data") == 0 else None))
    long_ctx = b_ax is None

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        r = len(leaf.shape)
        if r == 0:
            return NamedSharding(mesh, P())
        if re.search(r"\['k'\]|\['v'\]", name) and r >= 4:
            # kv cache (..., B, Hkv, S, hd): sequence-shard S
            lead = (None,) * (r - 4)
            seq_c = leaf.shape[-2]
            if long_ctx:
                both = _axis_size(mesh, "data") * _axis_size(mesh, "model")
                seq_ax = (("data", "model") if seq_c % both == 0 else
                          ("model" if _fits(seq_c, mesh, "model") else None))
                return NamedSharding(mesh, P(*lead, None, None, seq_ax, None))
            s_ax = "model" if _fits(seq_c, mesh, "model") else None
            return NamedSharding(mesh, P(*lead, b_ax, None, s_ax, None))
        if ("wkv" in name or "ssm" in name) and r >= 4:
            # recurrent state (..., B, H, *, *): batch + heads
            lead = (None,) * (r - 4)
            h_ax = "model" if leaf.shape[-3] % _axis_size(mesh, "model") == 0 \
                else None
            return NamedSharding(mesh, P(*lead, b_ax, h_ax, None, None))
        if ("conv" in name or "time_x" in name or "chan_x" in name) and r >= 3:
            lead = (None,) * (r - 3)
            return NamedSharding(mesh, P(*lead, b_ax, None, None))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_abs)
    return treedef.unflatten([spec(p, l) for p, l in flat])


def logits_sharding(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig):
    b_ok = shape.global_batch % _axis_size(mesh, "data") == 0
    v_ok = cfg.vocab % _axis_size(mesh, "model") == 0
    return NamedSharding(mesh, P("data" if b_ok else None, None,
                                 "model" if v_ok else None))
