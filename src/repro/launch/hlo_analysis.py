"""Trip-count-aware HLO analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a scan(8) of a matmul reports 1x the matmul flops), which
undercounts every scanned-layer / microbatched model by the loop trip
counts.  This module parses ``compiled.as_text()`` (the per-device SPMD
program) and:

  * builds the computation call graph (while body/cond, fusion calls,
    conditionals) with multipliers from each while's
    ``backend_config known_trip_count``;
  * counts **flops** from every ``dot`` op (2 x out_elems x contraction),
    weighted by its computation's multiplier;
  * models **HBM bytes** as sum(operands) + output per *top-level* op in
    executed computations (post-fusion, so fusion interiors do not count),
    with slice/update ops counted at their true traffic, weighted likewise;
  * sums **collective bytes** by kind, weighted likewise.

All numbers are per-device (the module is the partitioned program).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ZERO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "iota", "partition-id",
                 "replica-id", "broadcast"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class Op:
    __slots__ = ("name", "type_str", "opcode", "operands", "rest")

    def __init__(self, name, type_str, opcode, operands, rest):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.operands = operands
        self.rest = rest


def _parse_op(line: str) -> Optional[Op]:
    m = _OP_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # type part: tuple "(...)" or "dtype[...]..." up to " <opcode>("
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str = rhs[:i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    # operand list = up to matching close paren
    depth = 0
    for j in range(par, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    operands = _OPERAND_RE.findall(rest[par:j + 1])
    return Op(name, type_str, opcode, operands, rest)


def parse_module(text: str):
    comps: Dict[str, List[Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            cur = cm.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            op = _parse_op(line)
            if op:
                comps[cur].append(op)
    return comps, entry


def _multipliers(comps, entry) -> Dict[str, float]:
    """Propagate trip-count multipliers from the entry computation."""
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    stack = [entry]
    seen_edges = set()
    while stack:
        c = stack.pop()
        m = mult[c]
        for op in comps.get(c, []):
            trip = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.rest)
                trip = float(tm.group(1)) if tm else 1.0
            for cm in _CALLED_RE.finditer(op.rest):
                names = ([cm.group(1)] if cm.group(1)
                         else _OPERAND_RE.findall(cm.group(2)))
                for nm in names:
                    key = (c, op.name, nm)
                    if key in seen_edges:
                        continue
                    seen_edges.add(key)
                    mult[nm] += m * trip
                    stack.append(nm)
    return mult


def _fusion_targets(comps) -> set:
    targets = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode.startswith("fusion"):
                cm = re.search(r"calls=%([\w.\-]+)", op.rest)
                if cm:
                    targets.add(cm.group(1))
    return targets


def _symbols(comps) -> Dict[str, str]:
    table = {}
    for ops in comps.values():
        for op in ops:
            table[op.name] = op.type_str
    return table


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    out = _type_elems(op.type_str)
    lhs_t = symbols.get(op.operands[0] if op.operands else "", "")
    lm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    dims_m = _SHAPE_RE.search(lhs_t)
    if not lm or not dims_m:
        return 2.0 * out            # fallback: rank-deficient dot
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for i in (int(x) for x in lm.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out * contract


def _op_bytes(op: Op, symbols: Dict[str, str],
              dus_fusions: Optional[set] = None,
              fusion_target=None) -> float:
    if op.opcode in _ZERO_TRAFFIC:
        return 0.0
    out_b = _type_bytes(op.type_str)
    if op.opcode in ("dynamic-slice", "gather"):
        return 2.0 * out_b
    if op.opcode == "dynamic-update-slice":
        upd = symbols.get(op.operands[1], "") if len(op.operands) > 1 else ""
        return 2.0 * _type_bytes(upd)
    if op.opcode in ("while", "conditional", "call"):
        return 0.0                  # traffic counted inside the body
    if op.opcode.startswith("fusion") and dus_fusions is not None:
        # in-place accumulation fusions (root = dynamic-update-slice, the
        # lowering of scan-output writes): the big buffer is aliased, true
        # traffic is the updated slice, not the whole array.
        tgt = fusion_target(op) if fusion_target else None
        if tgt in dus_fusions:
            return 2.0 * dus_fusions[tgt]
    opnd_b = sum(_type_bytes(symbols.get(o, "")) for o in op.operands)
    return out_b + opnd_b


def _dus_fusion_slices(comps) -> Dict[str, float]:
    """fused computations whose ROOT is a dynamic-update-slice -> bytes of
    the updated slice (the true traffic of the in-place write)."""
    out: Dict[str, float] = {}
    for cname, ops in comps.items():
        if not ops:
            continue
        root = ops[-1]
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            local = {o.name: o.type_str for o in ops}
            out[cname] = float(_type_bytes(local.get(root.operands[1], "")))
    return out


def analyze(text: str) -> Dict[str, Any]:
    comps, entry = parse_module(text)
    mult = _multipliers(comps, entry)
    fused = _fusion_targets(comps)
    symbols = _symbols(comps)
    dus_fusions = _dus_fusion_slices(comps)

    def fusion_target(op):
        m = re.search(r"calls=%([\w.\-]+)", op.rest)
        return m.group(1) if m else None

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = {k: 0.0 for k in _COLL_KINDS}
    coll_counts = {k: 0.0 for k in _COLL_KINDS}

    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for op in ops:
            base = op.opcode.replace("-start", "")
            if base in ("dot", "dot-general"):
                flops += m * _dot_flops(op, symbols)
            if not in_fusion:
                if not op.opcode.endswith("-done"):
                    bytes_accessed += m * _op_bytes(
                        op, symbols, dus_fusions, fusion_target)
                if base in _COLL_KINDS and not op.opcode.endswith("-done"):
                    coll_bytes[base] += m * _type_bytes(op.type_str)
                    coll_counts[base] += m
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collectives": {
            "bytes_by_kind": coll_bytes,
            "counts": coll_counts,
            "total_bytes": sum(coll_bytes.values()),
        },
    }
