import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract params/optimizer/batch/state specs,
attaches the production shardings, lowers the jitted step
(train_step / prefill / decode_step per the shape kind), compiles it, and
records memory_analysis + cost_analysis + the HLO collective-byte breakdown
into a JSON artifact consumed by launch.roofline and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import SHAPES, all_archs, cells, get_arch
from ..models import lm
from ..training.grad import make_train_step
from ..training.optimizer import AdamWConfig, adamw_init
from . import sharding as sh
from . import specs
from .hlo_analysis import analyze as hlo_analyze
from .mesh import make_production_mesh

TRAIN_MICROBATCHES = 16


def _jsonable(d: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in (d or {}).items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            continue
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int = TRAIN_MICROBATCHES) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    from ..models import runtime
    runtime.set_mesh(mesh, ("pod", "data") if multi_pod else ("data",))

    params_abs = specs.params_specs(cfg)
    params_sh = sh.params_shardings(mesh, params_abs)

    with mesh:
        if shape.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            opt_sh = sh.opt_shardings(mesh, opt_abs, params_sh)
            batch_abs = specs.train_batch_specs(cfg, shape)
            batch_sh = sh.batch_shardings(mesh, cfg, shape, batch_abs)
            opt_cfg = AdamWConfig()
            data_ax = ("pod", "data") if multi_pod else ("data",)
            data_size = 32 if multi_pod else 16
            mb = min(microbatches, shape.global_batch // data_size)
            microbatches = mb
            step = make_train_step(cfg, opt_cfg, microbatches=mb,
                                   param_shardings=params_sh,
                                   data_axes=data_ax)
            fn = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            batch_abs = specs.prefill_batch_specs(cfg, shape)
            batch_sh = sh.batch_shardings(mesh, cfg, shape, batch_abs)
            state_abs = jax.eval_shape(
                functools.partial(lm.prefill, cfg, cache_size=shape.seq_len),
                params_abs, batch_abs)[1]
            state_sh = sh.state_shardings(mesh, cfg, shape, state_abs)
            fn = jax.jit(
                functools.partial(lm.prefill, cfg, cache_size=shape.seq_len),
                in_shardings=(params_sh, batch_sh),
                out_shardings=(sh.logits_sharding(mesh, cfg, shape), state_sh))
            lowered = fn.lower(params_abs, batch_abs)
        else:  # decode
            tok_abs = specs.decode_token_specs(shape)
            state_abs = specs.decode_state_specs(cfg, shape)
            state_sh = sh.state_shardings(mesh, cfg, shape, state_abs)
            tok_sh = sh.batch_shardings(mesh, cfg, shape, tok_abs)
            fn = jax.jit(functools.partial(lm.decode_step, cfg),
                         in_shardings=(params_sh, tok_sh, state_sh),
                         out_shardings=(sh.logits_sharding(mesh, cfg, shape),
                                        state_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_abs, tok_abs, state_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    runtime.clear_mesh()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    deep = hlo_analyze(hlo)       # trip-count-aware flops/bytes/collectives

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _jsonable({
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "alias_size": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                           None),
        }),
        "cost_raw": {k: v for k, v in _jsonable(
            cost if isinstance(cost, dict) else
            (cost[0] if cost else {})).items()
            if k in ("flops", "bytes accessed", "transcendentals")},
        "flops": deep["flops"],
        "bytes_accessed": deep["bytes_accessed"],
        "collectives": deep["collectives"],
        "params": lm.param_count(cfg),
        "active_params": lm.active_param_count(cfg),
        "microbatches": microbatches if shape.kind == "train" else None,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=TRAIN_MICROBATCHES)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch in all_archs():
            if arch == "paper-cftrag":
                continue                      # paper config: not an assigned cell
            todo.extend(cells(arch))
    else:
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'2pod' if mp else '1pod'}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip] {tag} (artifact exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = lower_cell(arch, shape_name, mp,
                                 microbatches=args.microbatches)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                per_dev = rec["memory"].get("argument_size", 0) + \
                    rec["memory"].get("temp_size", 0)
                print(f"  ok: lower {rec['lower_s']}s compile "
                      f"{rec['compile_s']}s, args+temp/device "
                      f"{per_dev/2**30:.2f} GiB, flops/dev "
                      f"{rec['flops']:.3g}, coll/dev "
                      f"{rec['collectives']['total_bytes']/2**20:.1f} MiB",
                      flush=True)
            except Exception as e:              # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
