"""Mesh construction (FUNCTIONS only — importing this module must not touch
jax device state; the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Production meshes: one v5e pod (16x16=256 chips) or two (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device meshes for CI-scale distribution tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Batch/FSDP axes: ('pod','data') when a pod axis exists."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
