"""llama4-maverick-400b-a17b — MoE 128 experts top-1 + shared expert,
interleaved every 2nd layer (HF Llama-4 interleave_moe_layer_step=2), early
fusion (vision patches prepended as tokens; frontend STUB)
[hf:meta-llama/Llama-4-Maverick-17B-128E].

Totals with the assigned dims: 24 MoE layers x 128 experts x 3 x 5120 x 8192
= 386B routed + dense/attn/shared ~= 400B total, ~17B active (top-1)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    num_experts=128,
    top_k=1,
    moe_every=2,
    shared_expert=True,
    rope_theta=500_000.0,
    frontend="vit",
    num_patches=0,              # early fusion supported; LM shapes text-only
    frontend_dim=1408,
))
