"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242].  81 mamba2 blocks; one weight-shared GQA attention +
MLP block applied every ``attn_every`` mamba blocks (Zamba2's shared-block
design).  Sub-quadratic -> runs long_500k."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="zamba2-7b",
    family="mamba_hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    sub_quadratic=True,
))
