"""whisper-base — encoder-decoder, audio conv frontend STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356].

Decode shapes exercise the *decoder* with a 32k-token causal cache; the
encoder consumes the stubbed 1500-frame audio embedding."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    enc_layers=6,
    dec_layers=6,
    frontend="audio",
    num_patches=1500,           # 30 s of audio at 50 frames/s (stub frames)
    frontend_dim=512,
    rope_theta=10_000.0,        # (whisper uses learned pos; we use sinusoidal)
))
