"""pixtral-12b — VLM: pixtral-ViT frontend (STUB: precomputed patch
embeddings via input_specs) + mistral-nemo text backbone
[hf:mistralai/Pixtral-12B-2409]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000_000.0,
    frontend="vit",
    num_patches=256,            # one 1024px image @ 16px patches, pooled 4x
    frontend_dim=1024,          # pixtral ViT width before projection
))
