"""The paper's own generator setting: CFT-RAG serves a small dense LM
(the paper is retrieval-side; any backbone works — see DESIGN.md §4).
We pair it with the qwen2-0.5b-class dense config at RAG-serving shapes."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="paper-cftrag",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=64000,
    qkv_bias=True,
    tie_embeddings=True,
))
