"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay
[arXiv:2404.05892].  Sub-quadratic -> runs long_500k."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # head size 64 (rwkv6 convention)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    sub_quadratic=True,
))
