"""Architecture + shape registries (one module per assigned arch)."""
from .base import (ARCH_REGISTRY, SHAPES, ModelConfig, ShapeConfig, all_archs,
                   cells, get_arch, register)

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (granite_moe_1b_a400m, llama4_maverick_400b_a17b,  # noqa: F401
                   mistral_large_123b, paper, pixtral_12b, qwen2_0_5b,
                   qwen2_5_14b, rwkv6_1_6b, whisper_base, yi_34b, zamba2_7b)


__all__ = ["ARCH_REGISTRY", "SHAPES", "ModelConfig", "ShapeConfig",
           "all_archs", "cells", "get_arch", "register"]
