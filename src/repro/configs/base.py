"""Config system: model architecture + input-shape registries.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro/configs/<arch>.py``) registered under its ``--arch`` id; every
assigned input shape is a ``ShapeConfig``.  ``smoke()`` derives the reduced
same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

ARCH_REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | mamba_hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # None -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # 1: every layer MoE; 2: interleaved (llama4)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM / hybrid (zamba2, rwkv6) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0         # zamba2: shared attn every k mamba blocks
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- modality frontend stubs ---
    frontend: str = "none"      # none | audio | vit
    num_patches: int = 0        # vlm: prepended patch embeddings
    frontend_dim: int = 0       # stub embedding dim (== d_model after proj)
    # --- runtime ---
    dtype: str = "bfloat16"
    attn_impl: str = "blocked"  # reference | blocked | flash
    attn_chunk: int = 1024      # blocked-attention kv tile
    scan_layers: bool = True
    remat: str = "full"         # none | full | dots
    sub_quadratic: bool = False # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 128-multiple so the embedding / lm_head
        shard over the model axis (Megatron-style padding; granite's 49155
        and whisper's 51865 otherwise replicate the head — measured at
        ~37% of the training-step flops).  Logits beyond ``vocab`` are
        masked to -inf in the loss/sampler."""
        return -(-self.vocab // 128) * 128

    @property
    def d_inner(self) -> int:   # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----------------------------------------------------------- smoke form
    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            dtype="float32",
            remat="none",
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2), d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16)
        if self.attn_every:
            kw.update(attn_every=2, n_layers=4)
        if self.enc_layers:
            kw.update(enc_layers=2, dec_layers=2)
        if self.num_patches:
            kw.update(num_patches=8)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ModelConfig:
    from . import _load_all          # lazy-populate the registry
    _load_all()
    return ARCH_REGISTRY[arch_id]


def all_archs() -> Tuple[str, ...]:
    from . import _load_all
    _load_all()
    return tuple(sorted(ARCH_REGISTRY))


def cells(arch_id: str) -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) cells for an arch, honouring long_500k skips."""
    cfg = get_arch(arch_id)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue            # pure full-attention archs skip (DESIGN §4)
        out.append((arch_id, s.name))
    return tuple(out)
