"""granite-moe-1b-a400m — MoE 32 experts top-8, every layer
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    num_experts=32,
    top_k=8,
    moe_every=1,
    tie_embeddings=True,
    rope_theta=10_000.0,
))
