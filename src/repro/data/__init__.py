"""Data substrate: corpora -> entities -> relations -> entity forest."""
from .datasets import SyntheticCorpus, hospital_corpus, unhcr_corpus
from .filtering import filter_relations
from .ner import recognize_entities
from .relations import extract_relations
from .tokenizer import HashTokenizer
from .pipeline import PackedBatches, TextDataset

__all__ = [
    "SyntheticCorpus", "hospital_corpus", "unhcr_corpus",
    "filter_relations", "recognize_entities", "extract_relations",
    "HashTokenizer", "PackedBatches", "TextDataset",
]
