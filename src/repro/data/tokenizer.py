"""Hash tokenizer — vocabulary-size-parameterized, deterministic, offline.

Every assigned architecture declares its own vocab size (64000, 152064, ...);
a hash tokenizer maps any token to a stable id inside that space without
shipping vocabulary files.  Collisions are harmless for the synthetic
training task; a reverse map of seen tokens supports decoding for demos.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Sequence

from ..core import hashing

_TOKEN_RE = re.compile(r"[A-Za-z0-9]+|[^\sA-Za-z0-9]")


class HashTokenizer:
    PAD, BOS, EOS, SEP = 0, 1, 2, 3
    NUM_SPECIAL = 4

    def __init__(self, vocab_size: int):
        assert vocab_size > self.NUM_SPECIAL
        self.vocab_size = vocab_size
        self._space = vocab_size - self.NUM_SPECIAL
        self._reverse: Dict[int, str] = {}

    def token_id(self, token: str) -> int:
        tid = int(hashing.fnv1a_64(token)) % self._space + self.NUM_SPECIAL
        self._reverse.setdefault(tid, token)
        return tid

    def encode(self, text: str, bos: bool = False, eos: bool = False
               ) -> List[int]:
        ids = [self.token_id(t) for t in _TOKEN_RE.findall(text)]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        out = []
        for i in ids:
            if i == self.PAD:
                continue
            if i == self.BOS:
                out.append("<s>")
            elif i == self.EOS:
                out.append("</s>")
            elif i == self.SEP:
                out.append("<sep>")
            else:
                out.append(self._reverse.get(int(i), f"<{int(i)}>"))
        return " ".join(out)
