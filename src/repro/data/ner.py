"""Entity recognition — deterministic spaCy stand-in (paper §2.1).

The paper uses spaCy's statistical NER to pull entities out of user queries.
Offline we replace it with the two mechanisms that matter for Tree-RAG:

1. **Gazetteer matching** — maximal-span match against the knowledge base's
   entity vocabulary (in production T-RAG the recognized entities are only
   useful if they exist in the forest anyway).
2. **Capitalization heuristics** — contiguous TitleCase token runs are
   surfaced as candidate entities (emulating spaCy's PERSON/ORG behaviour on
   unseen names) so the pipeline also works before the forest is built.

Deterministic, dependency-free, and O(len(text)) with a token-trie.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Optional, Sequence

_TOKEN_RE = re.compile(r"[A-Za-z0-9_]+(?:'[a-z]+)?")

_STOP = {"The", "A", "An", "In", "On", "Of", "And", "Or", "What", "Which",
         "How", "Who", "Where", "When", "Describe", "It", "Its", "This"}


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text)


class _Trie:
    __slots__ = ("children", "terminal")

    def __init__(self):
        self.children: Dict[str, "_Trie"] = {}
        self.terminal: Optional[str] = None


def add_to_gazetteer(root: _Trie, entity: str) -> None:
    """Register one more entity name on a live gazetteer (dynamic bank
    maintenance inserts entities after the trie was built)."""
    node = root
    for tok in tokenize(entity):
        node = node.children.setdefault(tok.lower(), _Trie())
    node.terminal = entity


def build_gazetteer(entities: Iterable[str]) -> _Trie:
    root = _Trie()
    for ent in entities:
        add_to_gazetteer(root, ent)
    return root


def recognize_entities(text: str, gazetteer: Optional[_Trie] = None,
                       use_heuristics: bool = True) -> List[str]:
    """Entities in order of first occurrence, de-duplicated."""
    toks = tokenize(text)
    found: List[str] = []
    seen = set()
    i = 0
    while i < len(toks):
        match = None
        match_len = 0
        if gazetteer is not None:          # maximal-span gazetteer match
            node = gazetteer
            j = i
            while j < len(toks) and toks[j].lower() in node.children:
                node = node.children[toks[j].lower()]
                j += 1
                if node.terminal is not None:
                    match, match_len = node.terminal, j - i
        if match is None and use_heuristics:
            j = i
            while (j < len(toks) and toks[j][:1].isupper()
                   and toks[j] not in _STOP):
                j += 1
            if j - i >= 2 or (j - i == 1 and i > 0):   # sentence-initial 1-tok
                match, match_len = " ".join(toks[i:j]), j - i
        if match is not None:
            if match not in seen:
                seen.add(match)
                found.append(match)
            i += match_len
        else:
            i += 1
    return found
