"""Host data pipeline: documents -> packed token batches, checkpointable.

Design points that matter at pod scale:
* deterministic **host sharding** — host h of H receives documents h::H, so
  the global batch is reproducible for any host count (elastic restarts);
* **packing** — documents are concatenated with EOS separators and cut into
  fixed seq_len windows (no padding waste);
* **stateful iteration** — (epoch, cursor) travels with the training
  checkpoint, so a preempted job resumes mid-epoch without replaying data;
* **prefetch** — a one-slot background thread keeps the next batch ready
  while the step runs (host-compute / device-compute overlap).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .tokenizer import HashTokenizer


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    cursor: int = 0          # token offset within the epoch's stream

    def as_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class TextDataset:
    """Tokenized, host-sharded document stream."""

    def __init__(self, documents: Sequence[str], tokenizer: HashTokenizer,
                 host_id: int = 0, num_hosts: int = 1, seed: int = 0):
        self.tokenizer = tokenizer
        self.seed = seed
        self._docs = list(documents[host_id::num_hosts])
        if not self._docs:
            self._docs = ["empty shard"]

    def epoch_tokens(self, epoch: int) -> np.ndarray:
        """The epoch's full token stream (shuffled doc order, EOS-joined)."""
        rng = np.random.default_rng(self.seed + epoch)
        order = rng.permutation(len(self._docs))
        ids: List[int] = []
        for di in order:
            ids.extend(self.tokenizer.encode(self._docs[di], bos=True,
                                             eos=True))
        return np.asarray(ids, dtype=np.int32)


class PackedBatches:
    """Iterator of {tokens, labels, mask} packed LM batches."""

    def __init__(self, dataset: TextDataset, batch_size: int, seq_len: int,
                 state: Optional[PipelineState] = None,
                 prefetch: bool = True):
        self.ds = dataset
        self.batch = batch_size
        self.seq = seq_len
        self.state = state or PipelineState()
        self._stream = self.ds.epoch_tokens(self.state.epoch)
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ batching
    def _next_window(self) -> np.ndarray:
        need = self.batch * (self.seq + 1)
        while self.state.cursor + need > self._stream.shape[0]:
            self.state = PipelineState(epoch=self.state.epoch + 1, cursor=0)
            self._stream = self.ds.epoch_tokens(self.state.epoch)
            if self._stream.shape[0] < need:     # tiny corpora: tile up
                reps = need // max(1, self._stream.shape[0]) + 1
                self._stream = np.tile(self._stream, reps)
        w = self._stream[self.state.cursor:self.state.cursor + need]
        self.state.cursor += need
        return w.reshape(self.batch, self.seq + 1)

    def next_batch(self) -> dict:
        w = self._next_window()
        return {
            "tokens": w[:, :-1].astype(np.int32),
            "labels": w[:, 1:].astype(np.int32),
            "mask": (w[:, 1:] != HashTokenizer.PAD).astype(np.float32),
        }

    # ------------------------------------------------------------ prefetch
    def _worker(self):
        while True:
            item = self.next_batch()
            self._q.put(item)        # blocks when the slot is full

    def __iter__(self) -> Iterator[dict]:
        if not self._prefetch:
            while True:
                yield self.next_batch()
        self._q = queue.Queue(maxsize=1)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        while True:
            yield self._q.get()

    # ------------------------------------------------------- checkpointing
    def checkpoint_state(self) -> dict:
        return self.state.as_dict()

    def restore_state(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
        self._stream = self.ds.epoch_tokens(self.state.epoch)
