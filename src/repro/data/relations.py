"""Relationship extraction (paper §2.2) — dependency-pattern stand-in.

The paper runs dependency parsers (gpt-4 / open-source NLP) and keeps the
dependency-expressing relations: "belongs to", "contains", "is part of",
"is dependent on", plus conjunction handling ("A and B belong to C" groups
both children under C).  We implement those surface patterns directly over
the recognizer's entity spans — deterministic and offline.
"""
from __future__ import annotations

import re
from typing import List, Optional, Sequence, Tuple

from .ner import recognize_entities, build_gazetteer

Edge = Tuple[str, str]      # (parent, child)

#: pattern -> which side is the parent. {a}/{b} are entity placeholders.
_PATTERNS = [
    (re.compile(r"\bbelongs? to\b", re.I), "right"),    # A belongs to B
    (re.compile(r"\bis part of\b", re.I), "right"),
    (re.compile(r"\bis dependent on\b", re.I), "right"),
    (re.compile(r"\breports? to\b", re.I), "right"),
    (re.compile(r"\bunder the guidance of\b", re.I), "right"),
    (re.compile(r"\bcontains?\b", re.I), "left"),       # B contains A
    (re.compile(r"\bconsists? of\b", re.I), "left"),
    (re.compile(r"\bincludes?\b", re.I), "left"),
    (re.compile(r"\boversees?\b", re.I), "left"),
]

_SENT_SPLIT = re.compile(r"[.!?]\s+|[.!?]$")
_CONJ = re.compile(r"\b(?:and|or)\b", re.I)


def _split_conjuncts(segment: str, gazetteer) -> List[str]:
    """Entities in a segment, honouring conjunctions (grouping siblings)."""
    ents: List[str] = []
    for part in _CONJ.split(segment):
        ents.extend(recognize_entities(part, gazetteer))
    return ents


def extract_relations(text: str, entities: Optional[Sequence[str]] = None
                      ) -> List[Edge]:
    """Parent->child edges found in ``text``.

    ``entities``: optional gazetteer vocabulary; when omitted, capitalization
    heuristics alone drive recognition (as on raw unseen text).
    """
    gaz = build_gazetteer(entities) if entities is not None else None
    edges: List[Edge] = []
    for sentence in _SENT_SPLIT.split(text):
        if not sentence.strip():
            continue
        for pat, parent_side in _PATTERNS:
            m = pat.search(sentence)
            if not m:
                continue
            left_ents = _split_conjuncts(sentence[:m.start()], gaz)
            right_ents = _split_conjuncts(sentence[m.end():], gaz)
            if not left_ents or not right_ents:
                continue
            if parent_side == "right":
                parent = right_ents[0]
                children = left_ents          # all conjuncts share the parent
            else:
                parent = left_ents[-1]
                children = right_ents
            for child in children:
                if child != parent:
                    edges.append((parent, child))
            break    # one relation pattern per sentence (first match wins)
    return edges
