"""Relationship filtering (paper §2.3) — keep the edge set a forest.

Order of operations (each rule from the paper, Figure 3):
  1. self-pointing edges removed;
  2. duplicate edges pruned to one;
  3. cycles cut — "only the closest relationship is retained": the edge that
     appeared *first* in extraction order wins, the back-edge that would close
     a cycle is dropped;
  4. transitive relations reduced — (A,C) is dropped when a longer path
     A ->* C exists through retained edges;
  5. single-parent enforcement — a tree node has one parent; the earliest
     extracted parent is kept (extraction order is the paper's proxy for
     relation confidence).
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Dict, List, Sequence, Set, Tuple

Edge = Tuple[str, str]


def _reachable(adj: Dict[str, List[str]], src: str, dst: str,
               skip_direct: bool = False) -> bool:
    """Is dst reachable from src? skip_direct ignores the direct edge."""
    q = deque([src])
    seen = {src}
    first = True
    while q:
        u = q.popleft()
        for v in adj.get(u, ()):
            if first and skip_direct and u == src and v == dst:
                continue
            if v == dst:
                return True
            if v not in seen:
                seen.add(v)
                q.append(v)
        first = False
    return False


def filter_relations(edges: Sequence[Edge]) -> List[Edge]:
    # 1 + 2: self loops and duplicates (order-preserving)
    seen: Set[Edge] = set()
    stage: List[Edge] = []
    for p, c in edges:
        if p == c or (p, c) in seen:
            continue
        seen.add((p, c))
        stage.append((p, c))

    # 3: cycle cutting — accept edges in order, reject any that closes a cycle
    adj: Dict[str, List[str]] = defaultdict(list)
    acyclic: List[Edge] = []
    for p, c in stage:
        if _reachable(adj, c, p):       # adding p->c would close a cycle
            continue
        adj[p].append(c)
        acyclic.append((p, c))

    # 4: transitive reduction — drop (p, c) if another path p ->* c exists
    adj = defaultdict(list)
    for p, c in acyclic:
        adj[p].append(c)
    reduced: List[Edge] = []
    for p, c in acyclic:
        if _reachable(adj, p, c, skip_direct=True):
            adj[p].remove(c)            # distant relation removed
        else:
            reduced.append((p, c))

    # 5: single parent per child (earliest wins)
    parent_of: Dict[str, str] = {}
    out: List[Edge] = []
    for p, c in reduced:
        if c in parent_of:
            continue
        parent_of[c] = p
        out.append((p, c))
    return out


def is_forest(edges: Sequence[Edge]) -> bool:
    """Validation predicate used by tests: acyclic + single parent."""
    parents: Dict[str, str] = {}
    adj: Dict[str, List[str]] = defaultdict(list)
    for p, c in edges:
        if p == c or c in parents:
            return False
        parents[c] = p
        adj[p].append(c)
    # acyclicity via iterative DFS coloring
    color: Dict[str, int] = {}
    for start in list(adj):
        if color.get(start):
            continue
        stack = [(start, iter(adj.get(start, ())))]
        color[start] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for v in it:
                if color.get(v) == 1:
                    return False
                if color.get(v, 0) == 0:
                    color[v] = 1
                    stack.append((v, iter(adj.get(v, ()))))
                    advanced = True
                    break
            if not advanced:
                color[node] = 2
                stack.pop()
    return True
