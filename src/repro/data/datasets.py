"""Synthetic corpora mirroring the paper's two datasets (§4.3).

* ``unhcr_corpus``    — UNHCR-style organizational charts: pre-segmented
  hierarchy (the original dataset ships as entity pairs), deep org trees.
* ``hospital_corpus`` — hospital-history documents: raw text whose relations
  must be *extracted* (the paper runs dependency parsing on this one), with
  department / ward / clinic hierarchies.

Both are deterministic given a seed and scale to the paper's sizes (600
trees, ~3k entities).  Each corpus carries gold trees so retrieval accuracy
is measurable without an LLM judge (see DESIGN.md §7 accuracy proxy).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Sequence, Tuple

Edge = Tuple[str, str]

_ORG_UNITS = ["Division", "Bureau", "Section", "Unit", "Service", "Office",
              "Team", "Desk", "Mission", "Programme"]
_ORG_THEMES = ["Protection", "Operations", "Relief", "Logistics", "Health",
               "Shelter", "Registration", "Resettlement", "Field", "Policy",
               "Donor", "Legal", "Supply", "Education", "Emergency"]
_HOSP_UNITS = ["Department", "Ward", "Clinic", "Laboratory", "Center",
               "Institute", "Pharmacy", "Unit", "Station", "Group"]
_HOSP_THEMES = ["Cardiology", "Oncology", "Neurology", "Pediatrics",
                "Radiology", "Surgery", "Orthopedics", "Pathology",
                "Anesthesia", "Dermatology", "Urology", "Gastroenterology",
                "Hematology", "Nephrology", "Respiratory"]

_RELATION_TEMPLATES = [
    "{child} belongs to {parent}.",
    "{parent} contains {child}.",
    "{child} is part of {parent}.",
    "{child} is dependent on {parent}.",
    "{child} and {sibling} belong to {parent}.",
]

_QUERY_TEMPLATES = [
    "What is the role of {e} in the organization?",
    "Describe the history of {e} and its parent units.",
    "Which teams report to {e}?",
    "How does {e} relate to its departments?",
]


@dataclasses.dataclass
class SyntheticCorpus:
    name: str
    documents: List[str]               # raw text (relation sentences + noise)
    trees: List[List[Edge]]            # gold hierarchy per tree
    entities: List[str]                # gold entity vocabulary
    queries: List[str]                 # natural-language queries
    query_entities: List[List[str]]    # gold entities per query

    @property
    def num_entities(self) -> int:
        return len(self.entities)


def _make_tree(rng: random.Random, prefix: str, units: Sequence[str],
               themes: Sequence[str], depth: int, branching: int) -> List[Edge]:
    """Random tree of named units; names unique within the tree."""
    counter = [0]

    def name() -> str:
        counter[0] += 1
        return (f"{rng.choice(themes)} {rng.choice(units)} "
                f"{prefix}{counter[0]}")

    edges: List[Edge] = []
    root = f"{rng.choice(themes)} Headquarters {prefix}0"
    frontier = [root]
    for _ in range(depth):
        nxt: List[str] = []
        for parent in frontier:
            for _ in range(rng.randint(1, branching)):
                child = name()
                edges.append((parent, child))
                nxt.append(child)
        frontier = nxt or frontier
        if not nxt:
            break
    return edges


def _corpus(name: str, units: Sequence[str], themes: Sequence[str],
            num_trees: int, depth: int, branching: int, num_queries: int,
            entities_per_query: int, seed: int,
            shared_entity_rate: float) -> SyntheticCorpus:
    rng = random.Random(seed)
    trees = [_make_tree(rng, f"T{t}_", units, themes, depth, branching)
             for t in range(num_trees)]

    # cross-tree shared entities: the same unit appearing in several trees is
    # what makes block linked lists non-trivial (multiple addresses/entity).
    all_names = sorted({n for tr in trees for e in tr for n in e})
    members = [sorted({n for e in tr for n in e}) for tr in trees]
    shared = rng.sample(all_names, max(1, int(len(all_names) * shared_entity_rate)))
    for s in shared:
        for _ in range(rng.randint(1, 3)):
            t = rng.randrange(num_trees)
            if s in members[t]:
                continue           # only graft where absent: keeps trees acyclic
            host = rng.choice(members[t])
            trees[t].append((host, s))
            members[t].append(s)

    entities = sorted({n for tr in trees for e in tr for n in e})

    documents: List[str] = []
    for tr in trees:
        sentences = []
        for parent, child in tr:
            tpl = rng.choice(_RELATION_TEMPLATES)
            sibling = rng.choice(entities)
            sentences.append(tpl.format(parent=parent, child=child,
                                        sibling=sibling))
            if rng.random() < 0.3:
                sentences.append(
                    f"In recent years, {child} expanded its mandate "
                    f"under the guidance of {parent}.")
        documents.append(" ".join(sentences))

    queries, query_entities = [], []
    for _ in range(num_queries):
        ents = rng.sample(entities, min(entities_per_query, len(entities)))
        q = " ".join(rng.choice(_QUERY_TEMPLATES).format(e=e) for e in ents)
        queries.append(q)
        query_entities.append(ents)

    return SyntheticCorpus(name=name, documents=documents, trees=trees,
                           entities=entities, queries=queries,
                           query_entities=query_entities)


def unhcr_corpus(num_trees: int = 50, depth: int = 4, branching: int = 3,
                 num_queries: int = 64, entities_per_query: int = 5,
                 seed: int = 20250114) -> SyntheticCorpus:
    """UNHCR-style org charts (pre-segmented hierarchy)."""
    return _corpus("unhcr", _ORG_UNITS, _ORG_THEMES, num_trees, depth,
                   branching, num_queries, entities_per_query, seed,
                   shared_entity_rate=0.05)


def hospital_corpus(num_trees: int = 600, depth: int = 3, branching: int = 3,
                    num_queries: int = 64, entities_per_query: int = 5,
                    seed: int = 20250607) -> SyntheticCorpus:
    """Hospital-history corpus (relations must be extracted from text)."""
    return _corpus("hospital", _HOSP_UNITS, _HOSP_THEMES, num_trees, depth,
                   branching, num_queries, entities_per_query, seed,
                   shared_entity_rate=0.08)
