"""repro — CFT-RAG (cuckoo-filter Tree-RAG) as a multi-pod JAX framework."""

__version__ = "0.1.0"
