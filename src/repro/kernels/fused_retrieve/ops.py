"""Public jit'd wrappers for the fused retrieval kernel.

Handles: query padding to the TILE multiple, f32 staging of the arena and
the packed CSR/forest context tables, arena-row padding for tiled grids,
VMEM-budget tile selection (shared derivation with ``cuckoo_lookup``), the
interpret/mxu switch off the backend, and repackaging into
``core.trag.DeviceRetrieval``.  Observability (``serve.fused_batches``,
``kernel.tile_rows``) is emitted from the non-traced auto entries so the
counters tick per call, not per trace.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.trag import NULL, DeviceRetrieval
from ...obs import get_registry
from .. import vmem
from ..cuckoo_lookup.kernel import TILE
from ..cuckoo_lookup.ops import lookup_vmem_budget, on_tpu, stage_tables
from .kernel import fused_retrieve_pallas, fused_retrieve_ragged_pallas

#: One-hot matmul gathers are exact in f32 only below this value bound;
#: wrappers assert every table dimension (node/CSR/arena counts) under it.
F32_EXACT_MAX = 1 << 24


def stage_context_tables(csr_offsets, csr_nodes, parent, entity_id,
                         child_offsets, child_index
                         ) -> Tuple[jax.Array, ...]:
    """Pack the CSR/forest tables into the kernel's f32 gather layout:

    csr_lc      (R+1, 2)  [row start | row count], final row the empty
                          miss sentinel [terminal, 0]
    csr_nodes   (L, 1)
    parent_eid  (N, 2)    [parent node | entity id]
    child_lc    (N, 2)    [children start | child count]
    child_index (C, 1)
    """
    lo = csr_offsets[:-1]
    cnt = csr_offsets[1:] - lo
    csr_lc = jnp.stack(
        [jnp.concatenate([lo, csr_offsets[-1:]]),
         jnp.concatenate([cnt, jnp.zeros((1,), cnt.dtype)])],
        axis=1).astype(jnp.float32)
    nodes2 = csr_nodes.astype(jnp.float32)[:, None]
    if nodes2.shape[0] == 0:
        nodes2 = jnp.zeros((1, 1), jnp.float32)
    parent_eid = jnp.stack([parent, entity_id], axis=1).astype(jnp.float32)
    child_lc = jnp.stack(
        [child_offsets[:-1], child_offsets[1:] - child_offsets[:-1]],
        axis=1).astype(jnp.float32)
    cidx2 = child_index.astype(jnp.float32)[:, None]
    if cidx2.shape[0] == 0:
        cidx2 = jnp.zeros((1, 1), jnp.float32)
    return csr_lc, nodes2, parent_eid, child_lc, cidx2


def _check_f32_exact(*dims: int) -> None:
    for d in dims:
        if d >= F32_EXACT_MAX:
            raise ValueError(
                f"table dimension {d} >= 2^24 breaks f32-exact one-hot "
                "gathers; shard the bank (core.distributed) first")


def fused_vmem_budget() -> vmem.VmemBudget:
    """The fused kernel shares the probe's measured VMEM derivation."""
    return lookup_vmem_budget()


def context_resident_bytes(arena_rows: int, slots: int, num_csr_rows: int,
                           num_csr_nodes: int, num_nodes: int,
                           num_children: int, mxu: bool) -> int:
    """VMEM pinned for the whole launch: temperature in+out blocks, the
    packed context tables, and (mxu) the (TILE, A) bump one-hot."""
    resident = 2 * arena_rows * slots * 4          # temperature in + out
    resident += (num_csr_rows + 1) * 2 * 4         # csr_lc (+ sentinel)
    resident += max(num_csr_nodes, 1) * 4
    resident += num_nodes * 4 * 4                  # parent_eid + child_lc
    resident += max(num_children, 1) * 4
    if mxu:
        resident += TILE * arena_rows * 4          # bump one-hot operand
    return resident


def fused_supported(arena_rows: int, slots: int, resident_bytes: int,
                    mxu: bool) -> bool:
    """Whether the fused kernel's resident working set fits the budget.
    Interpret mode has no VMEM constraint; on TPU, arenas whose resident
    blocks (temperature + context tables + bump one-hot) overflow the
    budget fall back to the unfused oracle path."""
    if not mxu:
        return True
    budget = fused_vmem_budget()
    return resident_bytes + TILE * budget.per_row_bytes \
        <= budget.budget_bytes


def fused_row_tile(arena_rows: int, resident_bytes: int) -> int:
    """0 = whole arena as one block; else the probe-tile row count (TILE
    multiple) fitting the measured budget after the resident blocks."""
    budget = fused_vmem_budget()
    cap = vmem.max_rows_for_vmem(budget, TILE, resident_bytes)
    return 0 if arena_rows <= cap else cap


def _pad_queries(b, *arrs):
    pad = (-b) % TILE
    return [jnp.pad(a, (0, pad)) for a in arrs]


def _pad_arena(row_tile, *tables):
    if row_tile <= 0:
        return tables
    a = tables[0].shape[0]
    row_pad = (-a) % row_tile
    return [jnp.pad(t, ((0, row_pad), (0, 0))) for t in tables]


def _repack(outs, b, a, max_locs, n) -> DeviceRetrieval:
    hit, _head, _bucket, _slot, _prio, loc, up, down, temp = outs
    return DeviceRetrieval(
        hit=hit[:b].astype(jnp.bool_), locations=loc[:b],
        up=up[:b].reshape(b, max_locs, n),
        down=down[:b].reshape(b, max_locs, n),
        temperature=temp[:a])


@functools.partial(jax.jit, static_argnames=("max_locs", "n", "interpret",
                                             "row_tile", "mxu"))
def fused_retrieve_arena(fingerprints, temperature, heads, row_offsets,
                         masks, valid, h, csr_offsets, csr_nodes, parent,
                         entity_id, child_offsets, child_index,
                         max_locs: int = 4, n: int = 3,
                         interpret: bool = True, row_tile: int = 0,
                         mxu: bool = False) -> DeviceRetrieval:
    """Pre-routed fused retrieval: per-query (segment start, bucket mask)
    pairs as in ``core.lookup.lookup_arena``, plus a ``valid`` admission
    mask (the unfused path's ``in_range``).  Returns a full
    ``DeviceRetrieval`` from one kernel launch."""
    a, s = fingerprints.shape
    _check_f32_exact(a, csr_offsets.shape[0], csr_nodes.shape[0],
                     parent.shape[0], child_index.shape[0])
    b = h.shape[0]
    hp, op, mp, vp = _pad_queries(
        b, h.astype(jnp.uint32), row_offsets.astype(jnp.int32),
        masks.astype(jnp.uint32), valid.astype(jnp.int32))
    fp32, hd32 = stage_tables(fingerprints, heads)
    fp32, hd32, temp = _pad_arena(row_tile, fp32, hd32, temperature)
    ctx = stage_context_tables(csr_offsets, csr_nodes, parent, entity_id,
                               child_offsets, child_index)
    outs = fused_retrieve_pallas(
        hp, op, mp, vp, fp32, hd32, temp, *ctx, max_locs=max_locs, n=n,
        interpret=interpret, row_tile=row_tile, mxu=mxu)
    return _repack(outs, b, a, max_locs, n)


@functools.partial(jax.jit, static_argnames=("max_locs", "n", "interpret",
                                             "row_tile", "mxu"))
def fused_retrieve_ragged(fingerprints, temperature, heads, bucket_offsets,
                          tree_nb, tree_ids, h, csr_offsets, csr_nodes,
                          parent, entity_id, child_offsets, child_index,
                          max_locs: int = 4, n: int = 3,
                          interpret: bool = True, row_tile: int = 0,
                          mxu: bool = False) -> DeviceRetrieval:
    """Tree-routed fused retrieval — the ``retrieve_device(fused=True)``
    entry.  Out-of-range tree ids miss (clamped for the gather, masked via
    ``valid``), exactly as the unfused path's ``in_range`` handling."""
    a, s = fingerprints.shape
    num_trees = tree_nb.shape[0]
    _check_f32_exact(a, csr_offsets.shape[0], csr_nodes.shape[0],
                     parent.shape[0], child_index.shape[0])
    b = h.shape[0]
    in_range = (tree_ids >= 0) & (tree_ids < num_trees)
    tp = jnp.where(in_range, tree_ids, 0).astype(jnp.int32)
    hp, tpp, vp = _pad_queries(b, h.astype(jnp.uint32), tp,
                               in_range.astype(jnp.int32))
    fp32, hd32 = stage_tables(fingerprints, heads)
    fp32, hd32, temp = _pad_arena(row_tile, fp32, hd32, temperature)
    ctx = stage_context_tables(csr_offsets, csr_nodes, parent, entity_id,
                               child_offsets, child_index)
    outs = fused_retrieve_ragged_pallas(
        hp, tpp, vp, bucket_offsets, tree_nb, fp32, hd32, temp, *ctx,
        max_locs=max_locs, n=n, interpret=interpret, row_tile=row_tile,
        mxu=mxu)
    return _repack(outs, b, a, max_locs, n)


@functools.partial(jax.jit, static_argnames=("max_locs", "interpret",
                                             "row_tile", "mxu"))
def fused_probe_locs(fingerprints, temperature, heads, row_offsets, masks,
                     valid, h, csr_offsets, csr_nodes, max_locs: int = 4,
                     interpret: bool = True, row_tile: int = 0,
                     mxu: bool = False):
    """Owner-shard fusion: probe + temperature bump + CSR location window
    in one launch, no hierarchy tail (the forest walk runs on the source
    shard after the route-back all-to-all).  Returns ``(hit (B,) bool,
    locations (B, max_locs) int32, temperature (A, S))``."""
    a, s = fingerprints.shape
    _check_f32_exact(a, csr_offsets.shape[0], csr_nodes.shape[0])
    b = h.shape[0]
    hp, op, mp, vp = _pad_queries(
        b, h.astype(jnp.uint32), row_offsets.astype(jnp.int32),
        masks.astype(jnp.uint32), valid.astype(jnp.int32))
    fp32, hd32 = stage_tables(fingerprints, heads)
    fp32, hd32, temp = _pad_arena(row_tile, fp32, hd32, temperature)
    dummy = jnp.zeros((1,), jnp.int32)
    csr_lc, nodes2, pe, clc, cidx = stage_context_tables(
        csr_offsets, csr_nodes, dummy, dummy,
        jnp.zeros((2,), jnp.int32), dummy)
    hit, _head, _bucket, _slot, _prio, loc, tout = fused_retrieve_pallas(
        hp, op, mp, vp, fp32, hd32, temp, csr_lc, nodes2, pe, clc, cidx,
        max_locs=max_locs, n=1, interpret=interpret, row_tile=row_tile,
        mxu=mxu, locs_only=True)
    return hit[:b].astype(jnp.bool_), loc[:b], tout[:a]


def _emit_obs(row_tile: int) -> None:
    reg = get_registry()
    reg.counter("serve.fused_batches",
                "batches served by the fused retrieval kernel").inc()
    reg.gauge("kernel.tile_rows",
              "arena rows per fused-kernel grid step (0 = single block)"
              ).set(row_tile)


@functools.lru_cache(maxsize=256)
def _auto_plan(arena_rows: int, slots: int, num_csr_rows: int,
               num_csr_nodes: int, num_nodes: int, num_children: int
               ) -> Optional[Tuple[bool, bool, int]]:
    """Per-geometry launch plan (interpret, mxu, row_tile) — None when
    the resident working set overflows the TPU VMEM budget.  Cached so
    the hot serving path pays the derivation once per table geometry."""
    interpret = not on_tpu()
    mxu = not interpret
    resident = context_resident_bytes(arena_rows, slots, num_csr_rows,
                                      num_csr_nodes, num_nodes,
                                      num_children, mxu)
    if not fused_supported(arena_rows, slots, resident, mxu):
        return None                                # pragma: no cover - TPU
    rt = 0 if interpret else fused_row_tile(arena_rows, resident)
    return interpret, mxu, rt


def fused_retrieve_state_auto(state, query_hashes, query_trees=None,
                              max_locs: int = 4, n: int = 3
                              ) -> Optional[DeviceRetrieval]:
    """Backend-aware fused entry over a ``CFTDeviceState``: kernel with
    MXU one-hot gathers on TPU, interpret + direct gathers elsewhere.
    Returns None when the fused resident working set cannot fit the VMEM
    budget (huge arenas on TPU) — the caller falls back to the unfused
    oracle."""
    if query_trees is None:
        query_trees = jnp.zeros(query_hashes.shape, jnp.int32)
    a, s = state.fingerprints.shape
    plan = _auto_plan(a, s, state.csr_offsets.shape[0] - 1,
                      state.csr_nodes.shape[0], state.parent.shape[0],
                      state.child_index.shape[0])
    if plan is None:                               # pragma: no cover - TPU
        return None
    interpret, mxu, rt = plan
    _emit_obs(rt)
    return fused_retrieve_ragged(
        state.fingerprints, state.temperature, state.heads,
        state.bucket_offsets, state.tree_nb, query_trees, query_hashes,
        state.csr_offsets, state.csr_nodes, state.parent, state.entity_id,
        state.child_offsets, state.child_index, max_locs=max_locs, n=n,
        interpret=interpret, row_tile=rt, mxu=mxu)


def fused_retrieve_arena_auto(fingerprints, temperature, heads,
                              row_offsets, masks, valid, h, csr_offsets,
                              csr_nodes, parent, entity_id, child_offsets,
                              child_index, max_locs: int = 4, n: int = 3
                              ) -> DeviceRetrieval:
    """Backend-aware pre-routed fused entry (tests / direct callers)."""
    interpret = not on_tpu()
    mxu = not interpret
    a, s = fingerprints.shape
    resident = context_resident_bytes(
        a, s, csr_offsets.shape[0] - 1, csr_nodes.shape[0],
        parent.shape[0], child_index.shape[0], mxu)
    rt = 0 if interpret else fused_row_tile(a, resident)
    _emit_obs(rt)
    return fused_retrieve_arena(
        fingerprints, temperature, heads, row_offsets, masks, valid, h,
        csr_offsets, csr_nodes, parent, entity_id, child_offsets,
        child_index, max_locs=max_locs, n=n, interpret=interpret,
        row_tile=rt, mxu=mxu)
