from .kernel import (TILE, fused_retrieve_pallas, fused_retrieve_ragged_pallas)
from .ops import (fused_probe_locs, fused_retrieve_arena,
                  fused_retrieve_arena_auto, fused_retrieve_ragged,
                  fused_retrieve_state_auto, fused_row_tile,
                  fused_vmem_budget, stage_context_tables)
from .ref import (fused_retrieve_ref, gather_descendants_unrolled,
                  gather_hierarchy_unrolled)

__all__ = ["TILE", "fused_retrieve_pallas", "fused_retrieve_ragged_pallas",
           "fused_retrieve_arena", "fused_retrieve_arena_auto",
           "fused_retrieve_ragged", "fused_retrieve_state_auto",
           "fused_probe_locs", "fused_row_tile", "fused_vmem_budget",
           "stage_context_tables", "fused_retrieve_ref",
           "gather_hierarchy_unrolled", "gather_descendants_unrolled"]
