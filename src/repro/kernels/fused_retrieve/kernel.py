"""Pallas TPU kernel: fused CFT-RAG retrieval — one pass from query hash to
context rows.

Dataflow per query tile (TILE=128 lanes), all stages on-chip:

    hash -> arena probe (shared ``_arena_probe`` accumulators, arena rows
    streamed in ``row_tile`` blocks over the inner grid axis, double-
    buffered by the Pallas pipeline) -> temperature bump -> CSR location
    window (sentinel-row miss routing) -> ancestor / descendant hierarchy
    windows (static ``n``-step unrolled walks)

No ``(B,)``-shaped intermediate (hit/head/bucket/slot) ever round-trips
HBM: the probe accumulators live in the output blocks, and the context
tail consumes them in-register on the *last* arena tile, when the
cross-tile priority merge has settled.  The CSR/forest tables and the
temperature table ride as whole VMEM blocks with constant index maps
(resident for the launch, consecutively revisited — the budget in
``ops.fused_row_tile`` accounts for them).

Two static gather strategies (``mxu``):
  * ``mxu=True``  — one-hot matmul gathers on the MXU (TPU; exact in f32
    for values < 2^24, which the wrapper asserts from the table shapes).
  * ``mxu=False`` — direct clipped vector gathers (interpret mode, where
    one-hot matmuls would lower to giant dense XLA ops).
Both produce bit-identical results; tests pin them against each other and
against the unfused oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                      # TPU grid specs (scalar prefetch); optional on
    from jax.experimental.pallas import tpu as pltpu   # CPU-only installs
except ImportError:       # pragma: no cover - depends on the jax build
    pltpu = None

from ..cuckoo_lookup.kernel import TILE, _arena_probe

NULL = -1
_HIGHEST = jax.lax.Precision.HIGHEST


def _gather_rows(tab, idx, gate, mxu):
    """Gather rows of ``tab`` (R, C) f32 at ``idx`` (TILE,) int32; lanes
    with ``gate`` False yield zero rows (callers re-mask with their own
    sentinel).  mxu: one-hot matmul; else clipped direct indexing."""
    rows = tab.shape[0]
    if mxu:
        it = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], rows), 1)
        oh = ((it == idx[:, None]) & gate[:, None]).astype(jnp.float32)
        return jax.lax.dot(oh, tab, precision=_HIGHEST)
    safe = jnp.clip(idx, 0, rows - 1)
    return jnp.where(gate[:, None], tab[safe], jnp.float32(0))


def _up_walk(nodes, pe_tab, n, mxu):
    """Ancestor window (TILE, n) — mirrors ``gather_hierarchy_unrolled``
    on the packed (N, 2) [parent | entity_id] table."""
    cur = nodes
    outs = []
    for _ in range(n):
        g = cur != NULL
        prow = _gather_rows(pe_tab, jnp.maximum(cur, 0), g, mxu)
        p = jnp.where(g, prow[:, 0].astype(jnp.int32), NULL)
        g2 = p != NULL
        erow = _gather_rows(pe_tab, jnp.maximum(p, 0), g2, mxu)
        outs.append(jnp.where(g2, erow[:, 1].astype(jnp.int32), NULL))
        cur = p
    return jnp.stack(outs, axis=1)


def _down_walk(nodes, child_lc_tab, child_index_tab, pe_tab, n, mxu):
    """Descendant window (TILE, n) — mirrors
    ``gather_descendants_unrolled`` on packed tables: child_lc (N, 2)
    [child_lo | child_count], child_index (C, 1), entity ids from the
    (N, 2) parent/entity table's second column."""
    ci = child_index_tab.shape[0]
    buf = jnp.full((TILE, n), NULL, jnp.int32)
    w = jnp.zeros((TILE,), jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (TILE, n), 1)

    def push(buf, w, src):
        g = src != NULL
        lc = _gather_rows(child_lc_tab, jnp.maximum(src, 0), g, mxu)
        lo = lc[:, 0].astype(jnp.int32)
        hi = lo + lc[:, 1].astype(jnp.int32)
        for k in range(n):
            idx = lo + k
            valid = g & (idx < hi) & (w < n)
            crow = _gather_rows(child_index_tab, jnp.minimum(idx, ci - 1),
                                valid, mxu)
            c = jnp.where(valid, crow[:, 0].astype(jnp.int32), NULL)
            oh = (lane == jnp.minimum(w, n - 1)[:, None]) & valid[:, None]
            buf = jnp.where(oh, c[:, None], buf)
            w = jnp.where(valid, w + 1, w)
        return buf, w

    buf, w = push(buf, w, nodes)
    out = jnp.full((TILE, n), NULL, jnp.int32)
    for i in range(n):
        cur = buf[:, i]
        valid = (i < w) & (cur != NULL)
        erow = _gather_rows(pe_tab, jnp.maximum(cur, 0), valid, mxu)
        out = out.at[:, i].set(
            jnp.where(valid, erow[:, 1].astype(jnp.int32), out[:, i]))
        buf, w = push(buf, w, jnp.where(valid, cur, NULL))
    return out


def _context_tail(qoff, valid, csr_lc_ref, csr_nodes_ref, parent_eid_ref,
                  child_lc_ref, child_index_ref, hit_ref, head_ref,
                  bucket_ref, slot_ref, loc_ref, up_ref, down_ref,
                  temp_in_ref, temp_ref, qi, *, slots, max_locs, n, mxu,
                  locs_only):
    """Consume the settled probe accumulators: bump temperature, gather the
    CSR window, walk the hierarchy — all from VMEM-resident tables."""
    vhit = (hit_ref[...] > 0) & valid               # = unfused hit&in_range
    hit_ref[...] = vhit.astype(jnp.int32)           # the emitted hit
    bucket = bucket_ref[...]
    slot = slot_ref[...]

    @pl.when(qi == 0)
    def _init_temp():
        temp_ref[...] = temp_in_ref[...]

    arena_rows = temp_ref.shape[0]
    rows = qoff + bucket                            # always < arena_rows
    if mxu:
        it = jax.lax.broadcasted_iota(jnp.int32, (TILE, arena_rows), 1)
        rows_oh = ((it == rows[:, None]) &
                   vhit[:, None]).astype(jnp.float32)
        st = jax.lax.broadcasted_iota(jnp.int32, (TILE, slots), 1)
        slot_oh = (st == slot[:, None]).astype(jnp.float32)
        contrib = jax.lax.dot_general(                     # (A, S) counts
            rows_oh, slot_oh, (((0,), (0,)), ((), ())), precision=_HIGHEST)
        temp_ref[...] += contrib.astype(temp_ref.dtype)
    else:
        t = temp_ref[...]
        temp_ref[...] = t.at[jnp.clip(rows, 0, arena_rows - 1),
                             slot].add(vhit.astype(t.dtype))

    # CSR location window; misses route to the empty sentinel row R
    r_sent = csr_lc_ref.shape[0] - 1
    eid = jnp.where(vhit, head_ref[...], r_sent)
    lc = _gather_rows(csr_lc_ref[...], eid, vhit, mxu)
    lo = lc[:, 0].astype(jnp.int32)
    count = lc[:, 1].astype(jnp.int32)
    csr_nodes = csr_nodes_ref[...]
    node_cols = []
    for k in range(max_locs):
        idx = lo + k
        validk = (k < count) & vhit
        nrow = _gather_rows(csr_nodes, jnp.clip(idx, 0,
                                                csr_nodes.shape[0] - 1),
                            validk, mxu)
        node_cols.append(jnp.where(validk, nrow[:, 0].astype(jnp.int32),
                                   NULL))
    loc_ref[...] = jnp.stack(node_cols, axis=1)
    if locs_only:
        return

    pe_tab = parent_eid_ref[...]
    child_lc = child_lc_ref[...]
    child_index = child_index_ref[...]
    up_cols, down_cols = [], []
    for k in range(max_locs):
        node_k = node_cols[k]
        src = jnp.maximum(node_k, 0)
        upk = _up_walk(src, pe_tab, n, mxu)
        up_cols.append(jnp.where(node_k[:, None] == NULL, NULL, upk))
        downk = _down_walk(src, child_lc, child_index, pe_tab, n, mxu)
        down_cols.append(jnp.where(node_k[:, None] == NULL, NULL, downk))
    up_ref[...] = jnp.concatenate(up_cols, axis=1)
    down_ref[...] = jnp.concatenate(down_cols, axis=1)


def _split_out_refs(refs, locs_only):
    """(hit, head, bucket, slot, prio, loc[, up, down], temp) — the
    locs_only variant (sharded owner probe) omits the hierarchy blocks."""
    if locs_only:
        hit, head, bucket, slot, prio, loc, temp = refs
        return hit, head, bucket, slot, prio, loc, None, None, temp
    return refs


def _fused_kernel(h_ref, off_ref, mask_ref, valid_ref, fp_tab_ref,
                  head_tab_ref, temp_in_ref, csr_lc_ref, csr_nodes_ref,
                  parent_eid_ref, child_lc_ref, child_index_ref,
                  *out_refs, slots, row_tile, num_tiles, max_locs, n, mxu,
                  locs_only):
    """Pre-routed fused kernel: probe every arena tile, run the context
    tail once the last tile's priority merge has settled."""
    (hit_ref, head_ref, bucket_ref, slot_ref, prio_ref, loc_ref, up_ref,
     down_ref, temp_ref) = _split_out_refs(out_refs, locs_only)
    qi = pl.program_id(0)
    ti = pl.program_id(1)
    h = h_ref[...].astype(jnp.uint32)
    qoff = off_ref[...].astype(jnp.int32)
    qmask = mask_ref[...].astype(jnp.uint32)
    _arena_probe(h, qoff, qmask, ti, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, prio_ref, slots=slots,
                 row_tile=row_tile)

    @pl.when(ti == num_tiles - 1)
    def _tail():
        _context_tail(qoff, valid_ref[...] > 0, csr_lc_ref, csr_nodes_ref,
                      parent_eid_ref, child_lc_ref, child_index_ref,
                      hit_ref, head_ref, bucket_ref, slot_ref, loc_ref,
                      up_ref, down_ref, temp_in_ref, temp_ref, qi,
                      slots=slots, max_locs=max_locs, n=n, mxu=mxu,
                      locs_only=locs_only)


def _fused_kernel_sp(off_ref, nb_ref, tid_ref, h_ref, valid_ref,
                     fp_tab_ref, head_tab_ref, temp_in_ref, csr_lc_ref,
                     csr_nodes_ref, parent_eid_ref, child_lc_ref,
                     child_index_ref, *out_refs, slots, row_tile,
                     num_tiles, num_trees, max_locs, n, mxu, locs_only):
    """Tree-routed fused kernel: ``bucket_offsets``/``tree_nb`` ride as
    SMEM scalar-prefetch operands (PR 5's routing tables) and the
    per-lane (offset, mask) gather happens in-kernel — then the shared
    probe + context tail."""
    (hit_ref, head_ref, bucket_ref, slot_ref, prio_ref, loc_ref, up_ref,
     down_ref, temp_ref) = _split_out_refs(out_refs, locs_only)
    qi = pl.program_id(0)
    ti = pl.program_id(1)
    h = h_ref[...].astype(jnp.uint32)
    tid = tid_ref[...].astype(jnp.int32)                    # clamped valid
    offs = off_ref[...].astype(jnp.int32)                   # (T + 1,) SMEM
    nbs = nb_ref[...].astype(jnp.int32)                     # (T,) SMEM
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, num_trees), 1)
    sel = t_iota == tid[:, None]
    qoff = jnp.sum(jnp.where(sel, offs[None, :num_trees], 0), axis=1)
    qnb = jnp.sum(jnp.where(sel, nbs[None, :], 0), axis=1)
    qmask = (qnb - 1).astype(jnp.uint32)
    _arena_probe(h, qoff, qmask, ti, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, prio_ref, slots=slots,
                 row_tile=row_tile)

    @pl.when(ti == num_tiles - 1)
    def _tail():
        _context_tail(qoff, valid_ref[...] > 0, csr_lc_ref, csr_nodes_ref,
                      parent_eid_ref, child_lc_ref, child_index_ref,
                      hit_ref, head_ref, bucket_ref, slot_ref, loc_ref,
                      up_ref, down_ref, temp_in_ref, temp_ref, qi,
                      slots=slots, max_locs=max_locs, n=n, mxu=mxu,
                      locs_only=locs_only)


def _out_shapes(b, arena_rows, slots, temp_dtype, max_locs, n, locs_only):
    shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(5)]
    shapes.append(jax.ShapeDtypeStruct((b, max_locs), jnp.int32))
    if not locs_only:
        shapes.append(jax.ShapeDtypeStruct((b, max_locs * n), jnp.int32))
        shapes.append(jax.ShapeDtypeStruct((b, max_locs * n), jnp.int32))
    shapes.append(jax.ShapeDtypeStruct((arena_rows, slots), temp_dtype))
    return shapes


def _out_specs(qspec, wide, tempspec, max_locs, n, locs_only):
    specs = [qspec] * 5 + [wide(max_locs)]
    if not locs_only:
        specs += [wide(max_locs * n), wide(max_locs * n)]
    return specs + [tempspec]


def fused_retrieve_pallas(h, row_offsets, masks, valid, fp_table_f32,
                          head_table_f32, temperature, csr_lc, csr_nodes,
                          parent_eid, child_lc, child_index,
                          max_locs: int = 4, n: int = 3,
                          interpret: bool = True, row_tile: int = 0,
                          mxu: bool = False, locs_only: bool = False):
    """Pre-routed fused retrieval.  h/row_offsets/masks/valid: (B,) with
    B % TILE == 0; fp/head tables (A, S) f32 (A a multiple of row_tile
    when tiling); temperature (A, S); context tables packed by
    ``ops.stage_context_tables``.  Returns (hit, head, bucket, slot, prio,
    locations[, up, down], temperature) — the wrapper drops the probe
    internals."""
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    rt = rows_total if row_tile <= 0 else row_tile
    assert rows_total % rt == 0, \
        "pad the arena to a multiple of row_tile before calling"
    nt = rows_total // rt
    grid = (b // TILE, nt)                     # arena axis innermost
    qspec = pl.BlockSpec((TILE,), lambda qi, ti: (qi,))
    tabspec = pl.BlockSpec((rt, slots), lambda qi, ti: (ti, 0))

    def wide(w):
        return pl.BlockSpec((TILE, w), lambda qi, ti: (qi, 0))

    def const(arr):
        return pl.BlockSpec(arr.shape, lambda qi, ti: (0,) * arr.ndim)

    outs = pl.pallas_call(
        functools.partial(_fused_kernel, slots=slots, row_tile=rt,
                          num_tiles=nt, max_locs=max_locs, n=n, mxu=mxu,
                          locs_only=locs_only),
        grid=grid,
        in_specs=[qspec, qspec, qspec, qspec, tabspec, tabspec,
                  const(temperature), const(csr_lc), const(csr_nodes),
                  const(parent_eid), const(child_lc), const(child_index)],
        out_specs=_out_specs(qspec, wide, const(temperature), max_locs, n,
                             locs_only),
        out_shape=_out_shapes(b, rows_total, slots, temperature.dtype,
                              max_locs, n, locs_only),
        interpret=interpret,
    )(h, row_offsets, masks, valid, fp_table_f32, head_table_f32,
      temperature, csr_lc, csr_nodes, parent_eid, child_lc, child_index)
    return outs


def fused_retrieve_ragged_pallas(h, tree_ids, valid, bucket_offsets,
                                 tree_nb, fp_table_f32, head_table_f32,
                                 temperature, csr_lc, csr_nodes,
                                 parent_eid, child_lc, child_index,
                                 max_locs: int = 4, n: int = 3,
                                 interpret: bool = True, row_tile: int = 0,
                                 mxu: bool = False,
                                 locs_only: bool = False):
    """Tree-routed fused retrieval with SMEM scalar-prefetched routing
    tables (tree_ids pre-clamped to [0, T-1], ``valid`` carrying the
    in-range mask).  Falls back to the pre-routed kernel when the jax
    build exposes no TPU grid-spec module."""
    if pltpu is None:                      # pragma: no cover - build-dep
        off = bucket_offsets[tree_ids]
        mask = (tree_nb[tree_ids] - 1).astype(jnp.uint32)
        return fused_retrieve_pallas(
            h, off, mask, valid, fp_table_f32, head_table_f32, temperature,
            csr_lc, csr_nodes, parent_eid, child_lc, child_index,
            max_locs=max_locs, n=n, interpret=interpret, row_tile=row_tile,
            mxu=mxu, locs_only=locs_only)
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    rt = rows_total if row_tile <= 0 else row_tile
    assert rows_total % rt == 0, \
        "pad the arena to a multiple of row_tile before calling"
    nt = rows_total // rt
    num_trees = tree_nb.shape[0]
    grid = (b // TILE, nt)                     # arena axis innermost
    # index maps receive the scalar-prefetch refs after the grid indices
    qspec = pl.BlockSpec((TILE,), lambda qi, ti, off, nb: (qi,))
    tabspec = pl.BlockSpec((rt, slots), lambda qi, ti, off, nb: (ti, 0))

    def wide(w):
        return pl.BlockSpec((TILE, w), lambda qi, ti, off, nb: (qi, 0))

    def const(arr):
        return pl.BlockSpec(arr.shape,
                            lambda qi, ti, off, nb: (0,) * arr.ndim)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[qspec, qspec, qspec, tabspec, tabspec,
                  const(temperature), const(csr_lc), const(csr_nodes),
                  const(parent_eid), const(child_lc), const(child_index)],
        out_specs=_out_specs(qspec, wide, const(temperature), max_locs, n,
                             locs_only),
    )
    outs = pl.pallas_call(
        functools.partial(_fused_kernel_sp, slots=slots, row_tile=rt,
                          num_tiles=nt, num_trees=num_trees,
                          max_locs=max_locs, n=n, mxu=mxu,
                          locs_only=locs_only),
        grid_spec=grid_spec,
        out_shape=_out_shapes(b, rows_total, slots, temperature.dtype,
                              max_locs, n, locs_only),
        interpret=interpret,
    )(bucket_offsets.astype(jnp.int32), tree_nb.astype(jnp.int32),
      tree_ids, h, valid, fp_table_f32, head_table_f32, temperature,
      csr_lc, csr_nodes, parent_eid, child_lc, child_index)
    return outs
