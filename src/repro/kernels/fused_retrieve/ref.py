"""Pure-jnp oracle for the fused retrieval kernel.

Semantically this is ``lookup_arena`` + temperature bump + the CSR location
window + hierarchy walks — exactly what ``retrieve_device`` followed by
``gather_context`` computes — restated in the *fused* dataflow the Pallas
kernel implements: select-based unrolled walks (static ``n`` steps, no
``lax.while``/``lax.cond``) and the sentinel-row miss routing, so every
intermediate stays a register-shaped value.  Tests pin this function
bit-identical to the unfused core path; the kernel is validated against
both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.lookup import bump_temperature_arena, lookup_arena
from ...core.trag import NULL, DeviceRetrieval


def gather_hierarchy_unrolled(parent: jax.Array, entity_id: jax.Array,
                              nodes: jax.Array, n: int) -> jax.Array:
    """Ancestor entity-id window — unrolled form of
    ``context.gather_hierarchy`` (bit-identical; the scan becomes ``n``
    static select+gather steps)."""
    cur = nodes.astype(jnp.int32)
    outs = []
    for _ in range(n):
        p = jnp.where(cur == NULL, NULL, parent[jnp.maximum(cur, 0)])
        eid = jnp.where(p == NULL, NULL, entity_id[jnp.maximum(p, 0)])
        outs.append(eid)
        cur = p
    return jnp.stack(outs, axis=1)


def gather_descendants_unrolled(child_offsets: jax.Array,
                                child_index: jax.Array,
                                entity_id: jax.Array, nodes: jax.Array,
                                n: int) -> jax.Array:
    """Descendant entity-id window — unrolled form of
    ``context.gather_descendants``.  The per-node BFS (vmapped
    fori_loop + cond in the reference) becomes static select arithmetic:
    ``cond(valid, push(cur))`` is replaced by ``push(where(valid, cur,
    NULL))``, identical because a NULL source makes every inner push lane
    invalid.  This removes the XLA while-loop overhead that dominates the
    unfused path on CPU."""
    b = nodes.shape[0]
    ci = child_index.shape[0]
    nodes = nodes.astype(jnp.int32)
    buf = jnp.full((b, n), NULL, jnp.int32)      # BFS frontier ring, cap n
    w = jnp.zeros((b,), jnp.int32)               # frontier write cursor
    lane = jnp.arange(n, dtype=jnp.int32)[None, :]

    def push(buf, w, src):
        s = jnp.maximum(src, 0)
        lo = child_offsets[s]
        hi = child_offsets[s + 1]
        for k in range(n):
            idx = lo + k
            valid = (src != NULL) & (idx < hi) & (w < n)
            c = jnp.where(valid, child_index[jnp.minimum(idx, ci - 1)], NULL)
            oh = (lane == jnp.minimum(w, n - 1)[:, None]) & valid[:, None]
            buf = jnp.where(oh, c[:, None], buf)
            w = jnp.where(valid, w + 1, w)
        return buf, w

    buf, w = push(buf, w, nodes)
    out = jnp.full((b, n), NULL, jnp.int32)
    for i in range(n):
        cur = buf[:, i]
        valid = (i < w) & (cur != NULL)
        out = out.at[:, i].set(
            jnp.where(valid, entity_id[jnp.maximum(cur, 0)], out[:, i]))
        buf, w = push(buf, w, jnp.where(valid, cur, NULL))
    return out


def fused_retrieve_ref(fingerprints: jax.Array, temperature: jax.Array,
                       heads: jax.Array, row_offsets: jax.Array,
                       masks: jax.Array, valid: jax.Array, h: jax.Array,
                       csr_offsets: jax.Array, csr_nodes: jax.Array,
                       parent: jax.Array, entity_id: jax.Array,
                       child_offsets: jax.Array, child_index: jax.Array,
                       max_locs: int = 4, n: int = 3) -> DeviceRetrieval:
    """One fused pass: probe -> bump -> CSR window -> hierarchy windows.

    ``valid`` is the per-query admission mask (in-range tree, real lane):
    invalid lanes miss, bump nothing, and emit NULL windows — matching the
    ``in_range`` masking in ``retrieve_device``.
    """
    res = lookup_arena(fingerprints, heads, row_offsets, masks, h)
    res = res._replace(hit=res.hit & valid)
    temp = bump_temperature_arena(temperature, row_offsets, res)

    # Miss routing: misses read the empty sentinel window [terminal,
    # terminal) at CSR row R instead of row 0's real window (satellite fix,
    # mirrored from core.trag.csr_window).
    r = csr_offsets.shape[0] - 1
    eid = jnp.where(res.hit, res.head, r)
    lo = csr_offsets[eid]
    count = csr_offsets[jnp.minimum(eid + 1, r)] - lo
    k = jnp.arange(max_locs, dtype=jnp.int32)
    idx = lo[:, None] + k[None, :]
    window = (k[None, :] < count[:, None]) & res.hit[:, None]
    safe = jnp.clip(idx, 0, csr_nodes.shape[0] - 1)
    nodes = jnp.where(window, csr_nodes[safe], NULL)       # (B, max_locs)

    flat = nodes.reshape(-1)
    up = gather_hierarchy_unrolled(parent, entity_id,
                                   jnp.maximum(flat, 0), n)
    up = jnp.where(flat[:, None] == NULL, NULL, up)
    down = gather_descendants_unrolled(child_offsets, child_index,
                                       entity_id, jnp.maximum(flat, 0), n)
    down = jnp.where(flat[:, None] == NULL, NULL, down)
    b = res.hit.shape[0]
    return DeviceRetrieval(hit=res.hit, locations=nodes,
                           up=up.reshape(b, max_locs, n),
                           down=down.reshape(b, max_locs, n),
                           temperature=temp)
