"""Pure-jnp oracle: gated linear recurrence (shared by RWKV6 and Mamba2).

Semantics (per batch*head, 0-based):
    S_i = diag(exp(g_i)) S_{i-1} + k_i (x) v_i          S_{-1} = S_init
    inclusive:  out_i = q_i^T S_i        (Mamba2 / SSD: y uses updated state)
    exclusive:  out_i = q_i^T S_{i-1}    (RWKV6: state used before decay+update;
                                          the u-bonus term is added by callers)

Shapes: q, k: (B, H, L, Dk); v: (B, H, L, Dv); g (log decay <= 0):
(B, H, L, Dk); S_init: (B, H, Dk, Dv).  Returns (out (B,H,L,Dv), S_final).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def linear_scan_ref(q: jax.Array, k: jax.Array, v: jax.Array, g: jax.Array,
                    s_init: Optional[jax.Array] = None,
                    inclusive: bool = True) -> Tuple[jax.Array, jax.Array]:
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    gf = g.astype(jnp.float32)
    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if s_init is None
          else s_init.astype(jnp.float32))

    def step(s, inp):
        qi, ki, vi, gi = inp               # (B,H,Dk) / (B,H,Dv) / (B,H,Dk)
        s_new = jnp.exp(gi)[..., None] * s + ki[..., None] * vi[..., None, :]
        used = s_new if inclusive else s
        out = jnp.einsum("bhk,bhkv->bhv", qi, used)
        return s_new, out

    xs = (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(gf, 2, 0))
    s_fin, outs = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(outs, 0, 2).astype(q.dtype), s_fin


def linear_scan_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                        g: jax.Array, s_init: Optional[jax.Array] = None,
                        inclusive: bool = True, chunk: int = 64
                        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked-parallel form in pure jnp — the same math as the Pallas
    kernel (all decay exponents <= 0), scanning over CHUNKS instead of
    tokens.  This is what the models lower for training/prefill: the
    per-token scan round-trips the (Dk x Dv) state through HBM every step
    (measured 3.2e5 s memory term on zamba2 train_4k); chunking cuts state
    traffic by the chunk length and turns the work into matmuls."""
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    pad = (-l) % chunk
    if s_init is None:
        s_init = jnp.zeros((b, h, dk, dv), jnp.float32)

    def prep(t, d):
        t = jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        nc = (l + pad) // chunk
        return t.reshape(b, h, nc, chunk, d).astype(jnp.float32) \
                .transpose(2, 0, 1, 3, 4)          # (NC, B, H, C, D)

    qc, kc, gc = prep(q, dk), prep(k, dk), prep(g, dk)
    vc = prep(v, dv)
    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]
    mask = (jj <= ii) if inclusive else (jj < ii)

    def body(s, inp):
        q_c, k_c, v_c, g_c = inp                   # (B, H, C, D*)
        c = jnp.cumsum(g_c, axis=-2)
        cq = c if inclusive else c - g_c
        c_last = c[..., -1:, :]                    # (B, H, 1, Dk)
        out = jnp.einsum("bhck,bhkv->bhcv", q_c * jnp.exp(cq), s)
        pair = jnp.exp(cq[..., :, None, :] - c[..., None, :, :])
        scores = jnp.einsum("bhik,bhjk,bhijk->bhij", q_c, k_c, pair)
        scores = jnp.where(mask, scores, 0.0)
        out = out + jnp.einsum("bhij,bhjv->bhiv", scores, v_c)
        ke = k_c * jnp.exp(c_last - c)
        s_new = s * jnp.exp(c_last[..., 0, :])[..., None] + \
            jnp.einsum("bhck,bhcv->bhkv", ke, v_c)
        return s_new, out

    s_fin, outs = jax.lax.scan(body, s_init.astype(jnp.float32),
                               (qc, kc, vc, gc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, l + pad, dv)
    return out[:, :, :l].astype(q.dtype), s_fin
