"""Jit'd wrapper: (B,H,...) plumbing, CHUNK padding, interpret switch.

Padding is inert by construction: padded steps carry g=0 (decay 1) and
k=v=0 (no state update), so S_final is exact and padded outputs are sliced.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import CHUNK, linear_scan_pallas


@functools.partial(jax.jit, static_argnames=("inclusive", "interpret"))
def linear_scan(q, k, v, g, s_init=None, inclusive: bool = True,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Same shapes/semantics as ref.linear_scan_ref."""
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    pad = (-l) % CHUNK
    if s_init is None:
        s_init = jnp.zeros((b, h, dk, dv), jnp.float32)

    def flat(t, d):
        t = jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return t.reshape(b * h, l + pad, d)

    out, s_fin = linear_scan_pallas(
        flat(q, dk), flat(k, dk), flat(v, dv), flat(g, dk),
        s_init.reshape(b * h, dk, dv),
        inclusive=inclusive, interpret=interpret)
    return (out.reshape(b, h, l + pad, dv)[:, :, :l],
            s_fin.reshape(b, h, dk, dv))
