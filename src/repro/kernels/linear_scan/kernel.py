"""Pallas TPU kernel: chunked gated linear recurrence (RWKV6 / Mamba2 SSD).

The recurrence from ref.py is computed in CHUNK-length blocks so the MXU
does the work instead of a length-L sequential scan.  Numerical scheme: all
decay factors are expressed with NON-POSITIVE exponents (decay logs g <= 0),
so nothing can overflow and underflow flushes to an exact 0:

  * inter-chunk:   out_i += (q_i * exp(cq_i)) @ S_in              cq_i <= 0
  * state carry:   S_out = diag(exp(c_last)) S_in
                           + (k_j * exp(c_last - c_j))^T @ v      <= 0
  * intra-chunk:   sub-blocks of SUB=16.  Off-diagonal sub-block pairs
    factor through the query sub-block's *start boundary* b:
        (q_i exp(cq_i - b)) . (k_j exp(b - c_j))                  both <= 0
    Diagonal sub-blocks use the exact pairwise form
        sum_d q_id k_jd exp(cq_id - c_jd)                         <= 0
    via a (SUB, SUB, Dk) broadcast (small: 16*16*Dk).

inclusive=True  -> out_i = q_i . S_i      (Mamba2/SSD)
inclusive=False -> out_i = q_i . S_{i-1}  (RWKV6; cq_i = c_i - g_i)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64
SUB = 16
NSUB = CHUNK // SUB


def _kernel(q_ref, k_ref, v_ref, g_ref, s0_ref, o_ref, sfin_ref, s_scr,
            *, chunks, inclusive):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)            # (C, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (C, Dv)
    g = g_ref[0].astype(jnp.float32)            # (C, Dk) log decay (<= 0)

    c = jnp.cumsum(g, axis=0)                   # inclusive cumulative
    cq = c if inclusive else c - g              # query-side exponent
    c_last = c[CHUNK - 1]

    # inter-chunk: q_i . diag(exp(cq_i)) S_in          (exponents <= 0)
    q_in = q * jnp.exp(cq)
    out = jax.lax.dot(q_in, s_scr[...], preferred_element_type=jnp.float32)

    # intra-chunk: sub-block decomposition (all exponents <= 0)
    zeros_row = jnp.zeros((1, c.shape[1]), jnp.float32)
    c_ext = jnp.concatenate([zeros_row, c], axis=0)     # c_ext[i] = c_{i-1}
    for si in range(NSUB):
        lo = si * SUB
        b = c_ext[lo]                                   # boundary c_{lo-1}
        qi = q[lo:lo + SUB]
        cqi = cq[lo:lo + SUB]
        q_fac = qi * jnp.exp(cqi - b[None, :])          # <= 0 exponent
        acc = jnp.zeros((SUB, v.shape[1]), jnp.float32)
        for sj in range(si):                            # earlier sub-blocks
            jlo = sj * SUB
            kj = k[jlo:jlo + SUB] * jnp.exp(b[None, :] - c[jlo:jlo + SUB])
            scores = jax.lax.dot_general(
                q_fac, kj, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            acc = acc + jax.lax.dot(scores, v[jlo:jlo + SUB],
                                    preferred_element_type=jnp.float32)
        # diagonal sub-block: exact pairwise (SUB, SUB, Dk) broadcast
        cj = c[lo:lo + SUB]
        kj = k[lo:lo + SUB]
        pair = jnp.exp(cqi[:, None, :] - cj[None, :, :])     # (S,S,Dk) <= 0
        scores = jnp.einsum("id,jd,ijd->ij", qi, kj, pair)
        ii = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)
        mask = (jj <= ii) if inclusive else (jj < ii)
        scores = jnp.where(mask, scores, 0.0)
        acc = acc + jax.lax.dot(scores, v[lo:lo + SUB],
                                preferred_element_type=jnp.float32)
        out = out.at[lo:lo + SUB].add(acc)
    o_ref[0] = out.astype(o_ref.dtype)

    # state carry: S_out = diag(exp(c_last)) S_in + (k exp(c_last - c))^T v
    ke = k * jnp.exp(c_last[None, :] - c)
    s_scr[...] = s_scr[...] * jnp.exp(c_last)[:, None] + jax.lax.dot_general(
        ke, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ic == chunks - 1)
    def _emit():
        sfin_ref[0] = s_scr[...]


def linear_scan_pallas(q, k, v, g, s_init, *, inclusive: bool = True,
                       interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """q,k,g: (BH, L, Dk); v: (BH, L, Dv); s_init: (BH, Dk, Dv); L%CHUNK==0."""
    bh, l, dk = q.shape
    dv = v.shape[-1]
    chunks = l // CHUNK

    seq = lambda: pl.BlockSpec((1, CHUNK, dk), lambda b, ic: (b, ic, 0))
    seqv = pl.BlockSpec((1, CHUNK, dv), lambda b, ic: (b, ic, 0))
    st = pl.BlockSpec((1, dk, dv), lambda b, ic: (b, 0, 0))

    out, s_fin = pl.pallas_call(
        functools.partial(_kernel, chunks=chunks, inclusive=inclusive),
        grid=(bh, chunks),
        in_specs=[seq(), seq(), seqv, seq(), st],
        out_specs=[seqv, st],
        out_shape=[jax.ShapeDtypeStruct((bh, l, dv), q.dtype),
                   jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, g, s_init)
    return out, s_fin
