from .kernel import CHUNK, linear_scan_pallas
from .ops import linear_scan
from .ref import linear_scan_ref

__all__ = ["CHUNK", "linear_scan", "linear_scan_pallas", "linear_scan_ref"]
