"""Shared VMEM budget derivation for the retrieval kernels.

The arena kernels (``cuckoo_lookup`` and ``fused_retrieve``) stream arena
tiles through VMEM and must cap the rows-per-tile so the tile working set
fits on chip.  Historically the cap came from a hand-written closed form
baked into ``LOOKUP_VMEM_BUDGET``; this module replaces that constant with a
derivation that *measures* the per-row cost from the compiled executable
(``memory_analysis()``, where the backend exposes it) and keeps the closed
form as the documented fallback.

Closed form (per arena row streamed through a probe tile, f32 staging):

    fp tile + head tile      2 * slots * 4 bytes
    concat(fp, head)             2 * slots * 4 bytes
    two one-hot operands     2 * TILE  * 4 bytes   (query-tile x rows)
    -------------------------------------------------
    per_row = 4 * (4 * slots + 2 * TILE)

Budget = half of a 16 MiB VMEM core so the Pallas pipeline can double-buffer
the streamed tiles (two tile generations resident at once).

Measurement: lower the single-block arena kernel at two row counts and take
the difference quotient of ``temp_size_in_bytes`` — the slope is the true
bytes/row after XLA fusion (on this container's CPU backend it comes out at
roughly half the closed form, because the concat and one-hots fuse).  The
measured slope only ever *raises* the row cap, never past the closed-form
floor of correctness: both derivations feed the same ``max_rows_for_vmem``
rounding to TILE multiples.

Derivations are cached and lazy — nothing compiles at import time.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

from ..obs import get_registry

#: Per-core VMEM capacity assumed for budgeting (TPU v4/v5e class).
DEFAULT_VMEM_BYTES = 16 * 1024 * 1024

#: Fraction of VMEM the streamed tiles may occupy; the other half is
#: headroom for the Pallas pipeline's double-buffering and residents.
BUDGET_FRACTION = 0.5


class VmemBudget(NamedTuple):
    budget_bytes: int     # bytes available to the streamed tile working set
    per_row_bytes: int    # bytes of VMEM one arena row costs inside a tile
    source: str           # "measured" | "closed_form"


def closed_form_row_bytes(slots: int, tile: int) -> int:
    """The documented closed form: staged f32 tables + matmul operands."""
    return 4 * (4 * slots + 2 * tile)


def measured_row_bytes(lower_fn: Callable[[int], object],
                       rows_lo: int = 256,
                       rows_hi: int = 512) -> Optional[int]:
    """Measure bytes/row from compiled memory stats, or None if the backend
    does not expose ``memory_analysis()``.

    ``lower_fn(rows)`` must return a ``jax.stages.Lowered`` for the kernel
    at the given arena row count with everything else held fixed; the
    difference quotient of temp (scratch) bytes is the per-row slope.
    """
    try:
        lo = lower_fn(rows_lo).compile().memory_analysis()
        hi = lower_fn(rows_hi).compile().memory_analysis()
        if lo is None or hi is None:
            return None
        slope = (int(hi.temp_size_in_bytes) - int(lo.temp_size_in_bytes)) \
            // (rows_hi - rows_lo)
    except Exception:          # backend without memory_analysis, or lowering
        return None            # quirk — the closed form is always available
    return slope if slope > 0 else None


@functools.lru_cache(maxsize=None)
def derive_budget(slots: int = 4, tile: int = 128,
                  measure: Optional[Callable[[int], object]] = None,
                  vmem_bytes: int = DEFAULT_VMEM_BYTES) -> VmemBudget:
    """Derive the tile budget for an arena kernel.

    ``measure`` is an optional hashable lower_fn (pass a module-level
    function, not a lambda, so the cache key is stable); when provided and
    the backend cooperates, the measured slope wins, else the closed form.
    """
    budget = int(vmem_bytes * BUDGET_FRACTION)
    per_row = closed_form_row_bytes(slots, tile)
    source = "closed_form"
    if measure is not None:
        got = measured_row_bytes(measure)
        if got is not None:
            per_row, source = got, "measured"
    get_registry().gauge(
        "kernel.vmem_budget_bytes",
        "VMEM bytes budgeted for streamed arena tiles").set(
            budget, source=source)
    return VmemBudget(budget_bytes=budget, per_row_bytes=per_row,
                      source=source)


def max_rows_for_vmem(budget: VmemBudget, tile: int = 128,
                      resident_bytes: int = 0) -> int:
    """Largest arena row count whose tile working set fits the budget after
    subtracting ``resident_bytes`` (tables pinned for the whole launch,
    e.g. the fused kernel's CSR/forest/temperature blocks)."""
    avail = max(budget.budget_bytes - resident_bytes, 0)
    rows = avail // budget.per_row_bytes
    return max(tile, rows // tile * tile)
