"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in its own subpackage with the mandated trio:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, dtype plumbing, interpret switch)
  ref.py    — pure-jnp oracle the kernel is validated against

On this CPU container kernels execute under ``interpret=True``; model code
selects kernel vs. reference implementation via config (TPU -> kernel).
"""
from . import (cuckoo_lookup, decode_attention, flash_attention,
               fused_retrieve, linear_scan, vmem)

__all__ = ["cuckoo_lookup", "decode_attention", "flash_attention",
           "fused_retrieve", "linear_scan", "vmem"]
