"""Public jit'd wrapper for the cuckoo-lookup Pallas kernel.

Handles: query padding to the TILE multiple, int->f32 table staging (done
once per table version, not per query), interpret-mode selection off the
backend, and repackaging into core.lookup.LookupResult.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.lookup import LookupResult
from .kernel import TILE, cuckoo_lookup_bank_pallas, cuckoo_lookup_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stage_tables(fingerprints: jax.Array, heads: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """One-time conversion of int tables to the kernel's f32 layout."""
    return (fingerprints.astype(jnp.float32), heads.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_lookup(fingerprints: jax.Array, heads: jax.Array, h: jax.Array,
                  interpret: bool = True) -> LookupResult:
    """Same signature/semantics as core.lookup.lookup_batch."""
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h, (0, pad))
    fp32, hd32 = stage_tables(fingerprints, heads)
    hit, head, bucket, slot = cuckoo_lookup_pallas(
        hp.astype(jnp.uint32), fp32, hd32, interpret=interpret)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_auto(fingerprints, heads, h) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — the serving engine's entry."""
    return cuckoo_lookup(fingerprints, heads, h, interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_lookup_bank(fingerprints: jax.Array, heads: jax.Array,
                       tree_ids: jax.Array, h: jax.Array,
                       interpret: bool = True) -> LookupResult:
    """Bank lookup with per-query tree routing — same signature/semantics
    as core.lookup.lookup_batch_bank.  Tables: (T, NB, S)."""
    t, nb, s = fingerprints.shape
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h, (0, pad))
    tp = jnp.pad(tree_ids.astype(jnp.int32), (0, pad))
    fp32, hd32 = stage_tables(fingerprints.reshape(t * nb, s),
                              heads.reshape(t * nb, s))
    hit, head, bucket, slot = cuckoo_lookup_bank_pallas(
        hp.astype(jnp.uint32), tp, fp32, hd32, num_buckets=nb,
        interpret=interpret)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_bank_auto(fingerprints, heads, tree_ids, h
                            ) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — serving's bank-routing entry."""
    return cuckoo_lookup_bank(fingerprints, heads, tree_ids, h,
                              interpret=not on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_lookup_trees(fingerprints: jax.Array, heads: jax.Array,
                        h: jax.Array, interpret: bool = True
                        ) -> LookupResult:
    """Vmapped-over-trees kernel entry: tables (T, NB, S), h (T, B) —
    one dense query batch per tree, result fields shaped (T, B)."""
    return jax.vmap(
        lambda f, d, q: cuckoo_lookup(f, d, q, interpret=interpret)
    )(fingerprints, heads, h)
