"""Public jit'd wrapper for the cuckoo-lookup Pallas kernel.

Handles: query padding to the TILE multiple, int->f32 table staging (done
once per table version, not per query), interpret-mode selection off the
backend, and repackaging into core.lookup.LookupResult.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.lookup import LookupResult
from .. import vmem
from .kernel import (TILE, cuckoo_lookup_arena_pallas,
                     cuckoo_lookup_bank_pallas, cuckoo_lookup_pallas,
                     cuckoo_lookup_ragged_pallas)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stage_tables(fingerprints: jax.Array, heads: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """One-time conversion of int tables to the kernel's f32 layout."""
    return (fingerprints.astype(jnp.float32), heads.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_lookup(fingerprints: jax.Array, heads: jax.Array, h: jax.Array,
                  interpret: bool = True) -> LookupResult:
    """Same signature/semantics as core.lookup.lookup_batch."""
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h, (0, pad))
    fp32, hd32 = stage_tables(fingerprints, heads)
    hit, head, bucket, slot = cuckoo_lookup_pallas(
        hp.astype(jnp.uint32), fp32, hd32, interpret=interpret)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_auto(fingerprints, heads, h) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — the serving engine's entry."""
    return cuckoo_lookup(fingerprints, heads, h, interpret=not on_tpu())


# Past SINGLE_BLOCK_MAX_ROWS flat bucket rows the bank/arena kernels tile
# the row axis so the VMEM-resident working set stays bounded instead of
# growing with the bank.  The budget derivation lives in
# ``repro.kernels.vmem`` (shared with the fused retrieval kernel): half of
# a 16 MiB core for the streamed tiles, per-row cost from the documented
# closed form — 4 * (4*S + 2*TILE) bytes: fp+head blocks, their (rows, 2S)
# concat, and two (TILE, rows) one-hot gather operands.
#
# SINGLE_BLOCK_MAX_ROWS is the *closed-form* cap and is resolved at
# import (the tiling threshold must not compile kernels, and the jitted
# wrappers auto-pick tiles at trace time where lowering a second kernel is
# off limits).  The non-traced ``*_auto`` serving entries refine the tile
# *size* with the measured derivation — ``memory_analysis()`` on the
# compiled probe, lazily, once — which typically roughly doubles the tile
# (XLA fuses the concat and one-hots, so the true slope is about half the
# closed form).
def max_rows_for_vmem(slots: int = 4, tile: int = TILE,
                      budget: int = 0) -> int:
    """Largest per-step row-tile (a TILE multiple) fitting the VMEM budget
    for the one-hot-matmul lookup working set (closed form; pass a budget
    to override the shared default)."""
    bd = vmem.VmemBudget(
        budget or int(vmem.DEFAULT_VMEM_BYTES * vmem.BUDGET_FRACTION),
        vmem.closed_form_row_bytes(slots, tile), "closed_form")
    return vmem.max_rows_for_vmem(bd, tile)


SINGLE_BLOCK_MAX_ROWS = max_rows_for_vmem()


def _probe_lower(rows: int):
    """Lower the single-block arena probe at ``rows`` arena rows — the
    measurement target for the shared VMEM derivation."""
    s = 4
    h = jnp.zeros((TILE,), jnp.uint32)
    off = jnp.zeros((TILE,), jnp.int32)
    mask = jnp.zeros((TILE,), jnp.uint32)
    fp = jnp.zeros((rows, s), jnp.float32)
    hd = jnp.zeros((rows, s), jnp.float32)
    fn = jax.jit(functools.partial(cuckoo_lookup_arena_pallas,
                                   interpret=not on_tpu(), row_tile=0))
    return fn.lower(h, off, mask, fp, hd)


def lookup_vmem_budget() -> "vmem.VmemBudget":
    """The arena kernels' VMEM budget: measured per-row slope where the
    backend exposes compiled memory stats, documented closed form else.
    Cached after the first call (one probe compile)."""
    return vmem.derive_budget(slots=4, tile=TILE, measure=_probe_lower)


_measured_max_rows: int = 0


def _max_rows() -> int:
    """Measured-budget row cap for the auto entries, derived lazily."""
    global _measured_max_rows
    if not _measured_max_rows:
        _measured_max_rows = vmem.max_rows_for_vmem(lookup_vmem_budget(),
                                                    TILE)
    return _measured_max_rows


def _auto_row_tile(a: int) -> int:
    """Row tile for the non-traced auto entries: single block below the
    closed-form threshold, measured-budget tiles above it."""
    if a <= SINGLE_BLOCK_MAX_ROWS:
        return 0
    return min(_max_rows(), (a + TILE - 1) // TILE * TILE)


def _pick_tree_tile(t: int, nb: int) -> int:
    """0 = single-block; else trees per grid step (>= 1)."""
    if t * nb <= SINGLE_BLOCK_MAX_ROWS:
        return 0
    return max(1, SINGLE_BLOCK_MAX_ROWS // nb)


@functools.partial(jax.jit, static_argnames=("interpret", "tree_tile"))
def cuckoo_lookup_bank(fingerprints: jax.Array, heads: jax.Array,
                       tree_ids: jax.Array, h: jax.Array,
                       interpret: bool = True,
                       tree_tile: int = -1) -> LookupResult:
    """Bank lookup with per-query tree routing — same signature/semantics
    as core.lookup.lookup_batch_bank.  Tables: (T, NB, S).

    ``tree_tile``: -1 auto-selects (single VMEM block for small banks,
    tree-axis grid tiling past ``SINGLE_BLOCK_MAX_ROWS`` flat rows);
    0 forces the single-block path; > 0 forces that many trees per grid
    step.  T is padded here to a tile multiple with empty-fingerprint rows
    (which can never match), so callers never pre-pad.
    """
    t, nb, s = fingerprints.shape
    if tree_tile < 0:
        tree_tile = _pick_tree_tile(t, nb)
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h, (0, pad))
    tp = jnp.pad(tree_ids.astype(jnp.int32), (0, pad))
    fps2, hds2 = fingerprints.reshape(t * nb, s), heads.reshape(t * nb, s)
    if tree_tile > 0:
        row_pad = ((-t) % tree_tile) * nb
        fps2 = jnp.pad(fps2, ((0, row_pad), (0, 0)))
        hds2 = jnp.pad(hds2, ((0, row_pad), (0, 0)))
    fp32, hd32 = stage_tables(fps2, hds2)
    hit, head, bucket, slot = cuckoo_lookup_bank_pallas(
        hp.astype(jnp.uint32), tp, fp32, hd32, num_buckets=nb,
        interpret=interpret, tree_tile=tree_tile)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_bank_auto(fingerprints, heads, tree_ids, h
                            ) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — serving's bank-routing entry."""
    return cuckoo_lookup_bank(fingerprints, heads, tree_ids, h,
                              interpret=not on_tpu())


def _pick_row_tile(a: int) -> int:
    """0 = single-block; else arena rows per grid step."""
    return 0 if a <= SINGLE_BLOCK_MAX_ROWS else SINGLE_BLOCK_MAX_ROWS


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def cuckoo_lookup_arena(fingerprints: jax.Array, heads: jax.Array,
                        row_offsets: jax.Array, masks: jax.Array,
                        h: jax.Array, interpret: bool = True,
                        row_tile: int = -1) -> LookupResult:
    """Ragged-arena lookup with pre-routed queries — same signature and
    semantics as ``core.lookup.lookup_arena``.  Tables: flat ``(A, S)``;
    ``row_offsets``/``masks``: per-query segment start and ``nb_t - 1``.

    ``row_tile``: -1 auto-selects (single VMEM block for small arenas,
    arena-row grid tiling past ``SINGLE_BLOCK_MAX_ROWS``); 0 forces the
    single-block path; > 0 forces that many arena rows per grid step.  The
    arena is padded here to a tile multiple with empty-fingerprint rows
    (which can never match), so callers never pre-pad.
    """
    a, s = fingerprints.shape
    if row_tile < 0:
        row_tile = _pick_row_tile(a)
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    op = jnp.pad(row_offsets.astype(jnp.int32), (0, pad))
    mp = jnp.pad(masks.astype(jnp.uint32), (0, pad))
    fps2, hds2 = fingerprints, heads
    if row_tile > 0:
        row_pad = (-a) % row_tile
        fps2 = jnp.pad(fps2, ((0, row_pad), (0, 0)))
        hds2 = jnp.pad(hds2, ((0, row_pad), (0, 0)))
    fp32, hd32 = stage_tables(fps2, hds2)
    hit, head, bucket, slot = cuckoo_lookup_arena_pallas(
        hp, op, mp, fp32, hd32, interpret=interpret, row_tile=row_tile)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_arena_auto(fingerprints, heads, row_offsets, masks, h
                             ) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — serving's ragged-arena entry
    (the ``lookup_fn`` shape ``retrieve_device`` and the sharded probe
    consume).  Tile size refined by the measured VMEM budget."""
    return cuckoo_lookup_arena(fingerprints, heads, row_offsets, masks, h,
                               interpret=not on_tpu(),
                               row_tile=_auto_row_tile(
                                   fingerprints.shape[0]))


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def cuckoo_lookup_ragged(fingerprints: jax.Array, heads: jax.Array,
                         bucket_offsets: jax.Array, tree_nb: jax.Array,
                         tree_ids: jax.Array, h: jax.Array,
                         interpret: bool = True,
                         row_tile: int = -1) -> LookupResult:
    """Tree-routed ragged lookup — same signature/semantics as
    ``core.lookup.lookup_batch_ragged``.  The per-tree offsets/nb tables
    are O(T) and SMEM-sized: they ride into the kernel as scalar-prefetch
    operands (``PrefetchScalarGridSpec``) and the per-query routing
    gather happens in-kernel from SMEM — no (B,)-expanded offset/mask
    VMEM operands.  Out-of-range tree ids are clamped (matching the jnp
    reference's clipped gather); the pre-routed
    :func:`cuckoo_lookup_arena` remains the sharded router's contract.
    """
    a, s = fingerprints.shape
    if row_tile < 0:
        row_tile = _pick_row_tile(a)
    b = h.shape[0]
    pad = (-b) % TILE
    hp = jnp.pad(h.astype(jnp.uint32), (0, pad))
    tp = jnp.clip(jnp.pad(tree_ids.astype(jnp.int32), (0, pad)),
                  0, tree_nb.shape[0] - 1)
    fps2, hds2 = fingerprints, heads
    if row_tile > 0:
        row_pad = (-a) % row_tile
        fps2 = jnp.pad(fps2, ((0, row_pad), (0, 0)))
        hds2 = jnp.pad(hds2, ((0, row_pad), (0, 0)))
    fp32, hd32 = stage_tables(fps2, hds2)
    hit, head, bucket, slot = cuckoo_lookup_ragged_pallas(
        hp, tp, bucket_offsets, tree_nb, fp32, hd32, interpret=interpret,
        row_tile=row_tile)
    return LookupResult(hit=hit[:b].astype(jnp.bool_), head=head[:b],
                        bucket=bucket[:b], slot=slot[:b])


def cuckoo_lookup_ragged_auto(fingerprints, heads, bucket_offsets, tree_nb,
                              tree_ids, h) -> LookupResult:
    """Kernel on TPU, interpret elsewhere — tree-routed ragged entry.
    Tile size refined by the measured VMEM budget."""
    return cuckoo_lookup_ragged(fingerprints, heads, bucket_offsets,
                                tree_nb, tree_ids, h,
                                interpret=not on_tpu(),
                                row_tile=_auto_row_tile(
                                    fingerprints.shape[0]))


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_lookup_trees(fingerprints: jax.Array, heads: jax.Array,
                        h: jax.Array, interpret: bool = True
                        ) -> LookupResult:
    """Vmapped-over-trees kernel entry: tables (T, NB, S), h (T, B) —
    one dense query batch per tree, result fields shaped (T, B)."""
    return jax.vmap(
        lambda f, d, q: cuckoo_lookup(f, d, q, interpret=interpret)
    )(fingerprints, heads, h)
