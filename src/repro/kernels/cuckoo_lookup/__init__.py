from .kernel import (TILE, cuckoo_lookup_arena_pallas,
                     cuckoo_lookup_bank_pallas, cuckoo_lookup_pallas,
                     cuckoo_lookup_ragged_pallas)
from .ops import (cuckoo_lookup, cuckoo_lookup_arena,
                  cuckoo_lookup_arena_auto, cuckoo_lookup_auto,
                  cuckoo_lookup_bank, cuckoo_lookup_bank_auto,
                  cuckoo_lookup_ragged, cuckoo_lookup_ragged_auto,
                  cuckoo_lookup_trees, stage_tables)
from .ref import (cuckoo_lookup_arena_ref, cuckoo_lookup_bank_ref,
                  cuckoo_lookup_ragged_ref, cuckoo_lookup_ref)

__all__ = ["TILE", "cuckoo_lookup_pallas", "cuckoo_lookup_bank_pallas",
           "cuckoo_lookup_arena_pallas", "cuckoo_lookup_ragged_pallas",
           "cuckoo_lookup", "cuckoo_lookup_auto", "cuckoo_lookup_bank",
           "cuckoo_lookup_bank_auto", "cuckoo_lookup_arena",
           "cuckoo_lookup_arena_auto", "cuckoo_lookup_ragged",
           "cuckoo_lookup_ragged_auto", "cuckoo_lookup_trees",
           "stage_tables", "cuckoo_lookup_ref", "cuckoo_lookup_bank_ref",
           "cuckoo_lookup_arena_ref", "cuckoo_lookup_ragged_ref"]
