"""Pure-jnp oracle for the cuckoo lookup kernel — delegates to the core
reference semantics (one definition of truth)."""
from __future__ import annotations

import jax

from ...core.lookup import (LookupResult, lookup_arena, lookup_batch,
                            lookup_batch_bank, lookup_batch_ragged)


def cuckoo_lookup_ref(fingerprints: jax.Array, heads: jax.Array,
                      h: jax.Array) -> LookupResult:
    return lookup_batch(fingerprints, heads, h)


def cuckoo_lookup_bank_ref(fingerprints: jax.Array, heads: jax.Array,
                           tree_ids: jax.Array, h: jax.Array
                           ) -> LookupResult:
    return lookup_batch_bank(fingerprints, heads, tree_ids, h)


def cuckoo_lookup_arena_ref(fingerprints: jax.Array, heads: jax.Array,
                            row_offsets: jax.Array, masks: jax.Array,
                            h: jax.Array) -> LookupResult:
    return lookup_arena(fingerprints, heads, row_offsets, masks, h)


def cuckoo_lookup_ragged_ref(fingerprints: jax.Array, heads: jax.Array,
                             bucket_offsets: jax.Array, tree_nb: jax.Array,
                             tree_ids: jax.Array, h: jax.Array
                             ) -> LookupResult:
    return lookup_batch_ragged(fingerprints, heads, bucket_offsets,
                               tree_nb, tree_ids, h)
