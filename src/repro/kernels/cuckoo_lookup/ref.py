"""Pure-jnp oracle for the cuckoo lookup kernel — delegates to the core
reference semantics (one definition of truth)."""
from __future__ import annotations

import jax

from ...core.lookup import LookupResult, lookup_batch


def cuckoo_lookup_ref(fingerprints: jax.Array, heads: jax.Array,
                      h: jax.Array) -> LookupResult:
    return lookup_batch(fingerprints, heads, h)
