"""Pure-jnp oracle for the cuckoo lookup kernel — delegates to the core
reference semantics (one definition of truth)."""
from __future__ import annotations

import jax

from ...core.lookup import LookupResult, lookup_batch, lookup_batch_bank


def cuckoo_lookup_ref(fingerprints: jax.Array, heads: jax.Array,
                      h: jax.Array) -> LookupResult:
    return lookup_batch(fingerprints, heads, h)


def cuckoo_lookup_bank_ref(fingerprints: jax.Array, heads: jax.Array,
                           tree_ids: jax.Array, h: jax.Array
                           ) -> LookupResult:
    return lookup_batch_bank(fingerprints, heads, tree_ids, h)
