"""Pallas TPU kernel: batched cuckoo-filter lookup (the paper's hot loop).

TPU-native design (DESIGN.md §3): the filter tables are small (NB x S x 4B —
a few hundred KiB at most) and live as *whole VMEM blocks*; the query batch
is tiled over the grid.  Bucket rows are gathered with one-hot matmuls on the
MXU (exact in f32 for 12-bit fingerprints and <2^24 head pointers), replacing
the CPU implementation's pointer dereference per probe.

Per query tile (TILE=128 lanes):
  1. integer hash pipeline (VPU):  fp, i1, i2 = candidates(h)
  2. rows1 = one_hot(i1) @ [fp_table | head_table]   (MXU)
     rows2 = one_hot(i2) @ [fp_table | head_table]
  3. match = rows == fp; first-match slot via iota-min; outputs hit/head/
     bucket/slot — identical semantics to repro.core.lookup.lookup_batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import hashing

TILE = 128          # queries per grid step (one vector lane row)


def _kernel(h_ref, fp_tab_ref, head_tab_ref, hit_ref, head_ref,
            bucket_ref, slot_ref, *, num_buckets: int, slots: int):
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    fp, i1, i2 = hashing.candidate_buckets(h, num_buckets, jnp)

    fp_tab = fp_tab_ref[...]                                # (NB, S) f32
    head_tab = head_tab_ref[...]                            # (NB, S) f32
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)       # (NB, 2S)

    nb_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, num_buckets), 1)
    oh1 = (nb_iota == i1.astype(jnp.int32)[:, None]).astype(jnp.float32)
    oh2 = (nb_iota == i2.astype(jnp.int32)[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    hit = first < 2 * slots
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = hit.astype(jnp.int32)
    head_ref[...] = jnp.where(hit, head.astype(jnp.int32), -1)
    bucket_ref[...] = jnp.where(first < slots, i1, i2).astype(jnp.int32)
    slot_ref[...] = jnp.where(first < slots, firstc,
                              firstc - slots).astype(jnp.int32)


def cuckoo_lookup_pallas(h: jax.Array, fp_table_f32: jax.Array,
                         head_table_f32: jax.Array,
                         interpret: bool = True):
    """h: (B,) uint32 (B % TILE == 0); tables: (NB, S) float32."""
    num_buckets, slots = fp_table_f32.shape
    b = h.shape[0]
    grid = (b // TILE,)
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(4)]
    qspec = pl.BlockSpec((TILE,), lambda i: (i,))
    tabspec = pl.BlockSpec((num_buckets, slots), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, num_buckets=num_buckets, slots=slots),
        grid=grid,
        in_specs=[qspec, tabspec, tabspec],
        out_specs=[qspec] * 4,
        out_shape=out_shapes,
        interpret=interpret,
    )(h, fp_table_f32, head_table_f32)


def _bank_kernel(h_ref, tid_ref, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, *, num_buckets: int,
                 slots: int):
    """Per-query tree routing: tables are the whole bank flattened to
    (T * NB, S); each query's bucket rows are tid * NB + {i1, i2}.  The
    hash pipeline stays tree-local (num_buckets = per-tree NB), so a bank
    lookup is bit-identical to probing that tree's standalone filter."""
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    tid = tid_ref[...].astype(jnp.int32)
    fp, i1, i2 = hashing.candidate_buckets(h, num_buckets, jnp)
    r1 = tid * num_buckets + i1.astype(jnp.int32)
    r2 = tid * num_buckets + i2.astype(jnp.int32)

    fp_tab = fp_tab_ref[...]                                # (T*NB, S) f32
    head_tab = head_tab_ref[...]
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)       # (T*NB, 2S)
    rows_total = fp_tab.shape[0]

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, rows_total), 1)
    oh1 = (row_iota == r1[:, None]).astype(jnp.float32)
    oh2 = (row_iota == r2[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    hit = first < 2 * slots
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = hit.astype(jnp.int32)
    head_ref[...] = jnp.where(hit, head.astype(jnp.int32), -1)
    bucket_ref[...] = jnp.where(first < slots, i1, i2).astype(jnp.int32)
    slot_ref[...] = jnp.where(first < slots, firstc,
                              firstc - slots).astype(jnp.int32)


def cuckoo_lookup_bank_pallas(h: jax.Array, tree_ids: jax.Array,
                              fp_table_f32: jax.Array,
                              head_table_f32: jax.Array, num_buckets: int,
                              interpret: bool = True):
    """h/tree_ids: (B,) with B % TILE == 0; tables: (T * NB, S) float32.

    The whole bank lives as one VMEM block, so this kernel targets banks up
    to a few MiB (T * NB * S * 8 bytes) — the many-small-trees regime the
    bank exists for.  Larger banks should shard over the mesh first
    (core.distributed) and route within each shard.
    """
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    grid = (b // TILE,)
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(4)]
    qspec = pl.BlockSpec((TILE,), lambda i: (i,))
    tabspec = pl.BlockSpec((rows_total, slots), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_bank_kernel, num_buckets=num_buckets,
                          slots=slots),
        grid=grid,
        in_specs=[qspec, qspec, tabspec, tabspec],
        out_specs=[qspec] * 4,
        out_shape=out_shapes,
        interpret=interpret,
    )(h, tree_ids, fp_table_f32, head_table_f32)
