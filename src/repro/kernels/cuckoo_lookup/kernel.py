"""Pallas TPU kernel: batched cuckoo-filter lookup (the paper's hot loop).

TPU-native design (DESIGN.md §3): the filter tables are small (NB x S x 4B —
a few hundred KiB at most) and live as *whole VMEM blocks*; the query batch
is tiled over the grid.  Bucket rows are gathered with one-hot matmuls on the
MXU (exact in f32 for 12-bit fingerprints and <2^24 head pointers), replacing
the CPU implementation's pointer dereference per probe.

Per query tile (TILE=128 lanes):
  1. integer hash pipeline (VPU):  fp, i1, i2 = candidates(h)
  2. rows1 = one_hot(i1) @ [fp_table | head_table]   (MXU)
     rows2 = one_hot(i2) @ [fp_table | head_table]
  3. match = rows == fp; first-match slot via iota-min; outputs hit/head/
     bucket/slot — identical semantics to repro.core.lookup.lookup_batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                      # TPU grid specs (scalar prefetch); optional on
    from jax.experimental.pallas import tpu as pltpu   # CPU-only installs
except ImportError:       # pragma: no cover - depends on the jax build
    pltpu = None

from ...core import hashing

TILE = 128          # queries per grid step (one vector lane row)


def _kernel(h_ref, fp_tab_ref, head_tab_ref, hit_ref, head_ref,
            bucket_ref, slot_ref, *, num_buckets: int, slots: int):
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    fp, i1, i2 = hashing.candidate_buckets(h, num_buckets, jnp)

    fp_tab = fp_tab_ref[...]                                # (NB, S) f32
    head_tab = head_tab_ref[...]                            # (NB, S) f32
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)       # (NB, 2S)

    nb_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, num_buckets), 1)
    oh1 = (nb_iota == i1.astype(jnp.int32)[:, None]).astype(jnp.float32)
    oh2 = (nb_iota == i2.astype(jnp.int32)[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    hit = first < 2 * slots
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = hit.astype(jnp.int32)
    head_ref[...] = jnp.where(hit, head.astype(jnp.int32), -1)
    bucket_ref[...] = jnp.where(first < slots, i1, i2).astype(jnp.int32)
    slot_ref[...] = jnp.where(first < slots, firstc,
                              firstc - slots).astype(jnp.int32)


def cuckoo_lookup_pallas(h: jax.Array, fp_table_f32: jax.Array,
                         head_table_f32: jax.Array,
                         interpret: bool = True):
    """h: (B,) uint32 (B % TILE == 0); tables: (NB, S) float32."""
    num_buckets, slots = fp_table_f32.shape
    b = h.shape[0]
    grid = (b // TILE,)
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(4)]
    qspec = pl.BlockSpec((TILE,), lambda i: (i,))
    tabspec = pl.BlockSpec((num_buckets, slots), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, num_buckets=num_buckets, slots=slots),
        grid=grid,
        in_specs=[qspec, tabspec, tabspec],
        out_specs=[qspec] * 4,
        out_shape=out_shapes,
        interpret=interpret,
    )(h, fp_table_f32, head_table_f32)


def _bank_kernel(h_ref, tid_ref, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, *, num_buckets: int,
                 slots: int):
    """Per-query tree routing: tables are the whole bank flattened to
    (T * NB, S); each query's bucket rows are tid * NB + {i1, i2}.  The
    hash pipeline stays tree-local (num_buckets = per-tree NB), so a bank
    lookup is bit-identical to probing that tree's standalone filter."""
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    tid = tid_ref[...].astype(jnp.int32)
    fp, i1, i2 = hashing.candidate_buckets(h, num_buckets, jnp)
    r1 = tid * num_buckets + i1.astype(jnp.int32)
    r2 = tid * num_buckets + i2.astype(jnp.int32)

    fp_tab = fp_tab_ref[...]                                # (T*NB, S) f32
    head_tab = head_tab_ref[...]
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)       # (T*NB, 2S)
    rows_total = fp_tab.shape[0]

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, rows_total), 1)
    oh1 = (row_iota == r1[:, None]).astype(jnp.float32)
    oh2 = (row_iota == r2[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    hit = first < 2 * slots
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = hit.astype(jnp.int32)
    head_ref[...] = jnp.where(hit, head.astype(jnp.int32), -1)
    bucket_ref[...] = jnp.where(first < slots, i1, i2).astype(jnp.int32)
    slot_ref[...] = jnp.where(first < slots, firstc,
                              firstc - slots).astype(jnp.int32)


def _bank_kernel_tiled(h_ref, tid_ref, fp_tab_ref, head_tab_ref, hit_ref,
                       head_ref, bucket_ref, slot_ref, *, num_buckets: int,
                       slots: int, tree_tile: int):
    """Tree-tiled bank routing: grid axis 1 walks tiles of ``tree_tile``
    trees, so VMEM only ever holds a ``(tree_tile * NB, S)`` slice of the
    bank instead of the whole ``(T * NB, S)`` table.  The output block is
    indexed by the query tile alone and revisited across tree steps
    (accumulate pattern): step 0 writes the miss defaults — identical to
    the single-block kernel's miss outputs (head -1, bucket i2, slot S-1)
    — and each step overwrites the lanes whose tree id falls in its tile.
    Every query belongs to exactly one tile, so the merge never races."""
    ti = pl.program_id(1)
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    tid = tid_ref[...].astype(jnp.int32)
    fp, i1, i2 = hashing.candidate_buckets(h, num_buckets, jnp)
    i1 = i1.astype(jnp.int32)
    i2 = i2.astype(jnp.int32)

    @pl.when(ti == 0)
    def _init():
        hit_ref[...] = jnp.zeros((TILE,), jnp.int32)
        head_ref[...] = jnp.full((TILE,), -1, jnp.int32)
        bucket_ref[...] = i2
        slot_ref[...] = jnp.full((TILE,), slots - 1, jnp.int32)

    local_t = tid - ti * tree_tile
    in_tile = (local_t >= 0) & (local_t < tree_tile)
    r1 = local_t * num_buckets + i1
    r2 = local_t * num_buckets + i2

    fp_tab = fp_tab_ref[...]                          # (tree_tile*NB, S)
    head_tab = head_tab_ref[...]
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)
    rows_block = fp_tab.shape[0]

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, rows_block), 1)
    # out-of-tile lanes produce all-zero one-hots -> zero rows -> no match
    oh1 = ((row_iota == r1[:, None]) &
           in_tile[:, None]).astype(jnp.float32)
    oh2 = ((row_iota == r2[:, None]) &
           in_tile[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    hit = first < 2 * slots
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = jnp.where(in_tile, hit.astype(jnp.int32), hit_ref[...])
    head_ref[...] = jnp.where(in_tile & hit, head.astype(jnp.int32),
                              jnp.where(in_tile, -1, head_ref[...]))
    bucket_ref[...] = jnp.where(in_tile,
                                jnp.where(first < slots, i1, i2),
                                bucket_ref[...])
    slot_ref[...] = jnp.where(in_tile,
                              jnp.where(first < slots, firstc,
                                        firstc - slots),
                              slot_ref[...])


def _arena_kernel(h_ref, off_ref, mask_ref, fp_tab_ref, head_tab_ref,
                  hit_ref, head_ref, bucket_ref, slot_ref, prio_ref, *,
                  slots: int, row_tile: int):
    """Ragged-arena routing: the table is a flat ``(A, S)`` bucket arena
    where each tree owns a contiguous segment of an independent power-of-
    two length.  Each query arrives pre-routed as (hash, segment start,
    bucket mask ``nb_t - 1``) — the offset/mask pair the wrapper gathers
    from the per-tree SMEM-sized offsets table — and probes arena rows
    ``off + (i1, i2)`` with ``i1 = mix(h) & mask``.

    Grid axis 1 walks tiles of ``row_tile`` arena rows, so VMEM only ever
    holds a slice of the arena.  Unlike the dense tree-tiled kernel, a
    query's two candidate rows may fall in *different* tiles (segments are
    not tile-aligned), so each tile contributes its local best match and a
    running priority (position in the [i1 slots | i2 slots] concat) picks
    the global first match — ``prio_ref`` is the cross-tile accumulator,
    discarded by the wrapper.  Step 0 writes the same miss defaults as the
    dense kernels (head -1, bucket i2, slot S-1); since every candidate
    row lives in exactly one tile, the min-priority merge reproduces the
    single-block match order exactly.
    """
    ti = pl.program_id(1)
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    qoff = off_ref[...].astype(jnp.int32)
    qmask = mask_ref[...].astype(jnp.uint32)
    _arena_probe(h, qoff, qmask, ti, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, prio_ref, slots=slots,
                 row_tile=row_tile)


def _arena_probe(h, qoff, qmask, ti, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, prio_ref, *, slots: int,
                 row_tile: int):
    """Shared probe body of the arena kernels: candidates from a
    per-query (segment start, bucket mask) pair, one-hot MXU row gather
    within the resident tile, running slot-priority merge across tiles."""
    fp, i1u, i2u = hashing.candidate_buckets_masked(h, qmask, jnp)
    i1 = i1u.astype(jnp.int32)
    i2 = i2u.astype(jnp.int32)
    r1 = qoff + i1
    r2 = qoff + i2

    @pl.when(ti == 0)
    def _init():
        hit_ref[...] = jnp.zeros((TILE,), jnp.int32)
        head_ref[...] = jnp.full((TILE,), -1, jnp.int32)
        bucket_ref[...] = i2
        slot_ref[...] = jnp.full((TILE,), slots - 1, jnp.int32)
        prio_ref[...] = jnp.full((TILE,), 2 * slots, jnp.int32)

    base = ti * row_tile
    l1, l2 = r1 - base, r2 - base
    in1 = (l1 >= 0) & (l1 < row_tile)
    in2 = (l2 >= 0) & (l2 < row_tile)

    fp_tab = fp_tab_ref[...]                          # (row_tile, S) f32
    head_tab = head_tab_ref[...]
    tab = jnp.concatenate([fp_tab, head_tab], axis=1)

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, row_tile), 1)
    # out-of-tile candidates produce all-zero one-hots -> zero rows -> no
    # match (query fingerprints are never the empty sentinel 0)
    oh1 = ((row_iota == l1[:, None]) & in1[:, None]).astype(jnp.float32)
    oh2 = ((row_iota == l2[:, None]) & in2[:, None]).astype(jnp.float32)
    rows1 = jax.lax.dot(oh1, tab, precision=jax.lax.Precision.HIGHEST)
    rows2 = jax.lax.dot(oh2, tab, precision=jax.lax.Precision.HIGHEST)

    fps = jnp.concatenate([rows1[:, :slots], rows2[:, :slots]], axis=1)
    heads = jnp.concatenate([rows1[:, slots:], rows2[:, slots:]], axis=1)

    match = fps == fp.astype(jnp.float32)[:, None]          # (TILE, 2S)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, 2 * slots), 1)
    first = jnp.min(jnp.where(match, pos_iota, 2 * slots), axis=1)
    better = first < prio_ref[...]
    firstc = jnp.minimum(first, 2 * slots - 1)

    sel = (pos_iota == firstc[:, None]).astype(jnp.float32)
    head = jnp.sum(heads * sel, axis=1)                     # exact gather

    hit_ref[...] = jnp.where(better, 1, hit_ref[...])
    head_ref[...] = jnp.where(better, head.astype(jnp.int32), head_ref[...])
    bucket_ref[...] = jnp.where(better,
                                jnp.where(first < slots, i1, i2),
                                bucket_ref[...])
    slot_ref[...] = jnp.where(better,
                              jnp.where(first < slots, firstc,
                                        firstc - slots),
                              slot_ref[...])
    prio_ref[...] = jnp.where(better, first, prio_ref[...])


def _arena_kernel_sp(off_ref, nb_ref, tid_ref, h_ref, fp_tab_ref,
                     head_tab_ref, hit_ref, head_ref, bucket_ref, slot_ref,
                     prio_ref, *, slots: int, row_tile: int,
                     num_trees: int):
    """Tree-routed arena kernel with the per-tree routing tables in SMEM.

    ``bucket_offsets``/``tree_nb`` are **scalar-prefetch operands**
    (``pltpu.PrefetchScalarGridSpec``): O(T) ints resident in SMEM for
    the whole launch instead of per-query-expanded (B,) VMEM operands —
    the wrapper no longer materializes a gathered offset/mask pair per
    query.  The per-lane gather happens here: an iota-compare one-hot sum
    over the SMEM tables (VPU work; T is small by construction — the
    tables are the same O(T) arrays the sharded router replicates).
    Everything downstream is the shared :func:`_arena_probe`, so results
    stay bit-identical to the pre-routed kernel and the jnp reference.
    """
    ti = pl.program_id(1)
    h = h_ref[...].astype(jnp.uint32)                       # (TILE,)
    tid = tid_ref[...].astype(jnp.int32)                    # clamped valid
    offs = off_ref[...].astype(jnp.int32)                   # (T + 1,) SMEM
    nbs = nb_ref[...].astype(jnp.int32)                     # (T,) SMEM
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (TILE, num_trees), 1)
    sel = t_iota == tid[:, None]
    qoff = jnp.sum(jnp.where(sel, offs[None, :num_trees], 0), axis=1)
    qnb = jnp.sum(jnp.where(sel, nbs[None, :], 0), axis=1)
    qmask = (qnb - 1).astype(jnp.uint32)
    _arena_probe(h, qoff, qmask, ti, fp_tab_ref, head_tab_ref, hit_ref,
                 head_ref, bucket_ref, slot_ref, prio_ref, slots=slots,
                 row_tile=row_tile)


def cuckoo_lookup_arena_pallas(h: jax.Array, row_offsets: jax.Array,
                               masks: jax.Array, fp_table_f32: jax.Array,
                               head_table_f32: jax.Array,
                               interpret: bool = True,
                               row_tile: int = 0):
    """h/row_offsets/masks: (B,) with B % TILE == 0; tables: (A, S) f32.

    ``row_tile == 0`` keeps the whole arena as one VMEM block (right for
    the many-small-trees regime); ``row_tile > 0`` tiles the arena rows
    over a second grid dimension — the caller must pad A to a multiple of
    ``row_tile`` (zero rows = empty fingerprints, so padding never
    matches).  Arenas larger than a device should shard over the mesh
    first (core.distributed) and route within each shard.
    """
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    rt = rows_total if row_tile <= 0 else row_tile
    assert rows_total % rt == 0, \
        "pad the arena to a multiple of row_tile before calling"
    grid = (b // TILE, rows_total // rt)       # arena axis innermost
    qspec = pl.BlockSpec((TILE,), lambda qi, ti: (qi,))
    tabspec = pl.BlockSpec((rt, slots), lambda qi, ti: (ti, 0))
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(5)]
    outs = pl.pallas_call(
        functools.partial(_arena_kernel, slots=slots, row_tile=rt),
        grid=grid,
        in_specs=[qspec, qspec, qspec, tabspec, tabspec],
        out_specs=[qspec] * 5,
        out_shape=out_shapes,
        interpret=interpret,
    )(h, row_offsets, masks, fp_table_f32, head_table_f32)
    return outs[:4]                            # drop the priority scratch


def cuckoo_lookup_ragged_pallas(h: jax.Array, tree_ids: jax.Array,
                                bucket_offsets: jax.Array,
                                tree_nb: jax.Array,
                                fp_table_f32: jax.Array,
                                head_table_f32: jax.Array,
                                interpret: bool = True,
                                row_tile: int = 0):
    """Tree-routed ragged lookup with SMEM scalar-prefetched routing.

    h/tree_ids: (B,) with B % TILE == 0 (tree_ids pre-clamped to
    [0, T-1]); bucket_offsets: (T + 1,); tree_nb: (T,); tables: (A, S)
    f32.  The two per-tree tables ride as scalar-prefetch args (SMEM)
    rather than per-query VMEM operands; ``row_tile`` tiles the arena
    rows exactly as :func:`cuckoo_lookup_arena_pallas`.  Falls back to
    the pre-gathered arena kernel when the jax build exposes no TPU
    grid-spec module.
    """
    if pltpu is None:                      # pragma: no cover - build-dep
        off = bucket_offsets[tree_ids]
        mask = (tree_nb[tree_ids] - 1).astype(jnp.uint32)
        return cuckoo_lookup_arena_pallas(
            h, off, mask, fp_table_f32, head_table_f32,
            interpret=interpret, row_tile=row_tile)
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    rt = rows_total if row_tile <= 0 else row_tile
    assert rows_total % rt == 0, \
        "pad the arena to a multiple of row_tile before calling"
    num_trees = tree_nb.shape[0]
    grid = (b // TILE, rows_total // rt)       # arena axis innermost
    # index maps receive the scalar-prefetch refs after the grid indices
    qspec = pl.BlockSpec((TILE,), lambda qi, ti, off, nb: (qi,))
    tabspec = pl.BlockSpec((rt, slots), lambda qi, ti, off, nb: (ti, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[qspec, qspec, tabspec, tabspec],
        out_specs=[qspec] * 5,
    )
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(5)]
    outs = pl.pallas_call(
        functools.partial(_arena_kernel_sp, slots=slots, row_tile=rt,
                          num_trees=num_trees),
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(bucket_offsets.astype(jnp.int32), tree_nb.astype(jnp.int32),
      tree_ids, h, fp_table_f32, head_table_f32)
    return outs[:4]                            # drop the priority scratch


def cuckoo_lookup_bank_pallas(h: jax.Array, tree_ids: jax.Array,
                              fp_table_f32: jax.Array,
                              head_table_f32: jax.Array, num_buckets: int,
                              interpret: bool = True,
                              tree_tile: int = 0):
    """h/tree_ids: (B,) with B % TILE == 0; tables: (T * NB, S) float32.

    ``tree_tile == 0`` is the single-block path: the whole bank lives as
    one VMEM block — right for the many-small-trees regime (a few MiB at
    most).  ``tree_tile > 0`` tiles the tree axis over a second grid
    dimension so only ``tree_tile * NB`` bucket rows are resident per
    step; the caller must pad T to a multiple of ``tree_tile`` (zero rows
    = empty fingerprints, so padded trees can never match).  Banks larger
    than a device should shard over the mesh first (core.distributed) and
    route within each shard.
    """
    rows_total, slots = fp_table_f32.shape
    b = h.shape[0]
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(4)]
    if tree_tile <= 0:
        grid = (b // TILE,)
        qspec = pl.BlockSpec((TILE,), lambda i: (i,))
        tabspec = pl.BlockSpec((rows_total, slots), lambda i: (0, 0))
        return pl.pallas_call(
            functools.partial(_bank_kernel, num_buckets=num_buckets,
                              slots=slots),
            grid=grid,
            in_specs=[qspec, qspec, tabspec, tabspec],
            out_specs=[qspec] * 4,
            out_shape=out_shapes,
            interpret=interpret,
        )(h, tree_ids, fp_table_f32, head_table_f32)

    block_rows = tree_tile * num_buckets
    assert rows_total % block_rows == 0, \
        "pad T to a multiple of tree_tile before calling"
    grid = (b // TILE, rows_total // block_rows)   # tree axis innermost
    qspec = pl.BlockSpec((TILE,), lambda qi, ti: (qi,))
    tabspec = pl.BlockSpec((block_rows, slots), lambda qi, ti: (ti, 0))
    return pl.pallas_call(
        functools.partial(_bank_kernel_tiled, num_buckets=num_buckets,
                          slots=slots, tree_tile=tree_tile),
        grid=grid,
        in_specs=[qspec, qspec, tabspec, tabspec],
        out_specs=[qspec] * 4,
        out_shape=out_shapes,
        interpret=interpret,
    )(h, tree_ids, fp_table_f32, head_table_f32)
