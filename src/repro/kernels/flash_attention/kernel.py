"""Pallas TPU flash attention (fwd + bwd), GQA-aware, causal.

Tiling: queries in (BQ=128)-row tiles, keys/values in (BK=128)-row tiles —
MXU-aligned (128x128 systolic array).  Grid iterates kv tiles innermost;
running max / sum / accumulator live in VMEM scratch across kv steps
(online softmax, Flash-2 style).  Fully-masked causal tiles are skipped
with pl.when so the causal prefill does ~half the work.

Backward follows the FA2 recipe with saved (out, lse): delta = rowsum(do*o)
precomputed outside; dq accumulated over kv tiles; dk/dv accumulated over q
tiles per q-head and group-reduced to kv heads in the wrapper.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


# ------------------------------------------------------------------ forward

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale, causal, kv_steps, q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0) + q_offset
    k_pos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    needed = (not causal) or (ik * BK <= iq * BQ + q_offset + BQ - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == kv_steps - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


def flash_attention_fwd_pallas(q, k, v, *, causal: bool = True,
                               scale: Optional[float] = None,
                               interpret: bool = True):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D). Lq%BQ == Lkv%BK == 0."""
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    q_steps, kv_steps = lq // BQ, lkv // BK
    q_offset = lkv - lq                  # right-aligned causal positions

    grid = (b, hq, q_steps, kv_steps)
    qspec = pl.BlockSpec((1, 1, BQ, d), lambda b_, h, iq, ik: (b_, h, iq, 0))
    kvspec = pl.BlockSpec((1, 1, BK, d),
                          lambda b_, h, iq, ik: (b_, h // group, ik, 0))
    ospec = qspec
    lsespec = pl.BlockSpec((1, 1, BQ), lambda b_, h, iq, ik: (b_, h, iq))

    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal,
                          kv_steps=kv_steps, q_offset=q_offset),
        grid=grid,
        in_specs=[qspec, kvspec, kvspec],
        out_specs=[ospec, lsespec],
        out_shape=[jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct((b, hq, lq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((BQ,), jnp.float32),
                        pltpu.VMEM((BQ,), jnp.float32),
                        pltpu.VMEM((BQ, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ----------------------------------------------------------------- backward

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, kv_steps, q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    needed = (not causal) or (ik * BK <= iq * BQ + q_offset + BQ - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0) + q_offset
            k_pos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ik == kv_steps - 1)
    def _emit():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, q_steps, q_offset):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    needed = (not causal) or (ik * BK <= iq * BQ + q_offset + BQ - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0) + q_offset
            k_pos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                     # (BQ, BK)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # p^T @ do
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # ds^T @ q

    @pl.when(iq == q_steps - 1)
    def _emit():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, out, lse, do, *, causal: bool,
                               scale: Optional[float], interpret: bool = True):
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    q_steps, kv_steps = lq // BQ, lkv // BK
    q_offset = lkv - lq

    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    qspec4 = lambda: pl.BlockSpec((1, 1, BQ, d),
                                  lambda b_, h, iq, ik: (b_, h, iq, 0))
    kvspec4 = lambda: pl.BlockSpec((1, 1, BK, d),
                                   lambda b_, h, iq, ik: (b_, h // group, ik, 0))
    vec4 = lambda: pl.BlockSpec((1, 1, BQ), lambda b_, h, iq, ik: (b_, h, iq))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          kv_steps=kv_steps, q_offset=q_offset),
        grid=(b, hq, q_steps, kv_steps),
        in_specs=[qspec4(), kvspec4(), kvspec4(), qspec4(), vec4(), vec4()],
        out_specs=qspec4(),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((BQ, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv per *q* head (grid swaps: kv tiles outer, q tiles inner/summed)
    qspec_s = pl.BlockSpec((1, 1, BQ, d), lambda b_, h, ik, iq: (b_, h, iq, 0))
    kvspec_s = pl.BlockSpec((1, 1, BK, d),
                            lambda b_, h, ik, iq: (b_, h // group, ik, 0))
    vec_s = pl.BlockSpec((1, 1, BQ), lambda b_, h, ik, iq: (b_, h, iq))
    dkv_out = pl.BlockSpec((1, 1, BK, d), lambda b_, h, ik, iq: (b_, h, ik, 0))

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          q_steps=q_steps, q_offset=q_offset),
        grid=(b, hq, kv_steps, q_steps),
        in_specs=[qspec_s, kvspec_s, kvspec_s, qspec_s, vec_s, vec_s],
        out_specs=[dkv_out, dkv_out],
        out_shape=[jax.ShapeDtypeStruct((b, hq, lkv, d), q.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((BK, d), jnp.float32),
                        pltpu.VMEM((BK, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # group-reduce q-head gradients onto kv heads
    dk = dk_h.reshape(b, hkv, group, lkv, d).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(b, hkv, group, lkv, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv
