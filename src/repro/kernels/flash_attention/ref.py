"""Pure-jnp oracle: GQA causal attention with logsumexp output."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(B, Hkv, L, D) -> (B, Hq, L, D) by group broadcast."""
    b, hkv, l, d = k.shape
    g = num_q_heads // hkv
    return jnp.repeat(k, g, axis=1)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, scale: Optional[float] = None,
                  return_lse: bool = False):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D). f32 math throughout."""
    b, hq, lq, d = q.shape
    lkv = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    kf = _expand_kv(k, hq).astype(jnp.float32)
    vf = _expand_kv(v, hq).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    if causal:
        # positions are right-aligned: query i sits at absolute lkv-lq+i
        qi = jnp.arange(lq)[:, None] + (lkv - lq)
        ki = jnp.arange(lkv)[None, :]
        s = jnp.where(ki <= qi, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / l, vf).astype(q.dtype)
    if return_lse:
        lse = (m + jnp.log(l))[..., 0]
        return out, lse
    return out
