"""Differentiable flash-attention wrapper (custom_vjp over the Pallas
kernels), with padding to tile multiples and interpret-mode selection."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import (BK, BQ, flash_attention_bwd_pallas,
                     flash_attention_fwd_pallas)


def _pad_len(l: int, t: int) -> int:
    return (-l) % t


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    scale: Optional[float] = None, interpret: bool = True):
    out, _ = _fwd(q, k, v, causal, scale, interpret)
    return out


def _fwd(q, k, v, causal, scale, interpret):
    b, hq, lq, d = q.shape
    lkv = k.shape[2]
    pq, pk = _pad_len(lq, BQ), _pad_len(lkv, BK)
    if causal and pq != pk:
        # zero-padded q/do rows are provably inert only when the causal
        # right-alignment is preserved, i.e. lq == lkv (mod tile) — true for
        # self-attention (train/prefill). Decode uses decode_attention.
        raise ValueError("causal flash requires lq % BQ == lkv % BK")
    if pk and not causal:
        raise ValueError("non-causal flash requires BK-aligned kv length")
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out, lse = flash_attention_fwd_pallas(qp, kp, vp, causal=causal,
                                          scale=scale, interpret=interpret)
    return out[:, :, :lq], (q, k, v, out, lse, lq, lkv)


def _fwd_rule(q, k, v, causal, scale, interpret):
    out, res = _fwd(q, k, v, causal, scale, interpret)
    return out, res


def _bwd_rule(causal, scale, interpret, res, do):
    q, k, v, out_p, lse, lq, lkv = res
    pq, pk = _pad_len(lq, BQ), _pad_len(lkv, BK)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pq), (0, 0)))
    dq, dk, dv = flash_attention_bwd_pallas(
        qp, kp, vp, out_p, lse, dop, causal=causal, scale=scale,
        interpret=interpret)
    return dq[:, :, :lq], dk[:, :, :lkv], dv[:, :, :lkv]


flash_attention.defvjp(_fwd_rule, _bwd_rule)
