from .kernel import (BK, BQ, flash_attention_bwd_pallas,
                     flash_attention_fwd_pallas)
from .ops import flash_attention
from .ref import attention_ref

__all__ = ["BK", "BQ", "flash_attention", "attention_ref",
           "flash_attention_fwd_pallas", "flash_attention_bwd_pallas"]
