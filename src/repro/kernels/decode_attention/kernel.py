"""Pallas TPU kernel: flash-decoding (one query token vs. long KV cache).

GQA grouping turns the degenerate (1 x D) @ (D x BK) matmul into
(G x D) @ (D x BK): the G query heads sharing one kv head are processed
together as the matmul's row dim — the standard TPU decode trick.

Grid: (B, Hkv, kv_tiles) with kv tiles innermost; running max/sum/acc in
VMEM scratch (online softmax).  Emits normalized output AND the logsumexp so
sequence-sharded caches can combine partial results across devices
(flash-decoding; see ref.combine_partial_attention).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BK = 256          # kv rows per tile (memory-bound op: bigger tiles amortize)
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_scr, l_scr, acc_scr, *, scale, kv_steps):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0, 0]
    # skip tiles entirely beyond the valid prefix
    @pl.when(ik * BK < cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == kv_steps - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


def decode_attention_pallas(q, k, v, cache_len, *,
                            scale: Optional[float] = None,
                            interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D) with S % BK == 0; cache_len: (B,).
    Returns out (B, Hq, D) and lse (B, Hq)."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_steps = s // BK

    qg = q.reshape(b, hkv, g, d)
    lens = cache_len.reshape(b, 1).astype(jnp.int32)

    lenspec = pl.BlockSpec((1, 1), lambda b_, h, ik: (b_, 0))
    qspec = pl.BlockSpec((1, 1, g, d), lambda b_, h, ik: (b_, h, 0, 0))
    kvspec = pl.BlockSpec((1, 1, BK, d), lambda b_, h, ik: (b_, h, ik, 0))
    ospec = qspec
    lsespec = pl.BlockSpec((1, 1, g), lambda b_, h, ik: (b_, h, 0))

    out, lse = pl.pallas_call(
        functools.partial(_kernel, scale=scale, kv_steps=kv_steps),
        grid=(b, hkv, kv_steps),
        in_specs=[lenspec, qspec, kvspec, kvspec],
        out_specs=[ospec, lsespec],
        out_shape=[jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
                   jax.ShapeDtypeStruct((b, hkv, g), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
        interpret=interpret,
    )(lens, qg, k, v)
    return out.reshape(b, hq, d), lse.reshape(b, hq)
