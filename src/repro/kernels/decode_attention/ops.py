"""Jit'd wrapper for flash-decoding: cache padding + interpret selection."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import BK, decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("scale", "interpret",
                                             "return_lse"))
def decode_attention(q, k, v, cache_len, scale: Optional[float] = None,
                     interpret: bool = True, return_lse: bool = False):
    """Same semantics as ref.decode_attention_ref (cache rows >= cache_len
    are ignored). Pads the cache to a BK multiple (padding is masked)."""
    s = k.shape[2]
    pad = (-s) % BK
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out, lse = decode_attention_pallas(q, k, v, cache_len.astype(jnp.int32),
                                       scale=scale, interpret=interpret)
    return (out, lse) if return_lse else out
