from .kernel import BK, decode_attention_pallas
from .ops import decode_attention
from .ref import combine_partial_attention, decode_attention_ref

__all__ = ["BK", "decode_attention", "decode_attention_pallas",
           "decode_attention_ref", "combine_partial_attention"]
