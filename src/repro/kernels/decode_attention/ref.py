"""Pure-jnp oracle: single-token GQA decode attention over a KV cache."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         cache_len: jax.Array,
                         scale: Optional[float] = None,
                         return_lse: bool = False):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); cache_len: (B,) valid prefix.

    GQA is computed GROUPED (q reshaped to (B, Hkv, G, D)) — materializing
    repeat(k, G) is G x the cache bytes and forces a full-cache reshard
    under GSPMD when the cache is sequence-sharded."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg,
                        k.astype(jnp.float32)) * scale     # (B, Hkv, G, S)
    mask = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", p / l, v.astype(jnp.float32))
    out = out.reshape(b, hq, d).astype(q.dtype)
    if return_lse:
        return out, (m + jnp.log(l)).reshape(b, hq)
    return out


def combine_partial_attention(outs: jax.Array, lses: jax.Array) -> jax.Array:
    """Merge per-shard partial decode attention (flash-decoding combine).

    outs: (P, B, H, D) normalized partial outputs; lses: (P, B, H).
    Used when the KV cache is sequence-sharded (long_500k, batch=1)."""
    m = jnp.max(lses, axis=0, keepdims=True)
    w = jnp.exp(lses - m)                                   # (P, B, H)
    num = jnp.sum(outs * w[..., None], axis=0)
    den = jnp.sum(w, axis=0)[..., None]
    return (num / den).astype(outs.dtype)
