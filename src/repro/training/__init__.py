"""Training substrate: optimizer, grad accumulation, checkpoint, fault loop."""
from .checkpoint import AsyncSaver, cleanup, latest_step, restore, save
from .fault import LoopConfig, SimulatedPreemption, TrainLoop
from .grad import make_train_step, quantize_grads_int8
from .optimizer import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                        global_norm, schedule_lr)

__all__ = [
    "AsyncSaver", "cleanup", "latest_step", "restore", "save",
    "LoopConfig", "SimulatedPreemption", "TrainLoop",
    "make_train_step", "quantize_grads_int8",
    "AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
    "global_norm", "schedule_lr",
]
