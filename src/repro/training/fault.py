"""Fault-tolerant training loop: preemption-safe, auto-resume, straggler
watchdog.

Production posture (1000+ nodes):
* checkpoint every ``ckpt_every`` steps through the async saver; SIGTERM
  (preemption notice) triggers a final synchronous save before exit;
* on start, the loop always tries to resume from the latest checkpoint —
  restarts (same or different mesh: elastic restore) are the recovery path
  for node failures;
* a step-time watchdog flags stragglers: steps slower than
  ``straggler_factor`` x the running median raise a callback (at scale the
  callback triggers hot-spare swap / checkpoint-and-reschedule; offline it
  logs).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np

from . import checkpoint as ckpt
from .optimizer import AdamWState


class SimulatedPreemption(Exception):
    """Raised by tests/examples to emulate a SIGTERM mid-run."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class TrainLoop:
    def __init__(self, loop_cfg: LoopConfig, train_step: Callable,
                 params: Any, opt_state: AdamWState, batches: Iterable[dict],
                 pipeline=None, shardings: Optional[Any] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 log: Callable[[str], None] = print):
        self.cfg = loop_cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.batches = iter(batches)
        self.pipeline = pipeline
        self.shardings = shardings
        self.on_straggler = on_straggler or (
            lambda step, t: log(f"[straggler] step {step} took {t:.3f}s"))
        self.log = log
        self.saver = ckpt.AsyncSaver()
        self.step = 0
        self.step_times: List[float] = []
        self._preempted = False

    # ---------------------------------------------------------- lifecycle
    def _install_signal_handler(self):
        def handler(_sig, _frm):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass            # non-main thread (tests)

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state._asdict()}

    def try_resume(self) -> bool:
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        tree, step, extra = ckpt.restore(self.cfg.ckpt_dir,
                                         self._state_tree(),
                                         shardings=self.shardings)
        self.params = tree["params"]
        self.opt_state = AdamWState(**tree["opt"])
        self.step = step
        if self.pipeline is not None and "pipeline" in extra:
            self.pipeline.restore_state(extra["pipeline"])
        self.log(f"[resume] restored step {step} from {self.cfg.ckpt_dir}")
        return True

    def _save(self, sync: bool = False):
        extra = {}
        if self.pipeline is not None:
            extra["pipeline"] = self.pipeline.checkpoint_state()
        if sync:
            ckpt.save(self.cfg.ckpt_dir, self.step, self._state_tree(), extra)
        else:
            self.saver.save_async(self.cfg.ckpt_dir, self.step,
                                  self._state_tree(), extra)
        ckpt.cleanup(self.cfg.ckpt_dir, self.cfg.keep_last)

    # --------------------------------------------------------------- run
    def run(self, max_steps: Optional[int] = None) -> Dict[str, Any]:
        self._install_signal_handler()
        self.try_resume()
        end = min(self.cfg.total_steps,
                  self.step + (max_steps or self.cfg.total_steps))
        metrics = {}
        try:
            while self.step < end:
                batch = next(self.batches)
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step += 1
                self.step_times.append(dt)
                med = float(np.median(self.step_times[-50:]))
                if (len(self.step_times) > 5
                        and dt > self.cfg.straggler_factor * med):
                    self.on_straggler(self.step, dt)
                if self.step % self.cfg.log_every == 0:
                    self.log(f"[step {self.step}] "
                             f"loss={float(metrics['loss']):.4f} "
                             f"({dt*1e3:.0f} ms)")
                if self.step % self.cfg.ckpt_every == 0:
                    self._save()
                if self._preempted:
                    raise SimulatedPreemption
        except SimulatedPreemption:
            self.log(f"[preempt] saving at step {self.step} and exiting")
            self.saver.wait()
            self._save(sync=True)
            raise
        self.saver.wait()
        self._save(sync=True)
        return metrics
