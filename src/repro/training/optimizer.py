"""AdamW from scratch (+ global-norm clipping, LR schedules).

Moments are f32 regardless of param dtype (bf16 params + f32 m/v is the
memory-lean large-model setup; see DESIGN.md §5).  The optimizer state is a
pytree mirroring params, so it shards identically — no replicated moments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: Any                   # f32 pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - 0.9 * frac
    else:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule_lr(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
