"""Train step: microbatched gradient accumulation + AdamW.

``make_train_step(cfg, opt_cfg, microbatches=k)`` splits the global batch
into k microbatches and accumulates f32 gradients with ``lax.scan`` — this
is what bounds activation memory for the 123B/400B dry-run configs (one
microbatch of activations live at a time; weight all-gathers overlap with
the previous microbatch under GSPMD).

Optional cross-pod gradient compression (int8 + error feedback) is applied
just before the optimizer when ``compress_grads`` — the all-reduce then
moves 4x fewer bytes on the slow pod interconnect.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.lm import loss_fn
from .optimizer import AdamWConfig, AdamWState, adamw_update


def _split_batch(batch: Dict[str, jax.Array], k: int,
                 data_axes=None) -> Dict[str, jax.Array]:
    """(B, ...) -> (k, B/k, ...) for every array in the batch.

    The reshape splits the data-sharded batch dim; without an explicit
    constraint GSPMD may replicate the per-step batch across the mesh
    (observed: 16x flops/device on the 256-chip dry-run).  ``data_axes``
    pins the per-microbatch batch dim back onto the data axes."""
    from jax.sharding import PartitionSpec as P

    def r(t):
        b = t.shape[0]
        t = t.reshape(k, b // k, *t.shape[1:])
        if data_axes is not None:
            t = jax.lax.with_sharding_constraint(
                t, P(None, data_axes, *(None,) * (t.ndim - 2)))
        return t
    return jax.tree.map(r, batch)


def quantize_grads_int8(grads: Any, error: Optional[Any] = None
                        ) -> Tuple[Any, Any]:
    """Per-leaf symmetric int8 quantization with error feedback state."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127)
        deq = qi * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [q(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, compress_grads: bool = False,
                    param_shardings: Optional[Any] = None,
                    data_axes=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', state', metrics).

    ``param_shardings``: optional NamedSharding tree; constrains the f32
    gradient accumulator (and per-microbatch grads) to the parameter layout.
    Without it GSPMD may replicate the accumulator across the mesh — a full
    f32 copy of the model per device (verified on the 512-device dry-run).
    """
    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.lax.with_sharding_constraint(tree, param_shardings)

    def grad_fn(params, mb):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
        return loss, aux, grads

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            loss, aux, grads = grad_fn(params, batch)
            grads = constrain(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        else:
            mbs = _split_batch(batch, microbatches, data_axes=data_axes)
            acc0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def body(carry, mb):
                acc, loss_sum = carry
                loss, aux, grads = grad_fn(params, mb)
                acc = constrain(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32),
                    acc, constrain(grads)))
                return (acc, loss_sum + loss), None

            (grads, loss_sum), _ = jax.lax.scan(body, (acc0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            aux = {}
        if compress_grads:
            grads, _ = quantize_grads_int8(grads)
        new_params, new_state, om = adamw_update(opt_cfg, grads, opt_state,
                                                 params)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step
