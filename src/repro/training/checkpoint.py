"""Sharded, atomic, elastic checkpointing.

* **Atomic**: written to ``<dir>/tmp.<step>`` and os.rename'd to
  ``<dir>/step_<n>`` — a preemption mid-write never corrupts the latest
  checkpoint (rename is atomic on POSIX).
* **Elastic**: leaves are saved as full (host-gathered) arrays + a JSON
  tree manifest; restore re-shards onto *any* mesh via device_put with that
  mesh's NamedShardings — pod count can change between jobs.  (At true
  multi-host scale each host writes its addressable shards and restore
  reads per-shard files; the manifest format already carries the leaf
  paths needed for that extension.)
* **Async**: ``save_async`` hands the host copy to a worker thread so the
  step loop is not blocked on disk.
* Data-pipeline state and the step counter travel inside the checkpoint, so
  resume replays nothing and skips nothing.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "__"


def _leaf_name(path) -> str:
    return _SEP.join(re.sub(r"[^A-Za-z0-9_.-]", "_", str(p)) for p in path)


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [_leaf_name([getattr(k, 'key', getattr(k, 'idx', k))
                         for k in path]) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{name}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncSaver:
    """One-slot background saver (latest request wins)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save_async(self, ckpt_dir: str, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=lambda: setattr(self, "last_path",
                                   save(ckpt_dir, step, host_tree, extra)),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``target_tree``; optionally re-shard
    every leaf with the matching ``shardings`` pytree (elastic restore)."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}

    names, leaves, treedef = _flatten_with_names(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for name, ref, shard in zip(names, leaves, shard_leaves):
        rec = by_name[name]
        arr = np.load(os.path.join(path, rec["file"]))
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step, manifest.get("extra", {})


def cleanup(ckpt_dir: str, keep_last: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
