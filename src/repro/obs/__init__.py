"""Observability: metrics registry, trace spans, recompile sentinel.

A leaf package — ``core`` and ``serving`` import it, never the reverse
— so instrumentation can reach any layer without cycles.  See the
README's "Observability" section for the metric catalog and the
CONTRIBUTING.md naming convention (``<layer>.<noun>[_<unit>]``).
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      PeriodicLogger, get_registry)
from .recompile import (EXPECTED_SHAPE_CHANGE_KINDS, HotPathRecompileError,
                        RecompileSentinel, state_shapes)
from .tracing import NULL_SPAN, Span, Tracer

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "PeriodicLogger", "get_registry",
           "EXPECTED_SHAPE_CHANGE_KINDS", "HotPathRecompileError",
           "RecompileSentinel", "state_shapes",
           "NULL_SPAN", "Span", "Tracer"]
