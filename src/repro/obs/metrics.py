"""Process-wide metrics registry: counters, gauges, and log-bucketed
latency histograms behind one lock.

The serving stack mutates statistics from at least three threads (the
scheduler, the prepare worker, and whoever calls ``stop()``); before
this module each layer kept ad-hoc dataclass counters with ad-hoc
locking.  The registry centralizes both the storage and the lock:

* **Counter** — monotone ``inc``; optional labels fan a name out into
  cells (``serve.batch_bucket{bucket=32}``).
* **Gauge** — last-write-wins ``set`` (plus ``add`` for deltas).
* **Histogram** — log₂-bucketed observations with exact ``count`` /
  ``sum`` / ``min`` / ``max`` and quantile summaries (p50/p90/p99 read
  off the bucket CDF, so they carry ~2x resolution — tail *ratios*
  across runs are meaningful, individual values are bucket edges).

Every mutation takes the registry lock — the fix for the torn
``AsyncStats`` updates — but a **disabled** registry short-circuits
before the lock, so instrumented hot paths pay one attribute load and
one branch.  ``snapshot()`` returns a plain-Python dict (every leaf
survives ``json.dumps`` untouched) and ``to_prometheus()`` renders the
v0 text exposition format; ``PeriodicLogger`` ships snapshots to a sink
on a timer for long-running servers.

One process-wide default registry (``get_registry``) keeps
instrumentation call sites decoupled from construction; tests that need
isolation construct a private ``MetricsRegistry`` and pass it down.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

# log2 histogram geometry: bucket i spans [2^(B0+i), 2^(B0+i+1)) seconds
# (or whatever unit the caller observes); 2^-20 s ≈ 1 µs up to 2^19 s.
_BUCKET0 = -20
_NBUCKETS = 40

_LabelKey = Tuple[Tuple[str, object], ...]


def _label_key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted(labels.items()))


def _cell_name(name: str, key: _LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone counter; ``labels`` fan out into independent cells."""

    __slots__ = ("name", "help", "_reg", "_cells")

    def __init__(self, reg: "MetricsRegistry", name: str, help: str = ""):
        self.name = name
        self.help = help
        self._reg = reg
        self._cells: Dict[_LabelKey, float] = {}

    def inc(self, value: float = 1, **labels) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        key = _label_key(labels)
        with reg.lock:
            self._cells[key] = self._cells.get(key, 0) + value

    def value(self, **labels) -> float:
        with self._reg.lock:
            return self._cells.get(_label_key(labels), 0)

    def cells(self) -> Dict[str, float]:
        """``{rendered-label-suffix: value}`` for every cell."""
        with self._reg.lock:
            return {_cell_name(self.name, k): v
                    for k, v in sorted(self._cells.items())}

    def raw(self) -> Dict[_LabelKey, float]:
        """Unrendered ``{label-key: value}`` — for delta snapshots."""
        with self._reg.lock:
            return dict(self._cells)


class Gauge:
    """Last-write-wins value (``set``) with a delta form (``add``)."""

    __slots__ = ("name", "help", "_reg", "_cells")

    def __init__(self, reg: "MetricsRegistry", name: str, help: str = ""):
        self.name = name
        self.help = help
        self._reg = reg
        self._cells: Dict[_LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        with reg.lock:
            self._cells[_label_key(labels)] = value

    def add(self, value: float, **labels) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        key = _label_key(labels)
        with reg.lock:
            self._cells[key] = self._cells.get(key, 0) + value

    def value(self, **labels) -> float:
        with self._reg.lock:
            return self._cells.get(_label_key(labels), 0)

    def cells(self) -> Dict[str, float]:
        with self._reg.lock:
            return {_cell_name(self.name, k): v
                    for k, v in sorted(self._cells.items())}


class Histogram:
    """Log₂-bucketed distribution with exact count/sum/min/max."""

    __slots__ = ("name", "help", "_reg", "_buckets", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, reg: "MetricsRegistry", name: str, help: str = ""):
        self.name = name
        self.help = help
        self._reg = reg
        self._buckets = [0] * _NBUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    @staticmethod
    def _index(value: float) -> int:
        if value <= 0:
            return 0
        # frexp: value = m * 2^e with m in [0.5, 1) -> floor(log2) = e - 1
        _, e = math.frexp(value)
        return min(max(e - 1 - _BUCKET0, 0), _NBUCKETS - 1)

    def observe(self, value: float) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        i = self._index(value)
        with reg.lock:
            self._buckets[i] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def time(self) -> "_HistTimer":
        """``with hist.time(): ...`` — observe the block's duration."""
        return _HistTimer(self)

    def _quantile_locked(self, q: float) -> float:
        """Upper edge of the bucket holding the q-quantile, clamped to
        the exact observed extremes (must hold ``self._reg.lock``)."""
        if self._count == 0:
            return 0.0
        rank = q * self._count
        acc = 0
        for i, n in enumerate(self._buckets):
            acc += n
            if acc >= rank:
                edge = 2.0 ** (_BUCKET0 + i + 1)
                return min(max(edge, self._min), self._max)
        return self._max

    def summary(self) -> Dict[str, float]:
        with self._reg.lock:
            if self._count == 0:
                return dict(count=0, sum=0.0)
            return dict(count=self._count, sum=self._sum,
                        min=self._min, max=self._max,
                        p50=self._quantile_locked(0.50),
                        p90=self._quantile_locked(0.90),
                        p99=self._quantile_locked(0.99))


class _HistTimer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self) -> "_HistTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


class MetricsRegistry:
    """Thread-safe named-metric store with JSON / Prometheus exporters.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create (same name
    → same object, so instrumentation sites never race on registration);
    re-registering a name as a different kind is an error.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.lock = threading.RLock()
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str):
        with self.lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(self, name, help)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def names(self) -> List[str]:
        with self.lock:
            return sorted(self._metrics)

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop every registered metric (tests; not for serving use)."""
        with self.lock:
            self._metrics.clear()

    # -------------------------------------------------------- exporters
    def snapshot(self) -> Dict[str, Dict]:
        """Pure-Python dict of everything registered — every leaf is an
        int/float/str, so ``json.dumps(snapshot())`` always works."""
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, float]] = {}
        with self.lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                counters.update(m.cells())
            elif isinstance(m, Gauge):
                gauges.update(m.cells())
            else:
                hists[name] = m.summary()
        return dict(counters=counters, gauges=gauges, histograms=hists)

    def to_prometheus(self) -> str:
        """Prometheus v0 text exposition.  Counters get the ``_total``
        suffix, histograms export as summaries (quantile-labelled
        samples plus ``_sum`` / ``_count``); every registered metric
        emits at least its ``# TYPE`` header and one sample."""
        out: List[str] = []
        with self.lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            pname = _prom_name(name)
            if isinstance(m, Counter):
                out.append(f"# TYPE {pname}_total counter")
                cells = m.cells() or {name: 0}
                for cell, v in cells.items():
                    out.append(f"{_prom_sample(cell, '_total')} {_fmt(v)}")
            elif isinstance(m, Gauge):
                out.append(f"# TYPE {pname} gauge")
                cells = m.cells() or {name: 0}
                for cell, v in cells.items():
                    out.append(f"{_prom_sample(cell, '')} {_fmt(v)}")
            else:
                s = m.summary()
                out.append(f"# TYPE {pname} summary")
                for q in ("p50", "p90", "p99"):
                    if q in s:
                        out.append(f'{pname}{{quantile="0.{q[1:]}"}} '
                                   f"{_fmt(s[q])}")
                out.append(f"{pname}_sum {_fmt(s.get('sum', 0.0))}")
                out.append(f"{pname}_count {_fmt(s.get('count', 0))}")
        return "\n".join(out) + "\n"


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_sample(cell: str, suffix: str) -> str:
    """Render one cell name (``a.b{k=v,...}`` or bare) as a Prometheus
    sample name with quoted label values."""
    if "{" not in cell:
        return _prom_name(cell) + suffix
    base, rest = cell.split("{", 1)
    labels = rest[:-1]
    quoted = ",".join(f'{k}="{v}"'
                      for k, v in (p.split("=", 1)
                                   for p in labels.split(",")))
    return f"{_prom_name(base)}{suffix}{{{quoted}}}"


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


class PeriodicLogger:
    """Ship a compact snapshot line to ``sink`` every ``interval``
    seconds on a daemon thread (default sink: ``print``).  ``stop()``
    flushes one final line so short runs still log."""

    def __init__(self, registry: MetricsRegistry, interval: float = 30.0,
                 sink: Optional[Callable[[str], None]] = None):
        self.registry = registry
        self.interval = interval
        self.sink = sink if sink is not None else print
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _emit(self) -> None:
        snap = self.registry.snapshot()
        self.sink(json.dumps(snap, separators=(",", ":"), sort_keys=True))

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            self._emit()

    def start(self) -> "PeriodicLogger":
        if self._thread is not None:
            raise RuntimeError("already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="cft-metrics-log", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._emit()

    def __enter__(self) -> "PeriodicLogger":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


_default_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every instrumented layer shares."""
    return _default_registry
