"""Hot-path recompile sentinel.

PR 6's dominant tail-latency pathology was a silent one: a maintenance
commit that changed a committed array shape (an unpadded CSR, a resized
arena reaching the jitted step with a new geometry) forced XLA to
recompile the serve step *on the next dispatch* — ~650 ms landing on
whichever request was unlucky.  The fix (``pad_csr`` shape stability)
was diagnosed by hand; this module makes the diagnosis permanent:

* **cache-size watching** — ``watch()`` registers jitted callables (the
  serve step) and baselines their compiled-geometry counts
  (``_cache_size``).  ``check()`` reports any growth since the baseline
  as hot-path recompiles (``serve.hot_recompiles`` counter) and
  re-baselines.  ``rebaseline()`` after warmup excludes intentional
  compiles.
* **commit shape classification** — ``note_commit()`` compares the
  committed state's array shapes before/after a maintenance commit.  A
  ``segment``/``full``/``splice`` plan legitimately changes the arena
  geometry (``maint.commit_shape_changes{expected=true}``); a ``delta``
  or ``none`` plan must not change any shape — when one does, that is
  exactly the PR 6 bug reborn (``expected=false``).
* **arming** — ``arm()`` turns both detectors from counters into
  tripwires: an unexpected commit shape change or a post-warmup
  hot-path recompile raises :class:`HotPathRecompileError` instead of
  silently eating the tail.

A process-wide ``jax.monitoring`` listener (via
``compat.register_compile_listener``) additionally counts *every*
backend compile in the process (``xla.compiles`` /
``xla.compile_s``) — warmup, maintenance warm-compiles, everything —
giving snapshots the denominator against which zero hot-path
recompiles is meaningful.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

from .metrics import MetricsRegistry, get_registry

# plan kinds whose commits legitimately change committed array shapes
# (resized arena segment, full repack / restage)
EXPECTED_SHAPE_CHANGE_KINDS = ("segment", "full", "splice")

# committed arrays whose shapes feed the jitted serve step: any change
# here invalidates the step's cached executable for that geometry
_STATE_FIELDS = ("fingerprints", "temperature", "heads", "masks",
                 "csr_offsets", "csr_nodes", "bucket_offsets",
                 "row_offsets", "tree_starts", "tree_shard")


class HotPathRecompileError(RuntimeError):
    """An armed sentinel observed serve-path compilation work that the
    padding / splice machinery promises never happens."""


def state_shapes(state) -> Dict[str, Tuple[int, ...]]:
    """Shape fingerprint of a device state's jit-relevant arrays."""
    out: Dict[str, Tuple[int, ...]] = {}
    for f in _STATE_FIELDS:
        a = getattr(state, f, None)
        if a is not None and hasattr(a, "shape"):
            out[f] = tuple(int(d) for d in a.shape)
    return out


class RecompileSentinel:
    """Watches jitted serve callables and maintenance commits for
    shape-instability; counts always, raises when armed."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.metrics = registry if registry is not None else get_registry()
        self._lock = threading.Lock()
        self._watched: Dict[str, Tuple[Callable[[], int], int]] = {}
        self._armed = False
        self._forgive = False           # one expected geometry compile
        self._local_recompiles = 0      # this sentinel's lifetime count
        #                                 (the registry counter is
        #                                 process-cumulative)
        self._recompiles = self.metrics.counter(
            "serve.hot_recompiles",
            "post-warmup compilations of watched serve-path callables")
        self._shape_changes = self.metrics.counter(
            "maint.commit_shape_changes",
            "maintenance commits that changed committed array shapes")
        _ensure_process_listener(self.metrics)

    # ------------------------------------------------------ cache sizes
    def watch(self, label: str, fn) -> bool:
        """Track a jitted callable's compiled-geometry count.  Accepts
        anything exposing ``_cache_size()`` (``jax.jit`` products);
        returns False (untracked) otherwise."""
        size = getattr(fn, "_cache_size", None)
        if not callable(size):
            return False
        with self._lock:
            self._watched[label] = (size, int(size()))
        return True

    def rebaseline(self) -> None:
        """Accept current cache sizes as intentional (call after
        warmup, or after an expected-shape-change commit)."""
        with self._lock:
            self._watched = {k: (fn, int(fn()))
                             for k, (fn, _) in self._watched.items()}

    def allow_next(self) -> None:
        """Forgive the next cache growth once — called after a commit
        whose plan kind legitimately changed the serve geometry (the
        step must compile it exactly once)."""
        with self._lock:
            self._forgive = True

    def check(self) -> Dict[str, int]:
        """New compilations per watched callable since the last check;
        counts them, re-baselines, raises when armed and non-empty
        (unless an expected geometry change forgave this growth)."""
        grown: Dict[str, int] = {}
        with self._lock:
            for label, (fn, base) in list(self._watched.items()):
                cur = int(fn())
                if cur > base:
                    grown[label] = cur - base
                    self._watched[label] = (fn, cur)
            forgiven = grown and self._forgive
            if grown:
                self._forgive = False
            if not forgiven:
                self._local_recompiles += sum(grown.values())
        if forgiven:
            self.metrics.counter(
                "serve.expected_recompiles",
                "serve-step compiles of legitimately resized geometries"
            ).inc(sum(grown.values()))
            return {}
        for label, n in grown.items():
            self._recompiles.inc(n, fn=label)
        if grown and self._armed:
            raise HotPathRecompileError(
                f"hot serve path recompiled: {grown} new XLA "
                "compilations on watched jitted callables — a commit "
                "leaked an unpadded / resized shape into the step")
        return grown

    @property
    def recompiles(self) -> int:
        """Hot-path recompiles this sentinel has counted (per-sentinel,
        unlike the process-cumulative registry counter)."""
        with self._lock:
            return self._local_recompiles

    # ---------------------------------------------------------- commits
    def note_commit(self, kind: Optional[str],
                    before: Dict[str, Tuple[int, ...]],
                    after: Dict[str, Tuple[int, ...]]) -> List[str]:
        """Classify one maintenance commit's shape delta.  Returns the
        fields whose shape changed; counts them as expected/unexpected
        by plan ``kind`` and raises when armed on an unexpected one."""
        changed = sorted(k for k in set(before) | set(after)
                         if before.get(k) != after.get(k))
        if not changed:
            return changed
        expected = kind in EXPECTED_SHAPE_CHANGE_KINDS
        self._shape_changes.inc(expected=str(expected).lower(),
                                kind=kind or "unknown")
        if expected:
            # the step must compile the new geometry once — forgive it
            self.allow_next()
            return changed
        if self._armed:
            raise HotPathRecompileError(
                f"{kind!r}-plan commit changed committed array shapes "
                f"{changed} — delta commits must be shape-preserving "
                "(is pad_csr being bypassed?)")
        return changed

    # ------------------------------------------------------------ state
    def arm(self) -> "RecompileSentinel":
        self._armed = True
        return self

    def disarm(self) -> "RecompileSentinel":
        self._armed = False
        return self

    @property
    def armed(self) -> bool:
        return self._armed


# one process-wide jax.monitoring listener, shared by every sentinel;
# jax offers no targeted unregister, so this never unhooks
_listener_lock = threading.Lock()
_listener_installed: Optional[bool] = None


def _on_backend_compile(event: str, duration: float) -> None:
    reg = get_registry()
    reg.counter("xla.compiles",
                "process-wide backend compilations (any cause)").inc()
    reg.histogram("xla.compile_s", "backend compile durations") \
       .observe(duration)


def _ensure_process_listener(registry: MetricsRegistry) -> bool:
    global _listener_installed
    with _listener_lock:
        if _listener_installed is None:
            from ..compat import register_compile_listener
            _listener_installed = register_compile_listener(
                _on_backend_compile)
        return _listener_installed
