"""Trace spans over the serving and maintenance lifecycles.

A span is one named unit of work (an async batch, a sync retrieve, a
maintenance prepare) carrying attributes (bucket size, plan kind) and a
sequence of timed **stages** — the async request path decomposes as
``coalesce → pad → dispatch → prepare → device_lookup → route_back``,
the maintenance path as ``maintain → plan → warm`` then ``splice``.

On ``end()`` the span lands twice:

* each stage's duration feeds a registry histogram named
  ``trace.<span>.<stage>`` (plus ``trace.<span>`` for the total), so the
  per-stage p50/p90/p99 aggregates ride in every snapshot;
* the finished span joins a bounded ring buffer (``Tracer.recent()``)
  for request-level inspection — plain dicts, JSON-ready.

A disabled registry makes ``Tracer.span`` return a shared no-op span,
so traced hot paths cost one branch when observability is off.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

from .metrics import MetricsRegistry, get_registry


class Span:
    """One traced unit of work; create via :meth:`Tracer.span`."""

    __slots__ = ("name", "attrs", "stages", "_tracer", "_t0", "_last",
                 "_wall")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self.stages: List[Dict] = []
        self._tracer = tracer
        self._t0 = tracer.clock()
        self._last = self._t0
        self._wall = time.time()

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def stage(self, name: str) -> "_StageTimer":
        """``with span.stage("dispatch"): ...`` — time one stage."""
        return _StageTimer(self, name)

    def add_stage(self, name: str, duration: float) -> "Span":
        """Record an externally-measured stage (e.g. coalesce time,
        which elapsed before the span opened)."""
        self.stages.append(dict(stage=name, duration_s=float(duration)))
        return self

    def end(self) -> "Span":
        self._tracer._finish(self, self._tracer.clock() - self._t0)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def to_dict(self) -> Dict:
        return dict(span=self.name, t_wall=self._wall,
                    attrs=dict(self.attrs), stages=list(self.stages))


class _StageTimer:
    __slots__ = ("_span", "_name", "_t0")

    def __init__(self, span: Span, name: str):
        self._span = span
        self._name = name

    def __enter__(self) -> "_StageTimer":
        self._t0 = self._span._tracer.clock()
        return self

    def __exit__(self, *exc) -> None:
        self._span.add_stage(self._name,
                             self._span._tracer.clock() - self._t0)


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def stage(self, name: str) -> "_NullSpan":
        return self

    def add_stage(self, name: str, duration: float) -> "_NullSpan":
        return self

    def end(self) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def to_dict(self) -> Dict:
        return {}


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory bound to a registry; keeps the last ``capacity``
    finished spans and aggregates stage durations into histograms."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 capacity: int = 512, clock=time.perf_counter):
        self.registry = registry if registry is not None else get_registry()
        self.clock = clock
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def span(self, name: str, **attrs):
        if not self.registry.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def _finish(self, span: Span, total: float) -> None:
        reg = self.registry
        reg.histogram(f"trace.{span.name}").observe(total)
        for st in span.stages:
            reg.histogram(f"trace.{span.name}.{st['stage']}") \
               .observe(st["duration_s"])
        with self._lock:
            self._ring.append(span.to_dict())

    def recent(self, n: Optional[int] = None) -> List[Dict]:
        """The most recent finished spans, oldest first — plain dicts."""
        with self._lock:
            spans = list(self._ring)
        return spans if n is None else spans[-n:]
