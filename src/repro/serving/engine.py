"""Serving engine: jitted prefill + decode loop with a continuous-lite
batch scheduler.

The decode step donates the cache/state buffers (no double-buffered KV), and
greedy sampling runs on device.  The scheduler packs pending requests into
fixed-size batches (padding short prompts) — the "continuous-lite" policy:
new requests join at the next batch boundary rather than mid-flight, which
keeps the step function shape-stable (one compilation per batch geometry).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import (CFTDeviceState, DeviceRetrieval, MaintenanceEngine,
                    MaintenanceReport, ShardedBankState, retrieve_device,
                    sharded_retrieve_device)
from ..core.maintenance import RestageCoordinator
from ..data.tokenizer import HashTokenizer
from ..models import lm


@dataclasses.dataclass
class Request:
    prompt_ids: List[int]
    max_new_tokens: int = 16
    out_ids: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, cache_size: int = 512,
                 batch_size: int = 4):
        self.cfg = cfg
        self.params = params
        self.cache_size = cache_size
        self.batch_size = batch_size

        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg, cache_size=cache_size))
        self._decode = jax.jit(
            functools.partial(lm.decode_step, cfg), donate_argnums=(2,))
        self._ret_state: Optional[CFTDeviceState] = None
        self._maint: Optional[MaintenanceEngine] = None
        self._coord: Optional[RestageCoordinator] = None

    # ---------------------------------------------------------- retrieval
    def attach_retrieval(self, state, lookup_fn=None,
                         max_locs: int = 4, n: int = 3,
                         batch_pad: int = 64) -> None:
        """Fuse CFT retrieval into the engine: one jitted step over the
        bank-axis device state, shape-stable via fixed padding geometry.

        ``state`` is either a replicated :class:`CFTDeviceState` or a
        bank-axis :class:`ShardedBankState` — the sharded step routes each
        query batch to the owning shards with an all-to-all instead of
        probing a replicated bank; everything downstream (padding policy,
        temperature threading, maintenance harvest) is identical.
        """
        self._ret_state = state
        self._ret_pad = batch_pad
        if isinstance(state, ShardedBankState):
            # already jitted; mesh/axis ride in the state's static aux
            self._ret_step = functools.partial(
                sharded_retrieve_device, max_locs=max_locs, n=n,
                lookup_fn=lookup_fn)
        else:
            self._ret_step = jax.jit(functools.partial(
                retrieve_device, max_locs=max_locs, n=n,
                lookup_fn=lookup_fn))

    def retrieve(self, tree_ids: Sequence[int],
                 hashes: Sequence[int]) -> DeviceRetrieval:
        """Serve one ``(tree_id, hash)`` query batch.

        Queries pad to a multiple of ``batch_pad`` (one compilation per
        geometry, like the token scheduler).  Pad slots query tree 0 with
        hash 0; a pad hash can in principle alias a stored fingerprint,
        which only over-bumps that slot's temperature — a heuristic,
        not a correctness input.
        """
        if self._ret_state is None:
            raise RuntimeError("call attach_retrieval() first")
        b = len(hashes)
        bp = max(self._ret_pad, -(-b // self._ret_pad) * self._ret_pad)
        tid = np.zeros((bp,), np.int32)
        tid[:b] = np.asarray(tree_ids, np.int32)
        hh = np.zeros((bp,), np.uint32)
        hh[:b] = np.asarray(hashes, np.uint32)
        out = self._ret_step(self._ret_state, jnp.asarray(hh),
                             jnp.asarray(tid))
        self._ret_state = self._ret_state.with_temperature(out.temperature)
        if self._maint is not None and not self._coord.deferring:
            # close the paper's feedback loop: harvest this batch's bumps
            # into the host bank (drives the idle-sort trigger policy).
            # While a restage is staged-but-uncommitted the harvest is
            # deferred — bumps stay on device and the first post-commit
            # batch harvests them.
            self._maint.absorb(self._ret_state)
        return DeviceRetrieval(hit=out.hit[:b], locations=out.locations[:b],
                               up=out.up[:b], down=out.down[:b],
                               temperature=out.temperature)

    # -------------------------------------------------------- maintenance
    def attach_maintenance(self, maint, forest) -> None:
        """Attach a host-side maintenance engine (``MaintenanceEngine`` or
        ``ShardedMaintenanceEngine``) over the bank backing the attached
        retrieval state — which must have just been staged from that bank
        (the engine's restage shadow is initialized to its content).
        ``retrieve`` then harvests temperature after every query batch,
        and :meth:`maintain` (called between batches, or by ``serve``
        automatically) applies queued insert/delete deltas, compacts,
        resorts, and splice-commits the device state whenever the bank
        mutated."""
        self._maint = maint
        self._coord = RestageCoordinator(maint, forest)

    def prepare_maintenance(self) -> Optional[MaintenanceReport]:
        """Phase one of the zero-pause restage: run the host-side
        maintenance pass (absorb → delta → compact → shrink → sort) and
        stage the restage plan's payload — only the changed bytes.

        Everything here is host work plus async device_put dispatch, so
        it overlaps with an in-flight serve batch: issue the next batch,
        call this, then :meth:`commit_maintenance` once the batch is
        consumed.  The old state keeps serving untouched until commit.
        An uncommitted previous plan is committed first (plans do not
        stack)."""
        if self._maint is None:
            return None
        self.commit_maintenance()
        return self._coord.prepare(self._ret_state)

    def commit_maintenance(self) -> bool:
        """Phase two: the O(changed-bytes) device splice + atomic state
        swap.  Returns True when a staged plan was applied.  The splice
        donates the old state's arena buffers — the swapped-out state must
        not be probed again (on backends without donation this is merely
        a copy)."""
        if self._coord is None:
            return False
        self._ret_state, applied = self._coord.commit(self._ret_state)
        return applied

    def maintain(self) -> Optional[MaintenanceReport]:
        """Idle-time maintenance hook (between serving batches) — the
        single-call wrapper over :meth:`prepare_maintenance` +
        :meth:`commit_maintenance`.

        With a maintenance engine attached: one ``maintain`` pass on the
        host bank, then splice-commit the changed bytes into the device
        state (host stays the source of truth so slot layouts never
        diverge; a compaction falls back to the full restage).  Without
        one: a pure device-side idle sort (``sort_buckets_arena``) — hot
        fingerprints bubble to slot 0 using temperature alone."""
        if self._maint is not None:
            report = self.prepare_maintenance()
            self.commit_maintenance()
            return report
        if self._ret_state is not None:
            self._ret_state = self._ret_state.sort_idle()
        return None

    # ----------------------------------------------------------- generate
    def generate(self, batch: Dict[str, jax.Array], max_new_tokens: int
                 ) -> np.ndarray:
        """Greedy generation. batch['tokens']: (B, S) prompt ids."""
        logits, state = self._prefill(self.params, batch)
        tok = lm.greedy_token(logits)
        out = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tok, state)
            tok = lm.greedy_token(logits)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)            # (B, new)

    # ---------------------------------------------------------- scheduler
    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Continuous-lite: group requests into fixed batches, pad, run."""
        pending = list(requests)
        done: List[Request] = []
        while pending:
            group = pending[:self.batch_size]
            pending = pending[self.batch_size:]
            max_new = max(r.max_new_tokens for r in group)
            # context-window truncation: keep the prompt tail (query end)
            budget = self.cache_size - max_new
            for r in group:
                if len(r.prompt_ids) > budget:
                    r.prompt_ids = r.prompt_ids[-budget:]
            max_len = max(len(r.prompt_ids) for r in group)
            toks = np.full((self.batch_size, max_len), HashTokenizer.PAD,
                           np.int32)
            for i, r in enumerate(group):     # left-pad to align last token
                toks[i, max_len - len(r.prompt_ids):] = r.prompt_ids
            out = self.generate({"tokens": jnp.asarray(toks)}, max_new)
            for i, r in enumerate(group):
                r.out_ids = out[i, :r.max_new_tokens].tolist()
                done.append(r)
            if self._maint is not None:
                self.maintain()    # idle window between batches: apply
                #                    pending deltas, resort, restage
        return done


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_size: int) -> int:
    """Sizing helper (used by roofline + admission control)."""
    hd = cfg.resolved_head_dim
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    if cfg.family == "rwkv":
        return cfg.n_layers * batch * cfg.n_heads * hd * hd * 4
    layers = cfg.n_layers if cfg.family != "mamba_hybrid" \
        else cfg.n_layers // max(cfg.attn_every, 1)
    return 2 * layers * batch * cfg.n_kv_heads * cache_size * hd * bpe
