"""Serving engine: jitted prefill + decode loop with a continuous-lite
batch scheduler.

The decode step donates the cache/state buffers (no double-buffered KV), and
greedy sampling runs on device.  The scheduler packs pending requests into
fixed-size batches (padding short prompts) — the "continuous-lite" policy:
new requests join at the next batch boundary rather than mid-flight, which
keeps the step function shape-stable (one compilation per batch geometry).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import (CFTDeviceState, DeviceRetrieval, MaintenanceEngine,
                    MaintenanceReport, ShardedBankState, retrieve_device,
                    sharded_retrieve_device)
from ..core.maintenance import RestageCoordinator
from ..data.tokenizer import HashTokenizer
from ..models import lm
from ..obs import RecompileSentinel, Tracer, get_registry, state_shapes


@dataclasses.dataclass
class Request:
    prompt_ids: List[int]
    max_new_tokens: int = 16
    out_ids: Optional[List[int]] = None


class RetrievalSession:
    """The enqueue-able retrieval unit behind every serving front end.

    Owns a bank-axis device state, the jitted lookup step, the padding
    policy, temperature threading, and the two-phase maintenance
    lifecycle.  ``ServeEngine`` composes one (synchronous batches),
    ``AsyncServeEngine`` schedules one (continuous batching), and
    ``RAGPipeline``'s device path delegates to one — so the state-swap /
    harvest / restage invariants live in exactly one place.

    The hot path splits into dispatch and harvest so a scheduler can
    overlap host work with the in-flight device batch:

    * :meth:`pad_queries` — shape-stable padding (fixed multiple for the
      sync engine, pow2 buckets for the async one);
    * :meth:`retrieve_dispatch` — run the jitted step and thread the
      temperature state *without* forcing a device sync;
    * :meth:`harvest` — best-effort absorb of device temperature into
      the host bank (skipped while a restage plan is pending).
    """

    def __init__(self):
        self.state = None                      # CFTDeviceState | Sharded
        self.maint: Optional[MaintenanceEngine] = None
        self.coord: Optional[RestageCoordinator] = None
        self.snapshots = None                  # Optional[SnapshotWriter]
        self.tenants = None                    # Optional[TenantRegistry]
        self.batch_pad = 64
        self.fused = False
        self._step = None
        self._watched_step = None
        self._attach_args = (None, 4, 3)
        # observability: process-wide registry, per-session tracer and
        # recompile sentinel (the PR 6 shape-instability tripwire)
        self.metrics = get_registry()
        self.tracer = Tracer(self.metrics)
        self.sentinel = RecompileSentinel(self.metrics)

    # ------------------------------------------------------------ attach
    def attach(self, state, lookup_fn=None, max_locs: int = 4, n: int = 3,
               batch_pad: int = 64, fused: bool = False) -> None:
        """Point the session at a device state: one jitted step over the
        bank-axis layout, shape-stable via the padding policy.

        ``state`` is either a replicated :class:`CFTDeviceState` or a
        bank-axis :class:`ShardedBankState` — the sharded step routes each
        query batch to the owning shards with an all-to-all instead of
        probing a replicated bank; everything downstream (padding policy,
        temperature threading, maintenance harvest) is identical.

        ``fused=True`` serves through the single-pass
        :mod:`repro.kernels.fused_retrieve` kernel (probe + bump + CSR
        window + hierarchy walks in one launch; owner-shard fusion on the
        sharded layout).  Mutually exclusive with ``lookup_fn`` — the
        fused kernel *is* the probe.  Flip at runtime with
        :meth:`set_fused`.
        """
        if fused and lookup_fn is not None:
            raise ValueError("fused=True embeds the probe; lookup_fn "
                             "cannot be combined with it")
        self.state = state
        self.batch_pad = batch_pad
        self.fused = bool(fused)
        self._attach_args = (lookup_fn, max_locs, n)
        self._build_step()

    def _build_step(self) -> None:
        lookup_fn, max_locs, n = self._attach_args
        if isinstance(self.state, ShardedBankState):
            # already jitted; mesh/axis ride in the state's static aux
            self._step = functools.partial(
                sharded_retrieve_device, max_locs=max_locs, n=n,
                lookup_fn=lookup_fn, fused=self.fused)
            from ..core.distributed import _sharded_retrieve_jit
            self._watched_step = _sharded_retrieve_jit
        elif self.fused:
            # the fused entry picks row tiling / VMEM fit outside any
            # trace, so the jit boundary is the kernel ops wrapper — keep
            # a jitted unfused step around for the VMEM-overflow fallback
            from ..kernels.fused_retrieve import (fused_retrieve_state_auto,
                                                  ops as _fops)
            unfused = jax.jit(functools.partial(
                retrieve_device, max_locs=max_locs, n=n))

            def step(state, hh, tid):
                out = fused_retrieve_state_auto(state, hh, tid,
                                                max_locs=max_locs, n=n)
                return out if out is not None else unfused(state, hh, tid)

            self._step = step
            self._watched_step = _fops.fused_retrieve_ragged
        else:
            self._step = jax.jit(functools.partial(
                retrieve_device, max_locs=max_locs, n=n,
                lookup_fn=lookup_fn))
            self._watched_step = self._step
        self.sentinel.watch("serve.step", self._watched_step)

    def set_fused(self, on: bool) -> None:
        """Flip the attached step between the fused single-pass kernel
        and the unfused oracle path at runtime.  The new step compiles
        its geometries once — an expected, intentional event — so the
        recompile sentinel forgives exactly one cache growth
        (:meth:`RecompileSentinel.allow_next`), keeping armed tripwires
        quiet for the flip itself but live for anything after it."""
        if self.state is None:
            raise RuntimeError("attach a retrieval state first")
        if bool(on) == self.fused:
            return
        lookup_fn, _, _ = self._attach_args
        if on and lookup_fn is not None:
            raise ValueError("fused=True embeds the probe; lookup_fn "
                             "cannot be combined with it")
        self.fused = bool(on)
        self._build_step()
        self.sentinel.allow_next()

    def attach_maintenance(self, maint, forest, breaker=None,
                           registry=None) -> None:
        """Attach a host-side maintenance engine over the bank backing
        the attached state — which must have just been staged from that
        bank (the engine's restage shadow initializes to its content).
        ``breaker`` overrides the coordinator's fault-domain circuit
        breaker (tests pass one with a tight threshold/cooldown);
        ``registry`` (a :class:`~repro.core.bank.TenantRegistry`) makes
        the fault domain per-tenant — see :meth:`attach_tenants`.  The
        fault-injection hook is wired here so ``repro.core`` never
        imports the serving layer."""
        from .faultinject import fault_point
        self.maint = maint
        self.coord = RestageCoordinator(maint, forest, breaker=breaker,
                                        fault_hook=fault_point,
                                        registry=registry)
        if registry is not None:
            self.tenants = registry

    def attach_tenants(self, registry) -> None:
        """Attach (or swap in) a :class:`~repro.core.bank.TenantRegistry`
        over the already-attached bank: tenant quotas, per-tenant
        maintenance fault domains, and the evict/reload/onboard lifecycle
        all key off it."""
        self.tenants = registry
        if self.coord is not None:
            self.coord.registry = registry

    def configure_snapshots(self, writer) -> None:
        """Attach a :class:`repro.core.snapshot.SnapshotWriter`: every
        applied maintenance commit ticks it, so snapshots land exactly
        when bank and device state are in sync."""
        self.snapshots = writer

    # ---------------------------------------------------------- hot path
    def pad_queries(self, tree_ids: Sequence[int], hashes: Sequence[int],
                    pad_to: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array, int]:
        """Pad a query batch to a shape-stable geometry; returns
        ``(hashes, tree_ids, true_length)``.  Default policy rounds up to
        a multiple of ``batch_pad``; a caller-picked ``pad_to`` (the
        async engine's pow2 buckets) overrides it.  Pad slots query tree
        0 with hash 0; a pad hash can in principle alias a stored
        fingerprint, which only over-bumps that slot's temperature — a
        heuristic, not a correctness input."""
        b = len(hashes)
        bp = pad_to if pad_to is not None else \
            max(self.batch_pad, -(-b // self.batch_pad) * self.batch_pad)
        if bp < b:
            raise ValueError(f"pad_to {bp} < batch {b}")
        tid = np.zeros((bp,), np.int32)
        tid[:b] = np.asarray(tree_ids, np.int32)
        hh = np.zeros((bp,), np.uint32)
        hh[:b] = np.asarray(hashes, np.uint32)
        return jnp.asarray(hh), jnp.asarray(tid), b

    def retrieve_dispatch(self, hh: jax.Array, tid: jax.Array):
        """Dispatch one already-padded retrieval step and thread the
        bumped temperature into the live state.  Returns the raw padded
        result *without* blocking — the arrays are in flight, so host
        maintenance can run under the batch before the caller touches
        them."""
        if self.state is None:
            raise RuntimeError("attach a retrieval state first")
        out = self._step(self.state, hh, tid)
        self.state = self.state.with_temperature(out.temperature)
        return out

    def harvest(self) -> int:
        """Close the paper's feedback loop: absorb this batch's bumps
        into the host bank (drives the idle-sort trigger policy).  While
        a restage is staged-but-uncommitted — or a background prepare
        holds the lifecycle lock — the harvest is skipped; bumps stay on
        device and the first post-commit batch harvests them."""
        if self.coord is None:
            return 0
        return self.coord.absorb(self.state)

    def retrieve(self, tree_ids: Sequence[int],
                 hashes: Sequence[int]) -> DeviceRetrieval:
        """Serve one ``(tree_id, hash)`` query batch synchronously: pad,
        dispatch, harvest, slice back to the true batch."""
        with self.tracer.span("serve.retrieve",
                              queries=len(hashes)) as sp:
            with sp.stage("pad"):
                hh, tid, b = self.pad_queries(tree_ids, hashes)
            with sp.stage("dispatch"):
                out = self.retrieve_dispatch(hh, tid)
            with sp.stage("harvest"):
                self.harvest()
        return DeviceRetrieval(hit=out.hit[:b], locations=out.locations[:b],
                               up=out.up[:b], down=out.down[:b],
                               temperature=out.temperature)

    def compile_cache_size(self) -> int:
        """Number of compiled geometries the jitted step holds (-1 when
        the backend does not expose it) — the async tests pin this to the
        bucket count to prove the hot path never recompiles.  Refreshes
        the ``serve.compile_cache_size`` gauge as a side effect."""
        size = getattr(self._watched_step, "_cache_size", None)
        n = int(size()) if callable(size) else -1
        self.metrics.gauge("serve.compile_cache_size",
                           "compiled geometries held by the serve step"
                           ).set(n)
        return n

    def observe(self) -> dict:
        """Post-batch observability tick: refresh the compile-cache
        gauge and let the sentinel attribute any new hot-path
        compilations (raising when armed).  Cheap — two cache-size
        reads — so schedulers call it every batch."""
        self.compile_cache_size()
        return self.sentinel.check()

    # -------------------------------------------------------- maintenance
    def prepare_maintenance(self, state=None, now=None,
                            force: bool = False
                            ) -> Optional[MaintenanceReport]:
        """Phase one of the zero-pause restage: run the host-side
        maintenance pass (absorb → delta → compact → shrink → sort) and
        stage the restage plan's payload — only the changed bytes.

        Everything here is host work plus async device_put dispatch, so
        it overlaps with an in-flight serve batch: issue the next batch,
        call this, then :meth:`commit_maintenance` once the batch is
        consumed.  The old state keeps serving untouched until commit.
        An uncommitted previous plan is committed first (plans do not
        stack).  ``state`` overrides the absorb target — a scheduler
        passes the pre-dispatch snapshot so the pass never blocks on the
        in-flight batch's temperature."""
        if self.maint is None:
            return None
        self.commit_maintenance()
        return self.coord.prepare(self.state if state is None else state,
                                  now=now, force=force)

    def commit_maintenance(self, blocking: bool = True,
                           now: Optional[float] = None) -> bool:
        """Phase two: the O(changed-bytes) device splice + atomic state
        swap.  Returns True when a staged plan was applied.  The splice
        donates the old state's arena buffers — the swapped-out state must
        not be probed again (on backends without donation this is merely
        a copy).  A splice failure quarantines the plan and re-raises;
        ``self.state`` is untouched (the fault fires before donation), so
        the session keeps serving the last committed content."""
        if self.coord is None:
            return False
        pending = self.coord.pending
        kind = getattr(pending, "kind", None)
        before = state_shapes(self.state) if pending is not None else None
        self.state, applied = self.coord.commit(self.state,
                                                blocking=blocking, now=now)
        if applied and before is not None:
            # shape-stability tripwire: a delta/none commit must never
            # change a committed array shape (PR 6's recompile bug)
            self.sentinel.note_commit(kind, before,
                                      state_shapes(self.state))
        if applied and self.snapshots is not None:
            # bank == device right here; the writer decides cadence and
            # swallows write failures (serving outlives a bad disk)
            self.snapshots.note_commit(self.state, self.maint)
        return applied

    def maintain(self) -> Optional[MaintenanceReport]:
        """Idle-time maintenance hook (between serving batches) — the
        single-call wrapper over :meth:`prepare_maintenance` +
        :meth:`commit_maintenance`.

        With a maintenance engine attached: one ``maintain`` pass on the
        host bank, then splice-commit the changed bytes into the device
        state (host stays the source of truth so slot layouts never
        diverge; a compaction falls back to the full restage).  Without
        one: a pure device-side idle sort (``sort_buckets_arena``) — hot
        fingerprints bubble to slot 0 using temperature alone."""
        if self.maint is not None:
            report = self.prepare_maintenance()
            self.commit_maintenance()
            return report
        if self.state is not None:
            self.state = self.state.sort_idle()
        return None

    def pending_mutations(self) -> int:
        """Queued-but-unapplied insert/delete count across the attached
        engine('s shards) — the async scheduler's prepare trigger."""
        if self.maint is None:
            return 0
        engines = getattr(self.maint, "engines", None)
        if engines is None:
            engines = [self.maint]
        return sum(len(e.delta) for e in engines)

    # ----------------------------------------------- tenant lifecycle
    def _tenant_registry(self):
        if self.tenants is None:
            raise RuntimeError("attach a TenantRegistry first "
                               "(attach_tenants)")
        if self.maint is None:
            raise RuntimeError("tenant lifecycle needs an attached "
                               "maintenance engine")
        return self.tenants

    def _host_bank(self):
        """The host bank the registry operates on — the sharded bank for
        a sharded engine, the flat one otherwise."""
        sb = getattr(self.maint, "sbank", None)
        return sb if sb is not None else self.maint.bank

    def _tenant_restage(self, lo: int, hi: int, pinned: bool) -> None:
        """Finish a registry surgery: set the tenant's pin state, then
        force a prepare/commit cycle so the surgically edited bank
        restages onto device (``force`` because the bank's arena geometry
        already disagrees with the device's — a plain absorb would
        raise)."""
        self.maint.pin_tree_range(lo, hi, pinned)
        self.prepare_maintenance(force=True)
        self.commit_maintenance()

    def evict_tenant(self, name: str):
        """Evict ``name`` to host under arena memory pressure: flush the
        pending maintenance cycle (bank == device), copy the tenant's
        arena rows into a :class:`~repro.core.bank.ColdTenant`, blank its
        tree range in place, pin it (cold rows reference live CSR ids —
        compaction/rebuild must not renumber them), and splice the
        blanked segments onto device.  Queries against its trees miss
        safely; the admission path sheds them with
        :class:`~repro.serving.errors.TenantEvicted` instead.  The
        ``evict`` fault site fires before the surgery — an injected
        fault leaves bank and device exactly as served."""
        from .faultinject import fault_point
        reg = self._tenant_registry()
        self.maintain()                    # bank == device for the copy
        fault_point("evict")
        cold = reg.evict(self._host_bank(), name)
        self._tenant_restage(cold.lo, cold.hi, pinned=True)
        self.metrics.counter(
            "tenant.evictions",
            "cold-tenant evictions to host").inc(tenant=name)
        return cold

    def reload_tenant(self, name: str, cold=None) -> None:
        """Splice an evicted tenant back in — the exact inverse of
        :meth:`evict_tenant`, bit-exact because eviction never mutates
        the cold copy or its CSR rows (the pin guarantees the ids still
        resolve).  ``cold`` overrides the registry's retained copy (the
        snapshot-restore path)."""
        from .faultinject import fault_point
        reg = self._tenant_registry()
        self.maintain()
        fault_point("reload")
        reg.reload(self._host_bank(), name, cold)
        lo, hi = reg.trees(name)
        self._tenant_restage(lo, hi, pinned=False)
        self.metrics.counter(
            "tenant.reloads",
            "cold-tenant reloads from host").inc(tenant=name)

    def offboard_tenant(self, name: str):
        """Live offboarding: evict ``name`` and drop it from the
        registry's residency — its trees stay as pinned empty segments
        (the range is reusable via :meth:`onboard_tenant`).  Returns the
        :class:`ColdTenant` so the caller can persist it
        (``save_tenant``)."""
        from .faultinject import fault_point
        reg = self._tenant_registry()
        self.maintain()
        fault_point("evict")
        cold = reg.offboard(self._host_bank(), name)
        self._tenant_restage(cold.lo, cold.hi, pinned=True)
        self.metrics.counter(
            "tenant.offboards", "tenants offboarded live").inc(tenant=name)
        return cold

    def onboard_tenant(self, name: str, cold) -> None:
        """Live onboarding into an offboarded range: splice ``cold``'s
        trees (typically from :func:`~repro.core.snapshot.load_tenant`)
        into the blank range and restage — no restart, no full
        rebuild."""
        from .faultinject import fault_point
        reg = self._tenant_registry()
        self.maintain()
        fault_point("onboard")
        reg.onboard(self._host_bank(), name, cold)
        lo, hi = reg.trees(name)
        self._tenant_restage(lo, hi, pinned=False)
        self.metrics.counter(
            "tenant.onboards", "tenants onboarded live").inc(tenant=name)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, cache_size: int = 512,
                 batch_size: int = 4):
        self.cfg = cfg
        self.params = params
        self.cache_size = cache_size
        self.batch_size = batch_size

        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg, cache_size=cache_size))
        self._decode = jax.jit(
            functools.partial(lm.decode_step, cfg), donate_argnums=(2,))
        self.retrieval = RetrievalSession()

    # engine-internal views of the session (kept for callers that poke
    # the state directly, e.g. the benches' equivalence gates)
    @property
    def _ret_state(self):
        return self.retrieval.state

    @property
    def _maint(self):
        return self.retrieval.maint

    @property
    def _coord(self):
        return self.retrieval.coord

    # ---------------------------------------------------------- retrieval
    def attach_retrieval(self, state, lookup_fn=None,
                         max_locs: int = 4, n: int = 3,
                         batch_pad: int = 64, fused: bool = False) -> None:
        """Fuse CFT retrieval into the engine — see
        :meth:`RetrievalSession.attach`."""
        self.retrieval.attach(state, lookup_fn=lookup_fn,
                              max_locs=max_locs, n=n, batch_pad=batch_pad,
                              fused=fused)

    def retrieve(self, tree_ids: Sequence[int],
                 hashes: Sequence[int]) -> DeviceRetrieval:
        """Serve one ``(tree_id, hash)`` query batch (padded to a
        multiple of ``batch_pad`` — one compilation per geometry, like
        the token scheduler)."""
        return self.retrieval.retrieve(tree_ids, hashes)

    # -------------------------------------------------------- maintenance
    def attach_maintenance(self, maint, forest) -> None:
        """Attach a host-side maintenance engine (``MaintenanceEngine`` or
        ``ShardedMaintenanceEngine``) over the bank backing the attached
        retrieval state — which must have just been staged from that bank
        (the engine's restage shadow is initialized to its content).
        ``retrieve`` then harvests temperature after every query batch,
        and :meth:`maintain` (called between batches, or by ``serve``
        automatically) applies queued insert/delete deltas, compacts,
        resorts, and splice-commits the device state whenever the bank
        mutated."""
        self.retrieval.attach_maintenance(maint, forest)

    def prepare_maintenance(self) -> Optional[MaintenanceReport]:
        """Phase one of the zero-pause restage (host maintenance pass +
        payload staging, overlappable with an in-flight batch) — see
        :meth:`RetrievalSession.prepare_maintenance`."""
        return self.retrieval.prepare_maintenance()

    def commit_maintenance(self) -> bool:
        """Phase two: O(changed-bytes) splice + atomic swap — see
        :meth:`RetrievalSession.commit_maintenance`."""
        return self.retrieval.commit_maintenance()

    def maintain(self) -> Optional[MaintenanceReport]:
        """Idle-time maintenance hook (between serving batches) — see
        :meth:`RetrievalSession.maintain`."""
        return self.retrieval.maintain()

    # ----------------------------------------------------------- generate
    def generate(self, batch: Dict[str, jax.Array], max_new_tokens: int
                 ) -> np.ndarray:
        """Greedy generation. batch['tokens']: (B, S) prompt ids."""
        logits, state = self._prefill(self.params, batch)
        tok = lm.greedy_token(logits)
        out = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tok, state)
            tok = lm.greedy_token(logits)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)            # (B, new)

    # ---------------------------------------------------------- scheduler
    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Continuous-lite: group requests into fixed batches, pad, run."""
        pending = list(requests)
        done: List[Request] = []
        while pending:
            group = pending[:self.batch_size]
            pending = pending[self.batch_size:]
            max_new = max(r.max_new_tokens for r in group)
            # context-window truncation: keep the prompt tail (query end)
            budget = self.cache_size - max_new
            for r in group:
                if len(r.prompt_ids) > budget:
                    r.prompt_ids = r.prompt_ids[-budget:]
            max_len = max(len(r.prompt_ids) for r in group)
            toks = np.full((self.batch_size, max_len), HashTokenizer.PAD,
                           np.int32)
            for i, r in enumerate(group):     # left-pad to align last token
                toks[i, max_len - len(r.prompt_ids):] = r.prompt_ids
            out = self.generate({"tokens": jnp.asarray(toks)}, max_new)
            for i, r in enumerate(group):
                r.out_ids = out[i, :r.max_new_tokens].tolist()
                done.append(r)
            if self._maint is not None:
                self.maintain()    # idle window between batches: apply
                #                    pending deltas, resort, restage
        return done


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_size: int) -> int:
    """Sizing helper (used by roofline + admission control)."""
    hd = cfg.resolved_head_dim
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    if cfg.family == "rwkv":
        return cfg.n_layers * batch * cfg.n_heads * hd * hd * 4
    layers = cfg.n_layers if cfg.family != "mamba_hybrid" \
        else cfg.n_layers // max(cfg.attn_every, 1)
    return 2 * layers * batch * cfg.n_kv_heads * cache_size * hd * bpe
