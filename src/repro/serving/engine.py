"""Serving engine: jitted prefill + decode loop with a continuous-lite
batch scheduler.

The decode step donates the cache/state buffers (no double-buffered KV), and
greedy sampling runs on device.  The scheduler packs pending requests into
fixed-size batches (padding short prompts) — the "continuous-lite" policy:
new requests join at the next batch boundary rather than mid-flight, which
keeps the step function shape-stable (one compilation per batch geometry).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..data.tokenizer import HashTokenizer
from ..models import lm


@dataclasses.dataclass
class Request:
    prompt_ids: List[int]
    max_new_tokens: int = 16
    out_ids: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, cache_size: int = 512,
                 batch_size: int = 4):
        self.cfg = cfg
        self.params = params
        self.cache_size = cache_size
        self.batch_size = batch_size

        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg, cache_size=cache_size))
        self._decode = jax.jit(
            functools.partial(lm.decode_step, cfg), donate_argnums=(2,))

    # ----------------------------------------------------------- generate
    def generate(self, batch: Dict[str, jax.Array], max_new_tokens: int
                 ) -> np.ndarray:
        """Greedy generation. batch['tokens']: (B, S) prompt ids."""
        logits, state = self._prefill(self.params, batch)
        tok = lm.greedy_token(logits)
        out = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(self.params, tok, state)
            tok = lm.greedy_token(logits)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)            # (B, new)

    # ---------------------------------------------------------- scheduler
    def serve(self, requests: Sequence[Request]) -> List[Request]:
        """Continuous-lite: group requests into fixed batches, pad, run."""
        pending = list(requests)
        done: List[Request] = []
        while pending:
            group = pending[:self.batch_size]
            pending = pending[self.batch_size:]
            max_new = max(r.max_new_tokens for r in group)
            # context-window truncation: keep the prompt tail (query end)
            budget = self.cache_size - max_new
            for r in group:
                if len(r.prompt_ids) > budget:
                    r.prompt_ids = r.prompt_ids[-budget:]
            max_len = max(len(r.prompt_ids) for r in group)
            toks = np.full((self.batch_size, max_len), HashTokenizer.PAD,
                           np.int32)
            for i, r in enumerate(group):     # left-pad to align last token
                toks[i, max_len - len(r.prompt_ids):] = r.prompt_ids
            out = self.generate({"tokens": jnp.asarray(toks)}, max_new)
            for i, r in enumerate(group):
                r.out_ids = out[i, :r.max_new_tokens].tolist()
                done.append(r)
        return done


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_size: int) -> int:
    """Sizing helper (used by roofline + admission control)."""
    hd = cfg.resolved_head_dim
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    if cfg.family == "rwkv":
        return cfg.n_layers * batch * cfg.n_heads * hd * hd * 4
    layers = cfg.n_layers if cfg.family != "mamba_hybrid" \
        else cfg.n_layers // max(cfg.attn_every, 1)
    return 2 * layers * batch * cfg.n_kv_heads * cache_size * hd * bpe
