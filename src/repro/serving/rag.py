"""End-to-end CFT-RAG serving pipeline (paper Figure 1).

query -> entity recognition (NER stub) -> cuckoo-filter lookup -> block-list
walk -> hierarchical context (Algorithm 3) -> prompt assembly
[system | context | query] -> generator prefill+decode.

Two retrieval paths:
* host path — CFTRAG (temperature bump + idle-time bucket sort between
  rounds), used by benchmarks and the default pipeline;
* device path — ``retrieve_device`` with the Pallas lookup kernel, fusing
  retrieval into the jitted serving step (TPU deployment shape).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import (CFTRAG, CFTDeviceState, build_forest, build_index,
                    retrieve_device)
from ..core import hashing
from ..data.datasets import SyntheticCorpus
from ..data.ner import build_gazetteer, recognize_entities
from ..data.tokenizer import HashTokenizer
from ..kernels.cuckoo_lookup.ops import cuckoo_lookup_auto
from .engine import Request, ServeEngine

SYSTEM_PROMPT = ("You are an assistant answering questions about an "
                 "organization using its entity hierarchy.")


@dataclasses.dataclass
class RAGAnswer:
    query: str
    entities: List[str]
    context: str
    prompt: str
    output_ids: Optional[List[int]] = None
    text: Optional[str] = None


class RAGPipeline:
    def __init__(self, corpus: SyntheticCorpus, engine: Optional[ServeEngine],
                 tokenizer: Optional[HashTokenizer] = None,
                 num_buckets: int = 1024, n_hierarchy: int = 3,
                 use_device_lookup: bool = False):
        self.corpus = corpus
        self.forest = build_forest(corpus.trees)
        self.index = build_index(self.forest, num_buckets=num_buckets)
        self.retriever = CFTRAG(self.index, n_hierarchy=n_hierarchy)
        self.gazetteer = build_gazetteer(self.forest.entity_names)
        self.engine = engine
        self.tokenizer = tokenizer or HashTokenizer(
            engine.cfg.vocab if engine else 64000)
        self.use_device_lookup = use_device_lookup
        self._dev_state = (CFTDeviceState.from_index(self.index)
                           if use_device_lookup else None)

    # ---------------------------------------------------------- retrieval
    def retrieve(self, query: str) -> RAGAnswer:
        ents = recognize_entities(query, self.gazetteer)
        if self.use_device_lookup:
            hashes = jnp.asarray(hashing.hash_entities(ents)
                                 if ents else np.zeros((1,), np.uint32))
            out = retrieve_device(self._dev_state, hashes,
                                  lookup_fn=lambda f, h, q:
                                  cuckoo_lookup_auto(f, h, q))
            self._dev_state = dataclasses.replace(
                self._dev_state, temperature=out.temperature)
            ctxs = self._render_device(ents, out)
        else:
            ctxs = self.retriever.render(self.retriever.retrieve(ents))
        prompt = f"{SYSTEM_PROMPT}\n{ctxs}\nQuestion: {query}\nAnswer:"
        return RAGAnswer(query=query, entities=ents, context=ctxs,
                         prompt=prompt)

    def _render_device(self, ents: Sequence[str], out) -> str:
        lines = []
        names = self.forest.entity_names
        for i, e in enumerate(ents):
            ups = [names[int(u)] for u in np.asarray(out.up[i]).ravel()
                   if int(u) >= 0]
            downs = [names[int(d)] for d in np.asarray(out.down[i]).ravel()
                     if int(d) >= 0]
            if ups:
                lines.append(f"The upward hierarchical relationship of {e} "
                             f"are: {', '.join(dict.fromkeys(ups))}.")
            if downs:
                lines.append(f"The downward hierarchical relationship of {e} "
                             f"are: {', '.join(dict.fromkeys(downs))}.")
        return "\n".join(lines)

    # ----------------------------------------------------------- generate
    def answer(self, query: str, max_new_tokens: int = 16) -> RAGAnswer:
        ans = self.retrieve(query)
        if self.engine is None:
            return ans
        ids = self.tokenizer.encode(ans.prompt, bos=True)
        req = Request(prompt_ids=ids, max_new_tokens=max_new_tokens)
        self.engine.serve([req])
        ans.output_ids = req.out_ids
        ans.text = self.tokenizer.decode(req.out_ids)
        return ans

    # --------------------------------------------------- retrieval metrics
    def retrieval_accuracy(self, queries: Sequence[str],
                           gold_entities: Sequence[Sequence[str]]) -> float:
        """Fraction of gold entities whose retrieved locations match a naive
        BFS exactly (the DESIGN.md §7 accuracy proxy)."""
        from ..core import NaiveTRAG
        naive = NaiveTRAG(self.forest)
        total, correct = 0, 0
        for q, gold in zip(queries, gold_entities):
            for e in gold:
                total += 1
                if sorted(self.retriever.locate(e)) == sorted(naive.locate(e)):
                    correct += 1
        return correct / max(total, 1)
