"""End-to-end CFT-RAG serving pipeline (paper Figure 1).

query -> entity recognition (NER stub) -> cuckoo-filter lookup -> block-list
walk -> hierarchical context (Algorithm 3) -> prompt assembly
[system | context | query] -> generator prefill+decode.

Two retrieval paths:
* host path — CFTRAG (temperature bump + idle-time bucket sort between
  rounds), used by benchmarks and the default pipeline;
* device path — ``retrieve_device`` with the Pallas lookup kernel, fusing
  retrieval into the jitted serving step (TPU deployment shape).
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import (CFTRAG, CFTDeviceState, MaintenanceEngine,
                    ShardedBankState, ShardedMaintenanceEngine, build_bank,
                    build_forest, build_index, retrieve_device,
                    sharded_retrieve_device, stage_sharded_bank)
from ..core import hashing
from ..data.datasets import SyntheticCorpus
from ..data.ner import (add_to_gazetteer, build_gazetteer,
                        recognize_entities)
from ..data.tokenizer import HashTokenizer
from ..kernels.cuckoo_lookup.ops import cuckoo_lookup_arena_auto
from .async_engine import AsyncServeEngine
from .engine import Request, RetrievalSession, ServeEngine

SYSTEM_PROMPT = ("You are an assistant answering questions about an "
                 "organization using its entity hierarchy.")


@dataclasses.dataclass
class RAGAnswer:
    query: str
    entities: List[str]
    context: str
    prompt: str
    output_ids: Optional[List[int]] = None
    text: Optional[str] = None


class RAGPipeline:
    def __init__(self, corpus: SyntheticCorpus, engine: Optional[ServeEngine],
                 tokenizer: Optional[HashTokenizer] = None,
                 num_buckets: int = 1024, n_hierarchy: int = 3,
                 use_device_lookup: bool = False, use_bank: bool = False,
                 mesh=None, mesh_axis: str = "model",
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 1, snapshot_keep: int = 3,
                 tenants=None):
        self.corpus = corpus
        self.forest = build_forest(corpus.trees)
        self.index = build_index(self.forest, num_buckets=num_buckets)
        self.retriever = CFTRAG(self.index, n_hierarchy=n_hierarchy)
        self.gazetteer = build_gazetteer(self.forest.entity_names)
        self.engine = engine
        self.tokenizer = tokenizer or HashTokenizer(
            engine.cfg.vocab if engine else 64000)
        self.use_device_lookup = use_device_lookup or use_bank
        self.use_bank = use_bank
        self._mesh, self._mesh_axis = mesh, mesh_axis
        self.bank = build_bank(self.forest) if use_bank else None
        # the session owns the device state and the two-phase restage
        # lifecycle; the pipeline's `_dev_state`/`_coord` are views on it
        self.session = RetrievalSession()
        self._gen_lock = threading.Lock()
        # crash recovery: a compatible snapshot under snapshot_dir
        # replaces the fresh bank/state build — bit-identical to what was
        # serving when the snapshot was taken (corrupt or layout-
        # incompatible snapshots fall back to a fresh build)
        self.snapshot_dir = snapshot_dir
        self.restored_step: Optional[int] = None
        if snapshot_dir:
            # startup sweep: a crash (or injected fault) mid-snapshot
            # leaves a tmp.* dir behind — sweep it here so restarts never
            # accumulate leaked disk (keep_last <= 0 means "keep all
            # snapshots", so the sweep then only removes tmp dirs)
            from ..core.snapshot import cleanup_snapshots, list_snapshots
            keep = snapshot_keep if snapshot_keep > 0 \
                else max(1, len(list_snapshots(snapshot_dir)))
            cleanup_snapshots(snapshot_dir, keep_last=keep)
        snap = self._load_snapshot() if use_bank and snapshot_dir else None
        if use_bank and mesh is not None:
            from ..core.snapshot import apply_maint_bookkeeping, \
                restore_state
            if snap is not None:
                self.bank = snap.bank
                self.maintenance = ShardedMaintenanceEngine(self.bank)
                apply_maint_bookkeeping(self.maintenance, snap)
                self._dev_state = restore_state(snap, mesh=mesh,
                                                axis=mesh_axis)
                self.restored_step = snap.step
            else:
                # bank-axis sharded deployment: tree ranges partitioned
                # over the mesh axis, shard-local maintenance,
                # all-to-all routing
                self.bank = self.bank.shard(int(mesh.shape[mesh_axis]))
                self.maintenance = ShardedMaintenanceEngine(self.bank)
                self._dev_state = stage_sharded_bank(self.bank, self.forest,
                                                     mesh, mesh_axis)
        elif use_bank:
            from ..core.snapshot import apply_maint_bookkeeping, \
                restore_state
            if snap is not None:
                self.bank = snap.bank
                self.maintenance = MaintenanceEngine(self.bank)
                apply_maint_bookkeeping(self.maintenance, snap)
                self._dev_state = restore_state(snap)
                self.restored_step = snap.step
            else:
                self.maintenance = MaintenanceEngine(self.bank)
                # NB: the pipeline owns its device state, so it runs its
                # own idle-time hook (maintain() below) rather than
                # attaching the engine's — two restage owners over one
                # bank would let host and device slot layouts diverge.
                self._dev_state = CFTDeviceState.from_bank(self.bank,
                                                           self.forest)
        elif use_device_lookup:
            self.maintenance = None
            self._dev_state = CFTDeviceState.from_index(self.index)
        else:
            self.maintenance = None
            self._dev_state = None
        if self._dev_state is not None:
            # builds the padded jitted step (used by the async engine);
            # the inline `retrieve` below keeps its own exact-shape calls
            self.session.attach(self._dev_state,
                                lookup_fn=cuckoo_lookup_arena_auto)
        if self.maintenance is not None:
            self.session.attach_maintenance(self.maintenance, self.forest)
        if tenants is not None:
            # tenant -> tree-range registry: quotas, per-tenant fault
            # domains, and the evict/reload lifecycle key off it
            from ..core.bank import TenantRegistry
            reg = tenants if isinstance(tenants, TenantRegistry) \
                else TenantRegistry(tenants)
            self.session.attach_tenants(reg)
        self.tenants = self.session.tenants
        if self.maintenance is not None and snapshot_dir is not None \
                and snapshot_every > 0:
            from ..core.snapshot import SnapshotWriter
            from .faultinject import fault_point
            self.session.configure_snapshots(SnapshotWriter(
                snapshot_dir, every=snapshot_every, keep_last=snapshot_keep,
                fault_hook=fault_point))

    def _load_snapshot(self):
        """Latest snapshot under ``snapshot_dir`` if it matches this
        pipeline's deployment layout (flat vs sharded, shard count ==
        mesh axis size); ``None`` — fresh build — otherwise, including
        on a corrupt snapshot (crash recovery must never crash)."""
        from ..core import ShardedBank
        from ..core.snapshot import latest_snapshot, restore_snapshot
        try:
            if latest_snapshot(self.snapshot_dir) is None:
                return None
            snap = restore_snapshot(self.snapshot_dir)
        except Exception:
            return None
        sharded = isinstance(snap.bank, ShardedBank)
        if sharded != (self._mesh is not None):
            return None
        if sharded and snap.bank.num_shards != int(
                self._mesh.shape[self._mesh_axis]):
            return None
        if not snap.state_leaves or not snap.row_alive:
            return None
        return snap

    # device state + restage lifecycle live on the session; keep the
    # historical attribute names as views so callers (and tests) that
    # poke `rag._dev_state` / `rag._coord` see the single source of truth
    @property
    def _dev_state(self):
        return self.session.state

    @_dev_state.setter
    def _dev_state(self, state) -> None:
        self.session.state = state

    @property
    def _coord(self):
        return self.session.coord

    # ---------------------------------------------------------- retrieval
    def retrieve(self, query: str,
                 tree_scope: Optional[int] = None) -> RAGAnswer:
        """Recognize entities and retrieve their hierarchical context.

        ``tree_scope`` routes the whole query batch to one tree of the
        filter bank (multi-tenant shape); ``None`` retrieves globally —
        on a bank state that fans each entity out to every tree.
        """
        ents = recognize_entities(query, self.gazetteer)
        if self.use_device_lookup:
            trees_np, hashes_np, b = self._device_query_batch(ents,
                                                              tree_scope)
            hashes = jnp.asarray(hashes_np)
            trees = jnp.asarray(trees_np)
            if isinstance(self._dev_state, ShardedBankState):
                # the Pallas arena probe routes per query (segment start +
                # bucket mask), so it works unchanged after tree-local
                # expansions diverge per-tree bucket counts
                out = sharded_retrieve_device(
                    self._dev_state, hashes, trees,
                    lookup_fn=cuckoo_lookup_arena_auto)
            else:
                out = retrieve_device(self._dev_state, hashes, trees,
                                      lookup_fn=cuckoo_lookup_arena_auto)
            self._dev_state = self._dev_state.with_temperature(
                out.temperature)
            # harvest defers while a restage is staged-but-uncommitted
            # (the bank may already carry the next geometry)
            self.session.harvest()
            up, down = self._merge_bank_updown(np.asarray(out.up),
                                               np.asarray(out.down),
                                               b, tree_scope)
            ctxs = self._render_device(ents, up, down)
        else:
            ctxs = self.retriever.render(self.retriever.retrieve(ents))
        prompt = f"{SYSTEM_PROMPT}\n{ctxs}\nQuestion: {query}\nAnswer:"
        return RAGAnswer(query=query, entities=ents, context=ctxs,
                         prompt=prompt)

    def _device_query_batch(self, ents: Sequence[str],
                            tree_scope: Optional[int] = None):
        """Map recognized entities to the ``(tree_ids, hashes)`` batch the
        device step consumes.  ``tree_scope`` routes everything to one
        tree; bank mode with no scope fans each entity out to every tree
        (per-entity results merge back in :meth:`_merge_bank_updown`)."""
        hashes = np.asarray(hashing.hash_entities(ents) if ents
                            else np.zeros((1,), np.uint32))
        b = hashes.shape[0]
        if tree_scope is not None:
            trees = np.full((b,), tree_scope, np.int32)
        elif self.use_bank:
            # global query over a bank: (tree_id, hash) pairs for every
            # tree; per-entity results merge across trees afterwards
            t = self.bank.num_trees
            trees = np.repeat(np.arange(t, dtype=np.int32), b)
            hashes = np.tile(hashes, t)
        else:
            trees = np.zeros((b,), np.int32)
        return trees, hashes, b

    def _merge_bank_updown(self, up: np.ndarray, down: np.ndarray, b: int,
                           tree_scope: Optional[int]):
        """Fold the per-tree fan-out back to per-entity rows: the
        ``(t*b, locs, n)`` device result regroups as ``(b, t*locs, n)``."""
        if tree_scope is None and self.use_bank:
            t, locs, n = self.bank.num_trees, up.shape[1], up.shape[2]
            up = (up.reshape(t, b, locs, n).transpose(1, 0, 2, 3)
                    .reshape(b, t * locs, n))
            down = (down.reshape(t, b, locs, n).transpose(1, 0, 2, 3)
                      .reshape(b, t * locs, n))
        return up, down

    # -------------------------------------------------------- maintenance
    def insert_entity(self, tree: int, name: str,
                      nodes: Sequence[int]) -> None:
        """Queue a live (tree, entity) insert; applied at the next
        :meth:`maintain` idle window (bank mode only).  ``nodes`` are
        existing forest node ids the entity should resolve to.  The NER
        gazetteer learns the name immediately so queries can mention it
        as soon as the delta lands."""
        if self.maintenance is None:
            raise RuntimeError("dynamic updates need use_bank=True")
        eid = self.forest.name_to_id.get(name, -1)
        self.maintenance.queue_insert(tree, name, nodes, entity_id=eid)
        add_to_gazetteer(self.gazetteer, name)

    def delete_entity(self, tree: int, name: str) -> None:
        if self.maintenance is None:
            raise RuntimeError("dynamic updates need use_bank=True")
        self.maintenance.queue_delete(tree, name)

    def prepare_maintenance(self):
        """Phase one of the zero-pause restage: host-side maintenance pass
        + staging of only the changed bytes (overlappable with in-flight
        retrieval on the still-serving old state).  Commits any previous
        uncommitted plan first; returns the MaintenanceReport (None in
        non-bank mode)."""
        return self.session.prepare_maintenance()

    def commit_maintenance(self) -> bool:
        """Phase two: O(changed-bytes) device splice + atomic swap of the
        retrieval state.  Returns True when a staged plan was applied."""
        return self.session.commit_maintenance()

    def maintain(self):
        """Idle-time maintenance: apply queued inserts/deletes, compact,
        shrink, resort hot buckets, and splice-commit the device state if
        the bank mutated (``prepare_maintenance`` + ``commit_maintenance``
        in one call).  Returns the MaintenanceReport (None in non-bank
        mode)."""
        report = self.prepare_maintenance()
        self.commit_maintenance()
        return report

    def _render_device(self, ents: Sequence[str], up_arr: np.ndarray,
                       down_arr: np.ndarray) -> str:
        lines = []
        names = self.forest.entity_names
        for i, e in enumerate(ents):
            ups = [names[int(u)] for u in up_arr[i].ravel() if int(u) >= 0]
            downs = [names[int(d)] for d in down_arr[i].ravel()
                     if int(d) >= 0]
            if ups:
                lines.append(f"The upward hierarchical relationship of {e} "
                             f"are: {', '.join(dict.fromkeys(ups))}.")
            if downs:
                lines.append(f"The downward hierarchical relationship of {e} "
                             f"are: {', '.join(dict.fromkeys(downs))}.")
        return "\n".join(lines)

    # ----------------------------------------------------------- generate
    def answer(self, query: str, max_new_tokens: int = 16) -> RAGAnswer:
        ans = self.retrieve(query)
        if self.engine is None:
            return ans
        ids = self.tokenizer.encode(ans.prompt, bos=True)
        req = Request(prompt_ids=ids, max_new_tokens=max_new_tokens)
        self.engine.serve([req])
        ans.output_ids = req.out_ids
        ans.text = self.tokenizer.decode(req.out_ids)
        self.maintain()        # generation was the idle window
        return ans

    # -------------------------------------------------------------- async
    def async_serving(self, **knobs) -> AsyncServeEngine:
        """Build a continuous-batching front end over this pipeline's
        retrieval session (``latency_budget``, ``max_batch``,
        ``commit_every``, ... forward to :class:`AsyncServeEngine`).
        The returned engine coalesces concurrent :meth:`answer_async`
        retrievals into shared device batches and runs the two-phase
        maintenance lifecycle in the background — do not call
        :meth:`maintain` concurrently with a started engine."""
        if self._dev_state is None:
            raise RuntimeError(
                "async serving needs use_device_lookup or use_bank")
        return AsyncServeEngine(self.session, **knobs)

    async def answer_async(self, query: str, aengine: AsyncServeEngine,
                           max_new_tokens: int = 16,
                           tree_scope: Optional[int] = None) -> RAGAnswer:
        """Async flavor of :meth:`answer`: retrieval rides the shared
        continuous batches of ``aengine`` (built by
        :meth:`async_serving`), generation runs on an executor thread
        serialized by a lock (the decode step donates its buffers, so
        two generations must not interleave).  Maintenance is *not*
        driven here — the async engine's background lifecycle owns it."""
        ents = recognize_entities(query, self.gazetteer)
        trees, hashes, b = self._device_query_batch(ents, tree_scope)
        sl = await aengine.retrieve_async(
            [int(t) for t in trees], [int(h) for h in hashes])
        up, down = self._merge_bank_updown(np.asarray(sl.up),
                                           np.asarray(sl.down),
                                           b, tree_scope)
        ctxs = self._render_device(ents, up, down)
        prompt = f"{SYSTEM_PROMPT}\n{ctxs}\nQuestion: {query}\nAnswer:"
        ans = RAGAnswer(query=query, entities=ents, context=ctxs,
                        prompt=prompt)
        if self.engine is None:
            return ans
        ids = self.tokenizer.encode(ans.prompt, bos=True)
        req = Request(prompt_ids=ids, max_new_tokens=max_new_tokens)

        def _generate() -> None:
            with self._gen_lock:
                self.engine.serve([req])

        await asyncio.get_running_loop().run_in_executor(None, _generate)
        ans.output_ids = req.out_ids
        ans.text = self.tokenizer.decode(req.out_ids)
        return ans

    # --------------------------------------------------- retrieval metrics
    def retrieval_accuracy(self, queries: Sequence[str],
                           gold_entities: Sequence[Sequence[str]]) -> float:
        """Fraction of gold entities whose retrieved locations match a naive
        BFS exactly (the DESIGN.md §7 accuracy proxy)."""
        from ..core import NaiveTRAG
        naive = NaiveTRAG(self.forest)
        total, correct = 0, 0
        for q, gold in zip(queries, gold_entities):
            for e in gold:
                total += 1
                if sorted(self.retriever.locate(e)) == sorted(naive.locate(e)):
                    correct += 1
        return correct / max(total, 1)
