"""Deterministic fault injection for the serving stack.

Chaos tests (and ``benchmarks/bench_faults.py``) need failures that are
*repeatable*: "the second maintenance prepare of the run raises", not
"some prepare eventually raises".  A :class:`FaultPlan` maps named fault
sites to the 0-based invocation ordinals that should raise; production
code calls :func:`fault_point` at each site, which is a no-op (one module
attribute load + ``None`` check) unless a plan is active.

Named sites — the registry is ``FAULT_SITES`` and documented in
CONTRIBUTING.md:

* ``prepare``  — start of ``RestageCoordinator.prepare``, before the
  host maintenance pass mutates the bank;
* ``commit``   — start of ``RestageCoordinator.commit``'s splice, before
  any device buffer is donated;
* ``dispatch`` — in ``AsyncServeEngine._launch``, before the batch
  dispatches on device;
* ``snapshot-write`` — in ``core.snapshot`` after the leaves are written
  but *before* the atomic rename (proves a crashed write never corrupts
  the previous snapshot);
* ``evict`` / ``reload`` / ``onboard`` — in the tenant lifecycle ops of
  ``RetrievalSession``, before the registry mutates the host bank (a
  fault leaves both bank and device state exactly as served).

Core modules never import this one — the serving layer injects
:func:`fault_point` as a ``fault_hook`` callable where core code needs a
site, so ``repro.core`` stays free of serving dependencies.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..obs import get_registry

#: the closed set of named fault sites production code exposes
FAULT_SITES = ("prepare", "commit", "dispatch", "snapshot-write",
               "evict", "reload", "onboard")


class InjectedFault(RuntimeError):
    """Raised by an armed fault site; carries the site name and the
    0-based invocation ordinal that fired."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at site {site!r} "
                         f"(invocation #{ordinal})")
        self.site = site
        self.ordinal = ordinal


class FaultPlan:
    """Deterministic fault schedule: ``{site: ordinals}`` where each
    ordinal is a 0-based invocation index of that site that raises
    :class:`InjectedFault`.  An ``int`` value is shorthand for "the
    first n invocations" (``3`` ≡ ``(0, 1, 2)``).

    Thread-safe: sites fire from the scheduler thread, the prepare
    worker, and test threads concurrently.  ``history`` records every
    injected ``(site, ordinal)`` in firing order; ``calls(site)`` counts
    total invocations (fired or not) so tests can assert coverage.
    """

    def __init__(self, spec: Dict[str, Union[int, Sequence[int]]]):
        self._spec: Dict[str, frozenset] = {}
        for site, ords in spec.items():
            if isinstance(ords, int):
                ords = range(ords)
            self._spec[site] = frozenset(int(o) for o in ords)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.history: List[Tuple[str, int]] = []

    def fire(self, site: str) -> None:
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            hit = n in self._spec.get(site, ())
            if hit:
                self.history.append((site, n))
        if hit:
            get_registry().counter(
                "faults.injected", "injected faults by site").inc(site=site)
            raise InjectedFault(site, n)

    def calls(self, site: str) -> int:
        """Total invocations of ``site`` seen so far (fired or not)."""
        with self._lock:
            return self._counts.get(site, 0)

    def hits(self, site: Optional[str] = None) -> int:
        """Number of faults actually injected (optionally per site)."""
        with self._lock:
            if site is None:
                return len(self.history)
            return sum(1 for s, _ in self.history if s == site)


_active: Optional[FaultPlan] = None


def fault_point(site: str) -> None:
    """Production-code hook: raises per the active plan, else a no-op."""
    plan = _active
    if plan is not None:
        plan.fire(site)


def active_plan() -> Optional[FaultPlan]:
    return _active


@contextmanager
def inject(plan: FaultPlan):
    """Arm ``plan`` for the duration of the block (process-global — one
    plan at a time; chaos tests do not run in parallel)."""
    global _active
    prev = _active
    _active = plan
    try:
        yield plan
    finally:
        _active = prev
