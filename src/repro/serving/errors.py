"""Typed serving-path errors.

Every failure the async engine can hand a caller is a distinct subclass
of ``RuntimeError`` (so pre-existing ``except RuntimeError`` callers keep
working) carrying enough context to act on:

* :class:`EngineOverloaded` — admission control rejected the request
  because the bounded queue is full.  Shed load upstream (back off,
  retry elsewhere); the engine itself never grows the queue unbounded.
* :class:`DeadlineExceeded` — the request's deadline passed before it
  dispatched.  Raised at coalesce or dispatch time, never after device
  work was spent on the request.
* :class:`EngineClosed` — the engine was stopped (or never started);
  the request cannot be served by this engine instance.  Outstanding
  futures at ``stop()`` resolve with this instead of hanging forever.
* :class:`TenantEvicted` — the request's tenant is not resident (cold
  or offboarded); reload/onboard the tenant, or route elsewhere.
"""
from __future__ import annotations

from typing import Optional


class EngineOverloaded(RuntimeError):
    """Admission control rejected a submit: the request queue is full.

    ``tenant`` is set when a per-tenant quota (not the global bound)
    rejected — one tenant's overload sheds only that tenant's traffic."""

    def __init__(self, pending: int, limit: int,
                 tenant: Optional[str] = None):
        scope = f"tenant {tenant!r}" if tenant else "engine"
        super().__init__(
            f"{scope} overloaded: {pending} pending requests at the "
            f"queue bound {limit}")
        self.pending = pending
        self.limit = limit
        self.tenant = tenant


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before it was dispatched."""

    def __init__(self, deadline_t: float, now: float):
        super().__init__(
            f"deadline exceeded: deadline_t={deadline_t:.6f} "
            f"now={now:.6f}")
        self.deadline_t = deadline_t
        self.now = now


class EngineClosed(RuntimeError):
    """The engine is stopped; the request was not (and will not be)
    served by this instance."""

    def __init__(self, msg: str = "engine is stopped"):
        super().__init__(msg)


class TenantEvicted(RuntimeError):
    """The request's tenant is cold (evicted to host) or offboarded —
    its trees are resident as empty segments and every lookup would
    miss, so the submit sheds instead of serving a silent all-miss."""

    def __init__(self, tenant: str):
        super().__init__(f"tenant {tenant!r} is not resident "
                         "(evicted or offboarded)")
        self.tenant = tenant
