"""Typed serving-path errors.

Every failure the async engine can hand a caller is a distinct subclass
of ``RuntimeError`` (so pre-existing ``except RuntimeError`` callers keep
working) carrying enough context to act on:

* :class:`EngineOverloaded` — admission control rejected the request
  because the bounded queue is full.  Shed load upstream (back off,
  retry elsewhere); the engine itself never grows the queue unbounded.
* :class:`DeadlineExceeded` — the request's deadline passed before it
  dispatched.  Raised at coalesce or dispatch time, never after device
  work was spent on the request.
* :class:`EngineClosed` — the engine was stopped (or never started);
  the request cannot be served by this engine instance.  Outstanding
  futures at ``stop()`` resolve with this instead of hanging forever.
"""
from __future__ import annotations


class EngineOverloaded(RuntimeError):
    """Admission control rejected a submit: the request queue is full."""

    def __init__(self, pending: int, limit: int):
        super().__init__(
            f"engine overloaded: {pending} pending requests at the "
            f"queue bound {limit}")
        self.pending = pending
        self.limit = limit


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before it was dispatched."""

    def __init__(self, deadline_t: float, now: float):
        super().__init__(
            f"deadline exceeded: deadline_t={deadline_t:.6f} "
            f"now={now:.6f}")
        self.deadline_t = deadline_t
        self.now = now


class EngineClosed(RuntimeError):
    """The engine is stopped; the request was not (and will not be)
    served by this instance."""

    def __init__(self, msg: str = "engine is stopped"):
        super().__init__(msg)
