"""Async serving engine: continuous batching over the retrieval session.

``ServeEngine`` processes synchronous batches back-to-back; nothing it
reports reflects what a caller sees under load.  ``AsyncServeEngine``
models the real request lifecycle:

1. **submit** — callers enqueue ``(tree_ids, hashes)`` query groups from
   any thread (or via ``retrieve_async`` from an event loop) and get a
   future per request.
2. **coalesce** — a ``MicroBatcher`` collects arrivals until the batch
   is full or the oldest request has waited out the latency budget.
3. **dispatch** — the batch pads to a pow2 bucket (closed shape set, so
   the jitted step never recompiles after warmup) and launches on
   device.
4. **overlap** — while the batch is in flight, the maintenance pass
   (absorb → delta → compact → sort → stage changed bytes) runs on the
   host against the *pre-dispatch* state snapshot; the serving state is
   untouched.
5. **commit** — between batches, under the ``CommitPolicy`` (every N
   batches or plan age past deadline), the staged plan splices into the
   serving state in O(changed bytes).

Retrieval outputs (hit/locations/up/down) depend only on the bank
content, not on temperature or batch grouping, so answers are
bit-identical to the synchronous engine on the same request stream —
the equivalence gate in ``benchmarks/bench_async.py`` checks exactly
that.

Determinism hooks: the constructor takes a ``clock`` (tests inject a
fake), and :meth:`pump` drives one scheduling step inline without any
threads.  ``start()``/``stop()`` run the same logic on a scheduler
thread for real workloads.

Observability: every statistic lives in the process-wide
``repro.obs`` registry (``serve.*`` counters, all mutation under the
registry lock — the old ``AsyncStats`` dataclass was updated from the
scheduler thread, the prepare worker, *and* ``stop()`` without one);
the :attr:`stats` property stays as a compat shim, reconstructing an
``AsyncStats`` view from this engine's registry deltas.  Each launch
emits a ``serve.batch`` trace span (coalesce → pad → dispatch →
prepare → device_lookup → route_back) and ticks the session's
recompile sentinel, so a commit that leaks an unstable shape into the
hot path is counted (and, armed, fatal) rather than a silent ~650 ms
tail spike.

Failure model (see README "Failure model" for the full contract):

* **admission control** — the request queue is bounded
  (``max_queue_requests``); a submit past the bound raises
  :class:`~repro.serving.errors.EngineOverloaded` instead of growing
  the queue (and the tail latency) without limit.
* **tenant isolation** — with a ``TenantRegistry`` attached to the
  session, each tenant gets a queue-share quota (``tenant_quota``,
  default an equal split of ``max_queue_requests``): one tenant's
  burst raises ``EngineOverloaded(tenant=...)`` for *that tenant only*
  while the global bound still protects the engine; the batcher
  coalesces tenant-fair (round-robin across tenants, per-tenant FIFO);
  a cold/offboarded tenant's submits shed with
  :class:`~repro.serving.errors.TenantEvicted`; and every batch span
  carries its tenants so a slow tenant is attributable from the
  metrics snapshot alone.
* **deadlines** — ``submit(..., timeout=s)`` stamps an absolute
  deadline; expired requests fail fast with
  :class:`~repro.serving.errors.DeadlineExceeded` at coalesce time
  (swept from the queue before every launch) and again at dispatch
  time, never occupying a batch slot or device work.
* **dispatch faults** — an exception while serving a batch fails that
  batch's futures and the engine keeps scheduling; it never kills the
  scheduler thread (counted as ``serve.batch_failures``).
* **maintenance faults** — prepare/commit exceptions are quarantined by
  the ``RestageCoordinator`` (plan dropped, shadow invalidated) and the
  engine keeps serving the last committed state; retries follow the
  breaker's backoff schedule and an open breaker degrades to serve-only
  mode (see :class:`~repro.core.maintenance.MaintenanceBreaker`).
* **shutdown** — ``stop()`` drains the queue (every outstanding future
  resolves — with a result, or with the failure that stopped it) and
  any submit afterwards raises
  :class:`~repro.serving.errors.EngineClosed` immediately.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs import HotPathRecompileError
from .engine import RetrievalSession
from .errors import (DeadlineExceeded, EngineClosed, EngineOverloaded,
                     TenantEvicted)
from .faultinject import fault_point
from .scheduler import (CommitPolicy, MicroBatcher, PendingRetrieval,
                        bucket_shapes)


@dataclasses.dataclass
class RetrievalSlice:
    """Per-request view of a batched retrieval: row ``i`` answers the
    request's ``i``-th ``(tree_id, hash)`` query."""
    hit: np.ndarray
    locations: np.ndarray
    up: np.ndarray
    down: np.ndarray


@dataclasses.dataclass
class AsyncStats:
    """Compat view of one engine's serving counters.

    The counters themselves live in the ``repro.obs`` registry (shared,
    lock-protected); :attr:`AsyncServeEngine.stats` materializes this
    dataclass from the registry values minus the engine's
    construction-time baseline, so sequential engines in one process
    never see each other's counts."""
    batches: int = 0
    requests: int = 0
    queries: int = 0
    padded_queries: int = 0
    prepares: int = 0
    commits: int = 0
    bucket_histogram: Dict[int, int] = dataclasses.field(default_factory=dict)


class AsyncServeEngine:
    """Continuous-batching front end over a :class:`RetrievalSession`.

    ``engine`` is a ``ServeEngine`` (its ``.retrieval`` session is used)
    or a bare ``RetrievalSession``.  ``maintenance`` picks how the
    prepare phase runs: ``"inline"`` (default) runs it on the scheduler
    thread strictly under the in-flight batch — dispatch, prepare, then
    block on results; ``"thread"`` hands it to a background worker so
    even the host pass is off the serving thread; ``"off"`` disables
    background maintenance entirely (callers drive ``maintain()``
    themselves).
    """

    def __init__(self, engine, *, latency_budget: float = 2e-3,
                 max_batch: int = 256, min_bucket: int = 16,
                 commit_every: int = 4, commit_deadline: float = 0.25,
                 clock=time.monotonic, maintenance: str = "inline",
                 max_queue_requests: int = 1024,
                 default_timeout: Optional[float] = None,
                 tenant_quota=None):
        self.session: RetrievalSession = getattr(engine, "retrieval", engine)
        if maintenance not in ("inline", "thread", "off"):
            raise ValueError(f"unknown maintenance mode {maintenance!r}")
        if max_queue_requests < 1:
            raise ValueError("max_queue_requests must be >= 1")
        self.maintenance = maintenance
        self.clock = clock
        # admission control: pending *requests* (split chunks included)
        # above this bound shed with EngineOverloaded at submit time
        self.max_queue_requests = max_queue_requests
        # per-tenant queue share: an int (same quota for every tenant),
        # a {tenant: quota} dict, or None — an equal split of the global
        # bound across the registry's tenants when one is attached
        self.tenant_quota = tenant_quota
        # deadline stamped on submits that pass no explicit timeout
        self.default_timeout = default_timeout
        self.batcher = MicroBatcher(latency_budget=latency_budget,
                                    max_batch=max_batch,
                                    min_bucket=min_bucket)
        self.policy = CommitPolicy(commit_every=commit_every,
                                   deadline=commit_deadline)

        # registry-backed statistics: one counter per AsyncStats field,
        # every mutation under the registry lock (thread-safe across the
        # scheduler thread, the prepare worker, and stop())
        m = self.session.metrics
        self._c_batches = m.counter("serve.batches", "launched batches")
        self._c_requests = m.counter("serve.requests", "served requests")
        self._c_queries = m.counter("serve.queries", "true queries served")
        self._c_padded = m.counter("serve.padded_queries",
                                   "pad slots dispatched")
        self._c_prepares = m.counter("serve.prepares",
                                     "maintenance prepare passes")
        self._c_commits = m.counter("serve.commits",
                                    "maintenance commits applied")
        self._c_bucket = m.counter("serve.batch_bucket",
                                   "batches per pow2 bucket geometry")
        self._c_rejected = m.counter(
            "serve.rejected",
            "requests shed before dispatch, by reason "
            "(overload | deadline | closed)")
        self._c_batch_failures = m.counter(
            "serve.batch_failures",
            "batches whose dispatch/serve path raised (futures failed, "
            "engine kept scheduling)")
        self._c_tenant_queries = m.counter(
            "serve.tenant_queries", "true queries served per tenant")
        self._base = self._counter_values()

        # last maintenance exception the background lifecycle swallowed
        # (the coordinator's quarantine already counted + metered it)
        self.last_maintenance_error: Optional[BaseException] = None

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        # thread-mode prepare handoff: scheduler stores the pre-dispatch
        # snapshot and sets the event; the worker runs the host pass.
        self._prep_event = threading.Event()
        self._prep_state = None
        self._prep_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- stats
    def _counter_values(self) -> Dict:
        return dict(batches=self._c_batches.value(),
                    requests=self._c_requests.value(),
                    queries=self._c_queries.value(),
                    padded_queries=self._c_padded.value(),
                    prepares=self._c_prepares.value(),
                    commits=self._c_commits.value(),
                    bucket=self._c_bucket.raw())

    @property
    def stats(self) -> AsyncStats:
        """This engine's counters as the legacy ``AsyncStats`` shape —
        registry values minus the construction-time baseline."""
        cur, base = self._counter_values(), self._base
        hist = {}
        for key, v in cur["bucket"].items():
            d = int(v - base["bucket"].get(key, 0))
            if d:
                hist[int(dict(key)["bucket"])] = d
        return AsyncStats(
            batches=int(cur["batches"] - base["batches"]),
            requests=int(cur["requests"] - base["requests"]),
            queries=int(cur["queries"] - base["queries"]),
            padded_queries=int(cur["padded_queries"]
                               - base["padded_queries"]),
            prepares=int(cur["prepares"] - base["prepares"]),
            commits=int(cur["commits"] - base["commits"]),
            bucket_histogram=dict(sorted(hist.items())))

    @property
    def hot_recompiles(self) -> int:
        """Serve-step recompiles the session's sentinel attributed to
        this process's hot path — 0 on a healthy padded path."""
        return self.session.sentinel.recompiles

    # ------------------------------------------------------------ intake
    @staticmethod
    def _fail(req: PendingRetrieval, exc: BaseException) -> None:
        """Resolve a request's future with ``exc`` unless the caller
        already cancelled it (never let a future hang)."""
        try:
            req.future.set_exception(exc)
        except InvalidStateError:
            pass

    @staticmethod
    def _resolve(req: PendingRetrieval, result: "RetrievalSlice") -> None:
        try:
            req.future.set_result(result)
        except InvalidStateError:
            pass

    def _quota_for(self, tenant: str) -> Optional[int]:
        """The queue-share quota (in pending requests) for one tenant —
        ``tenant_quota`` as given, or an equal split of the global bound
        across the registry's tenants; ``None`` disables the check."""
        tq = self.tenant_quota
        if tq is None:
            reg = self.session.tenants
            if reg is None:
                return None
            return max(1, self.max_queue_requests // max(1, len(reg.names)))
        if isinstance(tq, dict):
            q = tq.get(tenant)
            return None if q is None else int(q)
        return int(tq)

    def submit(self, tree_ids: Sequence[int], hashes: Sequence[int],
               *, timeout: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        """Enqueue one retrieval request; the future resolves to a
        :class:`RetrievalSlice` once the batch it rides in completes.
        Thread-safe.

        ``timeout`` (seconds, default :attr:`default_timeout`) stamps an
        absolute deadline: a request still queued — or popped but not yet
        dispatched — past it fails with :class:`DeadlineExceeded`.

        ``tenant`` labels the request for quota accounting and trace
        attribution; when omitted and the session carries a
        ``TenantRegistry``, it resolves from the queried tree ids (a
        batch must not span tenants).  A non-resident tenant's submit
        raises :class:`TenantEvicted`; a submit past the tenant's queue
        share raises :class:`EngineOverloaded` *with that tenant* —
        other tenants keep submitting up to their own shares.

        Raises :class:`EngineClosed` after ``stop()``, and
        :class:`EngineOverloaded` when the bounded queue is full (the
        request is shed, never enqueued).  A request larger than
        ``max_batch`` splits into chunks that ride separate batches; the
        returned future aggregates the chunk slices in query order (any
        chunk failure fails the whole request).
        """
        if len(tree_ids) != len(hashes):
            raise ValueError("tree_ids and hashes length mismatch")
        reg = self.session.tenants
        if tenant is None and reg is not None:
            tenant = reg.tenant_of_batch(tree_ids)
        if tenant is not None and reg is not None \
                and not reg.resident(tenant):
            self._c_rejected.inc(reason="evicted", tenant=tenant)
            raise TenantEvicted(tenant)
        now = self.clock()
        timeout = self.default_timeout if timeout is None else timeout
        deadline_t = None if timeout is None else now + timeout
        mb = self.batcher.max_batch
        chunks = [PendingRetrieval(
            tree_ids=list(tree_ids[i:i + mb]),
            hashes=list(hashes[i:i + mb]),
            arrive_t=now, deadline_t=deadline_t, tenant=tenant)
            for i in range(0, max(len(hashes), 1), mb)]
        with self._work:
            if self._stop:
                self._c_rejected.inc(reason="closed")
                raise EngineClosed()
            room = self.max_queue_requests - len(self.batcher)
            if len(chunks) > room:
                # all-or-nothing: a partially enqueued split request
                # could never resolve its aggregate future coherently
                if tenant is None:
                    self._c_rejected.inc(reason="overload")
                else:
                    self._c_rejected.inc(reason="overload", tenant=tenant)
                raise EngineOverloaded(pending=len(self.batcher),
                                       limit=self.max_queue_requests)
            if tenant is not None:
                quota = self._quota_for(tenant)
                held = self.batcher.pending_for(tenant)
                if quota is not None and held + len(chunks) > quota:
                    # the tenant's share is exhausted — shed *its*
                    # traffic while the rest of the queue keeps admitting
                    self._c_rejected.inc(reason="overload", tenant=tenant)
                    raise EngineOverloaded(pending=held, limit=quota,
                                           tenant=tenant)
            for c in chunks:
                self.batcher.add(c)
            self._work.notify()
        if len(chunks) == 1:
            return chunks[0].future
        return self._aggregate([c.future for c in chunks])

    @staticmethod
    def _aggregate(parts: List[Future]) -> Future:
        """One future over a split request's chunk futures: resolves to
        the concatenated :class:`RetrievalSlice` (query order preserved)
        once every chunk lands; the first chunk failure fails it."""
        parent: Future = Future()
        remaining = [len(parts)]
        lock = threading.Lock()

        def _on_done(_f) -> None:
            with lock:
                remaining[0] -= 1
                if remaining[0] > 0:
                    return
            try:
                slices = [p.result() for p in parts]
                out = RetrievalSlice(
                    hit=np.concatenate([s.hit for s in slices]),
                    locations=np.concatenate(
                        [s.locations for s in slices]),
                    up=np.concatenate([s.up for s in slices]),
                    down=np.concatenate([s.down for s in slices]))
                parent.set_result(out)
            except InvalidStateError:                # pragma: no cover
                pass
            except BaseException as exc:
                try:
                    parent.set_exception(exc)
                except InvalidStateError:            # pragma: no cover
                    pass

        for p in parts:
            p.add_done_callback(_on_done)
        return parent

    async def retrieve_async(self, tree_ids: Sequence[int],
                             hashes: Sequence[int],
                             timeout: Optional[float] = None,
                             tenant: Optional[str] = None
                             ) -> RetrievalSlice:
        """Event-loop flavor of :meth:`submit`."""
        return await asyncio.wrap_future(
            self.submit(tree_ids, hashes, timeout=timeout, tenant=tenant))

    def warmup(self) -> int:
        """Pre-compile every bucket geometry the batcher can produce so
        the measured run never hits a compile.  Returns the number of
        shapes touched."""
        shapes = bucket_shapes(self.batcher.min_bucket,
                               self.batcher.max_batch)
        for s in shapes:
            hh, tid, _ = self.session.pad_queries([0], [0], pad_to=s)
            out = self.session.retrieve_dispatch(hh, tid)
            np.asarray(out.hit)
        self.session.harvest()
        # warmup compiles are intentional: baseline the sentinel here so
        # everything after counts as a hot-path recompile
        self.session.sentinel.rebaseline()
        self.session.compile_cache_size()
        return len(shapes)

    # ----------------------------------------------------- deterministic
    def _fail_expired(self, expired: List[PendingRetrieval],
                      now: float) -> None:
        """Fail swept requests with DeadlineExceeded (outside the engine
        lock — future callbacks may re-enter submit())."""
        for req in expired:
            self._c_rejected.inc(reason="deadline")
            self._fail(req, DeadlineExceeded(req.deadline_t, now))

    def pump(self, now: Optional[float] = None) -> bool:
        """Drive one scheduling step inline: sweep expired requests,
        launch a batch if one is due, then commit a staged plan if the
        policy says so.  Returns True when a batch launched.  This is the
        thread-free path the deterministic tests (and single-threaded
        callers) use."""
        explicit = now is not None
        now = self.clock() if now is None else now
        with self._lock:
            expired = self.batcher.expire(now)
            batch = self.batcher.pop() if self.batcher.ready(now) else []
        self._fail_expired(expired, now)
        launched = False
        if batch:
            launched = self._launch(batch, now)
        self._maybe_commit(now if explicit else self.clock())
        return launched

    def flush(self, now: Optional[float] = None) -> int:
        """Launch until the queue drains, ignoring the coalescing budget
        (used on stop so no future is left hanging — every outstanding
        future resolves with a result, a DeadlineExceeded for requests
        already past deadline, or the failure that broke its batch).
        Returns batches launched."""
        n = 0
        while True:
            t = self.clock() if now is None else now
            with self._lock:
                expired = self.batcher.expire(t)
                batch = self.batcher.pop()
            self._fail_expired(expired, t)
            if not batch:
                break
            if self._launch(batch, t):
                n += 1
        return n

    # ------------------------------------------------------------ batch
    def _launch(self, batch: List[PendingRetrieval], now: float) -> bool:
        """Serve one popped batch.  Returns True when it dispatched.

        Dispatch-time deadline check: requests that expired while the
        batch coalesced fail fast here and never pad into the bucket.  A
        raise anywhere in the serve path (injected ``dispatch`` faults
        included) fails this batch's futures and returns — the engine
        keeps scheduling; it never kills the scheduler thread."""
        arrive_t = batch[0].arrive_t
        live = [r for r in batch if not r.expired(now)]
        self._fail_expired([r for r in batch if r.expired(now)], now)
        if not live:
            return False
        batch = live
        tids: List[int] = []
        hhs: List[int] = []
        for req in batch:
            tids.extend(int(t) for t in req.tree_ids)
            hhs.extend(int(h) for h in req.hashes)
        bucket = self.batcher.bucket(batch)

        sp = self.session.tracer.span("serve.batch", bucket=bucket,
                                      requests=len(batch))
        # per-tenant attribution: which tenants ride in this batch — a
        # slow tenant is identifiable from the span stream alone
        tenants = sorted({r.tenant for r in batch if r.tenant is not None})
        if tenants:
            sp.set(tenant=",".join(tenants))
        # the oldest request's queue wait is the coalescing cost this
        # batch imposed — measured from its arrival stamp, not timed here
        sp.add_stage("coalesce", max(0.0, now - arrive_t))

        # pre-dispatch snapshot: the maintenance pass absorbs against
        # arrays that are already materialized, so it never blocks on the
        # batch we just launched; this batch's bumps harvest next cycle.
        snapshot = self.session.state
        try:
            with sp.stage("pad"):
                hh, tid, b = self.session.pad_queries(tids, hhs,
                                                      pad_to=bucket)
            with sp.stage("dispatch"):
                fault_point("dispatch")
                out = self.session.retrieve_dispatch(hh, tid)

            with sp.stage("prepare"):
                self._maybe_prepare(snapshot, now)

            # materializing blocks until the batch lands — everything
            # above ran under it.
            with sp.stage("device_lookup"):
                hit = np.asarray(out.hit)
                loc = np.asarray(out.locations)
                up = np.asarray(out.up)
                down = np.asarray(out.down)
                self.session.harvest()
        except HotPathRecompileError:
            # armed sentinel at dispatch: fail loudly, don't contain
            raise
        except Exception as exc:
            # contain the blast radius to this batch: fail its futures,
            # count it, keep the scheduler alive on the last good state
            sp.set(error=type(exc).__name__).end()
            self._c_batch_failures.inc()
            for req in batch:
                self._fail(req, exc)
            return False

        with sp.stage("route_back"):
            off = 0
            for req in batch:
                k = len(req)
                self._resolve(req, RetrievalSlice(
                    hit=hit[off:off + k], locations=loc[off:off + k],
                    up=up[off:off + k], down=down[off:off + k]))
                off += k
        sp.set(queries=b).end()

        with self._lock:
            self.policy.note_batch()
        self._c_batches.inc()
        self._c_requests.inc(len(batch))
        self._c_queries.inc(b)
        self._c_padded.inc(bucket - b)
        self._c_bucket.inc(bucket=bucket)
        for req in batch:
            if req.tenant is not None:
                self._c_tenant_queries.inc(len(req), tenant=req.tenant)
        # post-batch sentinel tick: any serve-step compile after warmup
        # is attributed (and fatal when armed)
        self.session.observe()
        return True

    # ------------------------------------------------------ maintenance
    def _maybe_prepare(self, snapshot, now: float) -> None:
        coord = self.session.coord
        if self.maintenance == "off" or coord is None:
            return
        if coord.deferring:
            return
        # breaker gate: backoff after failures, serve-only while open —
        # the queued delta simply waits for the next allowed attempt
        if not coord.allow(now):
            return
        if self.session.pending_mutations() == 0 and not coord.dirty:
            return
        if self.maintenance == "thread":
            if not self._prep_event.is_set():
                self._prep_state = snapshot
                self._prep_event.set()
            return
        self._prepare(snapshot, now)

    def _prepare(self, snapshot, now: float) -> None:
        # coord.prepare (not session.prepare_maintenance): a pending plan
        # is the scheduler's to commit between batches — prepare must
        # never flush one from under it.
        coord = self.session.coord
        if coord is None or coord.deferring:
            return
        try:
            coord.prepare(snapshot, now=now)
        except Exception as exc:
            # the coordinator already quarantined (plan dropped, shadow
            # invalidated, breaker fed) — serving continues on the last
            # committed state and the breaker schedules the retry
            self.last_maintenance_error = exc
            return
        self._c_prepares.inc()
        with self._lock:
            if coord.deferring:
                self.policy.note_plan(now)

    def _maybe_commit(self, now: float) -> None:
        coord = self.session.coord
        if coord is None or not coord.deferring:
            return
        with self._lock:
            due = self.policy.due(now)
        if not due:
            return
        # non-blocking: if the prepare worker holds the lifecycle lock we
        # retry on the next pump rather than stalling the serving thread.
        try:
            applied = self.session.commit_maintenance(blocking=False,
                                                      now=now)
        except HotPathRecompileError:
            # the armed sentinel is a fail-loudly tripwire (CI/debug
            # mode), not a maintenance fault — never contain it
            raise
        except Exception as exc:
            # quarantined splice failure: the session still serves the
            # pre-commit state (the plan dropped before any donation) —
            # clear the policy, the breaker gates the re-prepare
            self.last_maintenance_error = exc
            with self._lock:
                self.policy.clear()
            return
        if applied:
            self._c_commits.inc()
            with self._lock:
                self.policy.clear()

    def _prep_loop(self) -> None:
        while True:
            self._prep_event.wait()
            if self._stop:
                return
            state, self._prep_state = self._prep_state, None
            if state is not None:
                self._prepare(state, self.clock())
            self._prep_event.clear()
            if self._stop:
                return

    # ---------------------------------------------------------- threads
    def start(self) -> None:
        """Spin up the scheduler thread (and, in ``"thread"`` maintenance
        mode, the prepare worker)."""
        if self._thread is not None:
            raise RuntimeError("already started")
        self._stop = False
        if self.maintenance == "thread":
            self._prep_thread = threading.Thread(
                target=self._prep_loop, name="cft-prepare", daemon=True)
            self._prep_thread.start()
        self._thread = threading.Thread(
            target=self._schedule_loop, name="cft-scheduler", daemon=True)
        self._thread.start()

    def _schedule_loop(self) -> None:
        while True:
            with self._work:
                if self._stop:
                    return
                now = self.clock()
                expired = self.batcher.expire(now)
                if not expired and not self.batcher.ready(now):
                    deadline = self.batcher.deadline()
                    timeout = None
                    if deadline is not None:
                        timeout = max(0.0, deadline - now)
                    if self.policy.armed:
                        # wake for the commit deadline even when idle
                        t2 = max(0.0, self.policy.deadline / 4)
                        timeout = t2 if timeout is None else min(timeout, t2)
                    self._work.wait(timeout=timeout)
                    if self._stop:
                        return
                    now = self.clock()
                    expired += self.batcher.expire(now)
                batch = self.batcher.pop() if self.batcher.ready(now) else []
            # future callbacks may re-enter submit(): resolve outside
            # the engine lock
            self._fail_expired(expired, now)
            if batch:
                self._launch(batch, now)
            self._maybe_commit(self.clock())

    def stop(self, commit: bool = True) -> None:
        """Stop the scheduler and drain: every outstanding future
        resolves (result, DeadlineExceeded, or its batch's failure —
        never left hanging), then any staged plan optionally commits.
        Afterwards :meth:`submit` raises :class:`EngineClosed`
        immediately.  Idempotent."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._prep_event.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._prep_thread is not None:
            self._prep_thread.join()
            self._prep_thread = None
        self.flush()
        # belt-and-braces: a request the drain could not serve (e.g. its
        # batch kept failing) must still resolve — never leak a future
        with self._lock:
            leftovers = self.batcher.pop()
            while leftovers:
                for req in leftovers:
                    self._fail(req, EngineClosed(
                        "engine stopped before the request was served"))
                leftovers = self.batcher.pop()
        if commit and self.session.coord is not None \
                and self.session.coord.deferring:
            try:
                applied = self.session.commit_maintenance()
            except Exception as exc:
                self.last_maintenance_error = exc
                applied = False
            if applied:
                self._c_commits.inc()
                with self._lock:
                    self.policy.clear()

    def close(self) -> None:
        """Alias for :meth:`stop` — the resource-style name."""
        self.stop()

    def __enter__(self) -> "AsyncServeEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
