"""Async serving engine: continuous batching over the retrieval session.

``ServeEngine`` processes synchronous batches back-to-back; nothing it
reports reflects what a caller sees under load.  ``AsyncServeEngine``
models the real request lifecycle:

1. **submit** — callers enqueue ``(tree_ids, hashes)`` query groups from
   any thread (or via ``retrieve_async`` from an event loop) and get a
   future per request.
2. **coalesce** — a ``MicroBatcher`` collects arrivals until the batch
   is full or the oldest request has waited out the latency budget.
3. **dispatch** — the batch pads to a pow2 bucket (closed shape set, so
   the jitted step never recompiles after warmup) and launches on
   device.
4. **overlap** — while the batch is in flight, the maintenance pass
   (absorb → delta → compact → sort → stage changed bytes) runs on the
   host against the *pre-dispatch* state snapshot; the serving state is
   untouched.
5. **commit** — between batches, under the ``CommitPolicy`` (every N
   batches or plan age past deadline), the staged plan splices into the
   serving state in O(changed bytes).

Retrieval outputs (hit/locations/up/down) depend only on the bank
content, not on temperature or batch grouping, so answers are
bit-identical to the synchronous engine on the same request stream —
the equivalence gate in ``benchmarks/bench_async.py`` checks exactly
that.

Determinism hooks: the constructor takes a ``clock`` (tests inject a
fake), and :meth:`pump` drives one scheduling step inline without any
threads.  ``start()``/``stop()`` run the same logic on a scheduler
thread for real workloads.

Observability: every statistic lives in the process-wide
``repro.obs`` registry (``serve.*`` counters, all mutation under the
registry lock — the old ``AsyncStats`` dataclass was updated from the
scheduler thread, the prepare worker, *and* ``stop()`` without one);
the :attr:`stats` property stays as a compat shim, reconstructing an
``AsyncStats`` view from this engine's registry deltas.  Each launch
emits a ``serve.batch`` trace span (coalesce → pad → dispatch →
prepare → device_lookup → route_back) and ticks the session's
recompile sentinel, so a commit that leaks an unstable shape into the
hot path is counted (and, armed, fatal) rather than a silent ~650 ms
tail spike.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from .engine import RetrievalSession
from .scheduler import (CommitPolicy, MicroBatcher, PendingRetrieval,
                        bucket_shapes)


@dataclasses.dataclass
class RetrievalSlice:
    """Per-request view of a batched retrieval: row ``i`` answers the
    request's ``i``-th ``(tree_id, hash)`` query."""
    hit: np.ndarray
    locations: np.ndarray
    up: np.ndarray
    down: np.ndarray


@dataclasses.dataclass
class AsyncStats:
    """Compat view of one engine's serving counters.

    The counters themselves live in the ``repro.obs`` registry (shared,
    lock-protected); :attr:`AsyncServeEngine.stats` materializes this
    dataclass from the registry values minus the engine's
    construction-time baseline, so sequential engines in one process
    never see each other's counts."""
    batches: int = 0
    requests: int = 0
    queries: int = 0
    padded_queries: int = 0
    prepares: int = 0
    commits: int = 0
    bucket_histogram: Dict[int, int] = dataclasses.field(default_factory=dict)


class AsyncServeEngine:
    """Continuous-batching front end over a :class:`RetrievalSession`.

    ``engine`` is a ``ServeEngine`` (its ``.retrieval`` session is used)
    or a bare ``RetrievalSession``.  ``maintenance`` picks how the
    prepare phase runs: ``"inline"`` (default) runs it on the scheduler
    thread strictly under the in-flight batch — dispatch, prepare, then
    block on results; ``"thread"`` hands it to a background worker so
    even the host pass is off the serving thread; ``"off"`` disables
    background maintenance entirely (callers drive ``maintain()``
    themselves).
    """

    def __init__(self, engine, *, latency_budget: float = 2e-3,
                 max_batch: int = 256, min_bucket: int = 16,
                 commit_every: int = 4, commit_deadline: float = 0.25,
                 clock=time.monotonic, maintenance: str = "inline"):
        self.session: RetrievalSession = getattr(engine, "retrieval", engine)
        if maintenance not in ("inline", "thread", "off"):
            raise ValueError(f"unknown maintenance mode {maintenance!r}")
        self.maintenance = maintenance
        self.clock = clock
        self.batcher = MicroBatcher(latency_budget=latency_budget,
                                    max_batch=max_batch,
                                    min_bucket=min_bucket)
        self.policy = CommitPolicy(commit_every=commit_every,
                                   deadline=commit_deadline)

        # registry-backed statistics: one counter per AsyncStats field,
        # every mutation under the registry lock (thread-safe across the
        # scheduler thread, the prepare worker, and stop())
        m = self.session.metrics
        self._c_batches = m.counter("serve.batches", "launched batches")
        self._c_requests = m.counter("serve.requests", "served requests")
        self._c_queries = m.counter("serve.queries", "true queries served")
        self._c_padded = m.counter("serve.padded_queries",
                                   "pad slots dispatched")
        self._c_prepares = m.counter("serve.prepares",
                                     "maintenance prepare passes")
        self._c_commits = m.counter("serve.commits",
                                    "maintenance commits applied")
        self._c_bucket = m.counter("serve.batch_bucket",
                                   "batches per pow2 bucket geometry")
        self._base = self._counter_values()

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        # thread-mode prepare handoff: scheduler stores the pre-dispatch
        # snapshot and sets the event; the worker runs the host pass.
        self._prep_event = threading.Event()
        self._prep_state = None
        self._prep_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- stats
    def _counter_values(self) -> Dict:
        return dict(batches=self._c_batches.value(),
                    requests=self._c_requests.value(),
                    queries=self._c_queries.value(),
                    padded_queries=self._c_padded.value(),
                    prepares=self._c_prepares.value(),
                    commits=self._c_commits.value(),
                    bucket=self._c_bucket.raw())

    @property
    def stats(self) -> AsyncStats:
        """This engine's counters as the legacy ``AsyncStats`` shape —
        registry values minus the construction-time baseline."""
        cur, base = self._counter_values(), self._base
        hist = {}
        for key, v in cur["bucket"].items():
            d = int(v - base["bucket"].get(key, 0))
            if d:
                hist[int(dict(key)["bucket"])] = d
        return AsyncStats(
            batches=int(cur["batches"] - base["batches"]),
            requests=int(cur["requests"] - base["requests"]),
            queries=int(cur["queries"] - base["queries"]),
            padded_queries=int(cur["padded_queries"]
                               - base["padded_queries"]),
            prepares=int(cur["prepares"] - base["prepares"]),
            commits=int(cur["commits"] - base["commits"]),
            bucket_histogram=dict(sorted(hist.items())))

    @property
    def hot_recompiles(self) -> int:
        """Serve-step recompiles the session's sentinel attributed to
        this process's hot path — 0 on a healthy padded path."""
        return self.session.sentinel.recompiles

    # ------------------------------------------------------------ intake
    def submit(self, tree_ids: Sequence[int],
               hashes: Sequence[int]) -> Future:
        """Enqueue one retrieval request; the future resolves to a
        :class:`RetrievalSlice` once the batch it rides in completes.
        Thread-safe."""
        if len(tree_ids) != len(hashes):
            raise ValueError("tree_ids and hashes length mismatch")
        req = PendingRetrieval(tree_ids=list(tree_ids),
                               hashes=list(hashes),
                               arrive_t=self.clock())
        with self._work:
            if self._stop:
                raise RuntimeError("engine is stopped")
            self.batcher.add(req)
            self._work.notify()
        return req.future

    async def retrieve_async(self, tree_ids: Sequence[int],
                             hashes: Sequence[int]) -> RetrievalSlice:
        """Event-loop flavor of :meth:`submit`."""
        return await asyncio.wrap_future(self.submit(tree_ids, hashes))

    def warmup(self) -> int:
        """Pre-compile every bucket geometry the batcher can produce so
        the measured run never hits a compile.  Returns the number of
        shapes touched."""
        shapes = bucket_shapes(self.batcher.min_bucket,
                               self.batcher.max_batch)
        for s in shapes:
            hh, tid, _ = self.session.pad_queries([0], [0], pad_to=s)
            out = self.session.retrieve_dispatch(hh, tid)
            np.asarray(out.hit)
        self.session.harvest()
        # warmup compiles are intentional: baseline the sentinel here so
        # everything after counts as a hot-path recompile
        self.session.sentinel.rebaseline()
        self.session.compile_cache_size()
        return len(shapes)

    # ----------------------------------------------------- deterministic
    def pump(self, now: Optional[float] = None) -> bool:
        """Drive one scheduling step inline: launch a batch if one is
        due, then commit a staged plan if the policy says so.  Returns
        True when a batch launched.  This is the thread-free path the
        deterministic tests (and single-threaded callers) use."""
        explicit = now is not None
        now = self.clock() if now is None else now
        launched = False
        with self._lock:
            batch = self.batcher.pop() if self.batcher.ready(now) else []
        if batch:
            self._launch(batch, now)
            launched = True
        self._maybe_commit(now if explicit else self.clock())
        return launched

    def flush(self, now: Optional[float] = None) -> int:
        """Launch until the queue drains regardless of deadlines (used on
        stop so no future is left hanging).  Returns batches launched."""
        n = 0
        while True:
            with self._lock:
                batch = self.batcher.pop()
            if not batch:
                break
            self._launch(batch, self.clock() if now is None else now)
            n += 1
        return n

    # ------------------------------------------------------------ batch
    def _launch(self, batch: List[PendingRetrieval], now: float) -> None:
        tids: List[int] = []
        hhs: List[int] = []
        for req in batch:
            tids.extend(int(t) for t in req.tree_ids)
            hhs.extend(int(h) for h in req.hashes)
        bucket = self.batcher.bucket(batch)

        sp = self.session.tracer.span("serve.batch", bucket=bucket,
                                      requests=len(batch))
        # the oldest request's queue wait is the coalescing cost this
        # batch imposed — measured from its arrival stamp, not timed here
        sp.add_stage("coalesce", max(0.0, now - batch[0].arrive_t))

        # pre-dispatch snapshot: the maintenance pass absorbs against
        # arrays that are already materialized, so it never blocks on the
        # batch we just launched; this batch's bumps harvest next cycle.
        snapshot = self.session.state
        with sp.stage("pad"):
            hh, tid, b = self.session.pad_queries(tids, hhs, pad_to=bucket)
        try:
            with sp.stage("dispatch"):
                out = self.session.retrieve_dispatch(hh, tid)
        except Exception as exc:                      # pragma: no cover
            for req in batch:
                req.future.set_exception(exc)
            raise

        with sp.stage("prepare"):
            self._maybe_prepare(snapshot, now)

        # materializing blocks until the batch lands — everything above
        # ran under it.
        with sp.stage("device_lookup"):
            hit = np.asarray(out.hit)
            loc = np.asarray(out.locations)
            up = np.asarray(out.up)
            down = np.asarray(out.down)
            self.session.harvest()

        with sp.stage("route_back"):
            off = 0
            for req in batch:
                k = len(req)
                req.future.set_result(RetrievalSlice(
                    hit=hit[off:off + k], locations=loc[off:off + k],
                    up=up[off:off + k], down=down[off:off + k]))
                off += k
        sp.set(queries=b).end()

        with self._lock:
            self.policy.note_batch()
        self._c_batches.inc()
        self._c_requests.inc(len(batch))
        self._c_queries.inc(b)
        self._c_padded.inc(bucket - b)
        self._c_bucket.inc(bucket=bucket)
        # post-batch sentinel tick: any serve-step compile after warmup
        # is attributed (and fatal when armed)
        self.session.observe()

    # ------------------------------------------------------ maintenance
    def _maybe_prepare(self, snapshot, now: float) -> None:
        if self.maintenance == "off" or self.session.coord is None:
            return
        if self.session.coord.deferring:
            return
        if self.session.pending_mutations() == 0:
            return
        if self.maintenance == "thread":
            if not self._prep_event.is_set():
                self._prep_state = snapshot
                self._prep_event.set()
            return
        self._prepare(snapshot, now)

    def _prepare(self, snapshot, now: float) -> None:
        # coord.prepare (not session.prepare_maintenance): a pending plan
        # is the scheduler's to commit between batches — prepare must
        # never flush one from under it.
        coord = self.session.coord
        if coord is None or coord.deferring:
            return
        coord.prepare(snapshot, now=now)
        self._c_prepares.inc()
        with self._lock:
            if coord.deferring:
                self.policy.note_plan(now)

    def _maybe_commit(self, now: float) -> None:
        coord = self.session.coord
        if coord is None or not coord.deferring:
            return
        with self._lock:
            due = self.policy.due(now)
        if not due:
            return
        # non-blocking: if the prepare worker holds the lifecycle lock we
        # retry on the next pump rather than stalling the serving thread.
        if self.session.commit_maintenance(blocking=False):
            self._c_commits.inc()
            with self._lock:
                self.policy.clear()

    def _prep_loop(self) -> None:
        while True:
            self._prep_event.wait()
            if self._stop:
                return
            state, self._prep_state = self._prep_state, None
            if state is not None:
                self._prepare(state, self.clock())
            self._prep_event.clear()
            if self._stop:
                return

    # ---------------------------------------------------------- threads
    def start(self) -> None:
        """Spin up the scheduler thread (and, in ``"thread"`` maintenance
        mode, the prepare worker)."""
        if self._thread is not None:
            raise RuntimeError("already started")
        self._stop = False
        if self.maintenance == "thread":
            self._prep_thread = threading.Thread(
                target=self._prep_loop, name="cft-prepare", daemon=True)
            self._prep_thread.start()
        self._thread = threading.Thread(
            target=self._schedule_loop, name="cft-scheduler", daemon=True)
        self._thread.start()

    def _schedule_loop(self) -> None:
        while True:
            with self._work:
                if self._stop:
                    return
                now = self.clock()
                if not self.batcher.ready(now):
                    deadline = self.batcher.deadline()
                    timeout = None
                    if deadline is not None:
                        timeout = max(0.0, deadline - now)
                    if self.policy.armed:
                        # wake for the commit deadline even when idle
                        t2 = max(0.0, self.policy.deadline / 4)
                        timeout = t2 if timeout is None else min(timeout, t2)
                    self._work.wait(timeout=timeout)
                    if self._stop:
                        return
                now = self.clock()
                batch = self.batcher.pop() if self.batcher.ready(now) else []
            if batch:
                self._launch(batch, now)
            self._maybe_commit(self.clock())

    def stop(self, commit: bool = True) -> None:
        """Stop the scheduler, drain the queue (every outstanding future
        resolves), and optionally commit any staged plan."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._prep_event.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._prep_thread is not None:
            self._prep_thread.join()
            self._prep_thread = None
        self.flush()
        if commit and self.session.coord is not None \
                and self.session.coord.deferring:
            if self.session.commit_maintenance():
                self._c_commits.inc()
                with self._lock:
                    self.policy.clear()

    def __enter__(self) -> "AsyncServeEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
