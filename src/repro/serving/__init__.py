"""Serving: engine (prefill/decode/scheduler), continuous-batching async
front end, and the CFT-RAG pipeline."""
from .async_engine import AsyncServeEngine, AsyncStats, RetrievalSlice
from .engine import Request, RetrievalSession, ServeEngine, kv_cache_bytes
from .rag import RAGAnswer, RAGPipeline
from .scheduler import (CommitPolicy, MicroBatcher, PendingRetrieval,
                        bucket_batch, bucket_shapes)

__all__ = ["AsyncServeEngine", "AsyncStats", "RetrievalSlice", "Request",
           "RetrievalSession", "ServeEngine", "kv_cache_bytes", "RAGAnswer",
           "RAGPipeline", "CommitPolicy", "MicroBatcher", "PendingRetrieval",
           "bucket_batch", "bucket_shapes"]
