"""Serving: engine (prefill/decode/scheduler) + the CFT-RAG pipeline."""
from .engine import Request, ServeEngine, kv_cache_bytes
from .rag import RAGAnswer, RAGPipeline

__all__ = ["Request", "ServeEngine", "kv_cache_bytes", "RAGAnswer",
           "RAGPipeline"]
