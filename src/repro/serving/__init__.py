"""Serving: engine (prefill/decode/scheduler), continuous-batching async
front end, the CFT-RAG pipeline, typed serving errors, and the
deterministic fault-injection harness."""
from .async_engine import AsyncServeEngine, AsyncStats, RetrievalSlice
from .engine import Request, RetrievalSession, ServeEngine, kv_cache_bytes
from .errors import (DeadlineExceeded, EngineClosed, EngineOverloaded,
                     TenantEvicted)
from .faultinject import (FAULT_SITES, FaultPlan, InjectedFault,
                          active_plan, fault_point, inject)
from .rag import RAGAnswer, RAGPipeline
from .scheduler import (CommitPolicy, MicroBatcher, PendingRetrieval,
                        bucket_batch, bucket_shapes)

__all__ = ["AsyncServeEngine", "AsyncStats", "RetrievalSlice", "Request",
           "RetrievalSession", "ServeEngine", "kv_cache_bytes", "RAGAnswer",
           "RAGPipeline", "CommitPolicy", "MicroBatcher", "PendingRetrieval",
           "bucket_batch", "bucket_shapes",
           "DeadlineExceeded", "EngineClosed", "EngineOverloaded",
           "TenantEvicted",
           "FAULT_SITES", "FaultPlan", "InjectedFault", "active_plan",
           "fault_point", "inject"]
