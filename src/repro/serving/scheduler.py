"""Continuous-batching primitives for the async serving engine.

Everything here is deterministic and clock-free: callers pass ``now``
explicitly, so the batching policy is unit-testable without sleeping and
the engine can swap in a fake clock.  Three pieces:

* :func:`bucket_batch` — pow2 batch-shape buckets.  The retrieval step is
  jitted per geometry; rounding every coalesced batch up to a power of
  two bounds the number of compilations at ``log2(max_batch /
  min_bucket) + 1`` regardless of arrival pattern, so XLA never
  recompiles on the hot path after warmup.
* :class:`MicroBatcher` — coalesces request arrivals into batches under a
  latency budget.  A batch launches when the pending query count reaches
  ``max_batch`` (bucket-full) or the *oldest* pending request has waited
  ``latency_budget`` seconds (budget expiry) — the standard continuous-
  batching tradeoff between padding waste and queueing delay.
* :class:`CommitPolicy` — decides when the background maintenance loop
  may splice a staged restage plan into the serving state: every
  ``commit_every`` batches, or sooner when the plan has aged past
  ``deadline`` seconds (bounding staleness of the served filter bank).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple


def bucket_batch(n: int, min_bucket: int = 16, max_batch: int = 256) -> int:
    """Smallest power-of-two ``>= n``, clamped to ``[min_bucket,
    max_batch]``.  ``n`` itself must not exceed ``max_batch``."""
    if n <= 0:
        raise ValueError("empty batch")
    if n > max_batch:
        raise ValueError(f"batch {n} exceeds max_batch {max_batch}")
    b = min_bucket
    while b < n:
        b <<= 1
    return min(b, max_batch)


def bucket_shapes(min_bucket: int = 16, max_batch: int = 256) -> List[int]:
    """All pow2 geometries :func:`bucket_batch` can produce — the closed
    set of shapes the jitted retrieval step will ever see, exposed so
    tests (and warmup) can enumerate them."""
    shapes = []
    b = min_bucket
    while b < max_batch:
        shapes.append(b)
        b <<= 1
    shapes.append(max_batch)
    return shapes


@dataclasses.dataclass
class PendingRetrieval:
    """One enqueued retrieval request: a (tree_ids, hashes) query group
    whose per-request slice resolves through ``future`` once the batch
    it rode in completes.  ``deadline_t`` is the absolute clock time
    after which the request must fail fast with ``DeadlineExceeded``
    instead of occupying a batch slot (``None`` = no deadline).
    ``tenant`` labels the request for quota accounting, fair coalescing
    and the per-tenant trace attribution (``None`` = unscoped)."""
    tree_ids: Sequence[int]
    hashes: Sequence[int]
    arrive_t: float
    future: Future = dataclasses.field(default_factory=Future)
    deadline_t: Optional[float] = None
    tenant: Optional[str] = None

    def __len__(self) -> int:
        return len(self.hashes)

    def expired(self, now: float) -> bool:
        return self.deadline_t is not None and now >= self.deadline_t


class MicroBatcher:
    """FIFO arrival coalescer — tenant-fair when requests carry tenant
    labels.  Not thread-safe — the engine serializes access under its own
    lock and this class stays pure policy."""

    def __init__(self, latency_budget: float = 2e-3,
                 max_batch: int = 256, min_bucket: int = 16):
        if min_bucket > max_batch:
            raise ValueError("min_bucket > max_batch")
        self.latency_budget = latency_budget
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self._queue: List[PendingRetrieval] = []
        self._pending_queries = 0
        self._tenant_pending: dict = {}    # tenant -> queued request count

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending_queries(self) -> int:
        return self._pending_queries

    def pending_for(self, tenant: Optional[str]) -> int:
        """Queued request count for one tenant — the admission-control
        input for per-tenant quotas."""
        return self._tenant_pending.get(tenant, 0)

    def _drop_count(self, reqs: Sequence[PendingRetrieval]) -> None:
        for r in reqs:
            n = self._tenant_pending.get(r.tenant, 0) - 1
            if n > 0:
                self._tenant_pending[r.tenant] = n
            else:
                self._tenant_pending.pop(r.tenant, None)

    def add(self, req: PendingRetrieval) -> None:
        if len(req) == 0:
            raise ValueError("empty retrieval request")
        if len(req) > self.max_batch:
            raise ValueError(
                f"request with {len(req)} queries exceeds max_batch "
                f"{self.max_batch}")
        self._queue.append(req)
        self._pending_queries += len(req)
        self._tenant_pending[req.tenant] = \
            self._tenant_pending.get(req.tenant, 0) + 1

    def expire(self, now: float) -> List[PendingRetrieval]:
        """Remove and return every queued request whose deadline has
        passed — the coalesce-time half of deadline enforcement.  The
        caller (which owns the engine lock) fails the returned requests'
        futures with ``DeadlineExceeded``; they never occupy a batch
        slot.  Pure policy, like everything else here."""
        expired = [r for r in self._queue if r.expired(now)]
        if expired:
            self._queue = [r for r in self._queue if not r.expired(now)]
            self._pending_queries -= sum(len(r) for r in expired)
            self._drop_count(expired)
        return expired

    def ready(self, now: float) -> bool:
        """Launch condition: bucket-full, or the head request's wait hit
        the latency budget."""
        if not self._queue:
            return False
        if self._pending_queries >= self.max_batch:
            return True
        return (now - self._queue[0].arrive_t) >= self.latency_budget

    def deadline(self) -> Optional[float]:
        """Absolute time at which the scheduler must next act: budget
        expiry of the head request, or the earliest request deadline
        (so an expiring request fails fast instead of waiting out the
        batching budget); ``None`` when the queue is empty.  The
        scheduler thread sleeps until ``deadline() - now`` (or an
        arrival)."""
        if not self._queue:
            return None
        t = self._queue[0].arrive_t + self.latency_budget
        for r in self._queue:
            if r.deadline_t is not None and r.deadline_t < t:
                t = r.deadline_t
        return t

    def pop(self) -> List[PendingRetrieval]:
        """Dequeue up to ``max_batch`` queries' worth of requests.
        Requests never split across batches — per-request futures resolve
        atomically.

        With at most one distinct tenant queued this is the longest FIFO
        prefix that fits.  With several it is a tenant-fair round-robin:
        tenants rotate in order of their oldest request, each contributing
        its own head-of-line request per turn — one tenant's burst can
        fill the queue without monopolizing the batch, while per-tenant
        FIFO order is preserved exactly."""
        tenants: List[Optional[str]] = []
        for r in self._queue:
            if r.tenant not in tenants:
                tenants.append(r.tenant)
        batch: List[PendingRetrieval] = []
        total = 0
        if len(tenants) <= 1:
            while self._queue and \
                    total + len(self._queue[0]) <= self.max_batch:
                req = self._queue.pop(0)
                total += len(req)
                batch.append(req)
        else:
            by: dict = {t: [] for t in tenants}
            for r in self._queue:
                by[r.tenant].append(r)
            took = True
            while took:
                took = False
                for t in tenants:
                    q = by[t]
                    if q and total + len(q[0]) <= self.max_batch:
                        req = q.pop(0)
                        total += len(req)
                        batch.append(req)
                        took = True
            picked = {id(r) for r in batch}
            self._queue = [r for r in self._queue if id(r) not in picked]
        self._pending_queries -= total
        self._drop_count(batch)
        return batch

    def bucket(self, batch: Sequence[PendingRetrieval]) -> int:
        return bucket_batch(sum(len(r) for r in batch),
                            self.min_bucket, self.max_batch)


class CommitPolicy:
    """When may the maintenance loop swap the serving state?

    Commits only happen *between* batches (the splice donates the live
    buffers), so the policy just answers "is one due": after
    ``commit_every`` batches since the plan was staged, or once the plan
    is ``deadline`` seconds old — whichever comes first.
    """

    def __init__(self, commit_every: int = 4, deadline: float = 0.25):
        self.commit_every = commit_every
        self.deadline = deadline
        self._plan_t: Optional[float] = None
        self._batches_since_plan = 0

    @property
    def armed(self) -> bool:
        return self._plan_t is not None

    def note_plan(self, now: float) -> None:
        self._plan_t = now
        self._batches_since_plan = 0

    def note_batch(self) -> None:
        if self._plan_t is not None:
            self._batches_since_plan += 1

    def due(self, now: float) -> bool:
        if self._plan_t is None:
            return False
        return (self._batches_since_plan >= self.commit_every
                or (now - self._plan_t) >= self.deadline)

    def clear(self) -> None:
        self._plan_t = None
        self._batches_since_plan = 0
