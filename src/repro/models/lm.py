"""Unified model API over all families: init / forward / loss / serve steps.

`batch` dict convention (built by data/pipeline.py and launch/specs):
  train:   {"tokens": (B,S) i32, "labels": (B,S) i32, "mask": (B,S) f32,
            + "patches" (B,P,Fd) for vlm | "frames" (B,T,D) for encdec}
  prefill: {"tokens": (B,S)} (+ modality inputs)
  decode:  {"tokens": (B,1)} + the state threaded from prefill/init
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import transformer as T
from .layers import Params


# ------------------------------------------------------------------- init

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    if cfg.family == "mamba_hybrid":
        return T.init_zamba_params(cfg, key)
    if cfg.family == "rwkv":
        return T.init_rwkv_params(cfg, key)
    if cfg.family == "encdec":
        return T.init_encdec_params(cfg, key)
    return T.init_decoder_params(cfg, key)


def abstract_params(cfg: ModelConfig, key: Optional[jax.Array] = None):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


# ---------------------------------------------------------------- forward

def forward(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
            ) -> jax.Array:
    if cfg.family == "mamba_hybrid":
        return T.zamba_forward(cfg, params, batch["tokens"])
    if cfg.family == "rwkv":
        return T.rwkv_forward(cfg, params, batch["tokens"])
    if cfg.family == "encdec":
        return T.encdec_forward(cfg, params, batch["tokens"], batch["frames"])
    return T.decoder_forward(cfg, params, batch["tokens"],
                             patches=batch.get("patches"))


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            z_loss: float = 1e-4) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Masked CE (+ z-loss). Labels for vlm cover text positions only —
    patch positions carry mask 0 (specs pad labels/mask to the fused len)."""
    logits = forward(cfg, params, batch)               # (B, L, V) f32
    labels = batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    if logits.shape[1] != labels.shape[1]:             # early-fusion prefix
        pad = logits.shape[1] - labels.shape[1]
        logits = logits[:, pad:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    zl = jnp.sum(jnp.square(lse) * mask) / denom
    loss = ce + z_loss * zl
    return loss, {"ce": ce, "z_loss": zl,
                  "tokens": jnp.sum(mask).astype(jnp.int32)}


# ------------------------------------------------------------------ serve

def init_decode_state(cfg: ModelConfig, params: Params, batch_size: int,
                      cache_size: int, batch: Optional[Dict[str, Any]] = None
                      ) -> Dict[str, Any]:
    """Decode state for a fresh (or dry-run) cache of ``cache_size``."""
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    if cfg.family == "mamba_hybrid":
        return T.zamba_init_state(cfg, batch_size, cache_size, dtype)
    if cfg.family == "rwkv":
        return T.rwkv_init_state(cfg, batch_size, dtype)
    if cfg.family == "encdec":
        assert batch is not None and "frames" in batch
        return T.encdec_init_state(cfg, params, batch["frames"], cache_size)
    steps, per = T._moe_layout(cfg)
    hd = cfg.resolved_head_dim
    kv = lambda: {"k": jnp.zeros((steps, batch_size, cfg.n_kv_heads,
                                  cache_size, hd), dtype),
                  "v": jnp.zeros((steps, batch_size, cfg.n_kv_heads,
                                  cache_size, hd), dtype)}
    cache = ({"dense": kv(), "moe": kv()} if per == 2 else kv())
    return {"cache": cache, "len": jnp.int32(0)}


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            cache_size: int) -> Tuple[jax.Array, Dict[str, Any]]:
    if cfg.family == "rwkv":
        logits, state = T.rwkv_forward(cfg, params, batch["tokens"],
                                       collect=True)
        return logits[:, -1:], state
    if cfg.family == "encdec":
        return T.encdec_prefill(cfg, params, batch["tokens"],
                                batch["frames"], cache_size)
    if cfg.family == "mamba_hybrid":
        return T.zamba_prefill(cfg, params, batch["tokens"], cache_size)
    return T.decoder_prefill(cfg, params, batch["tokens"], cache_size,
                             patches=batch.get("patches"))


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                state: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: (B, 1) -> (logits (B,1,V), new state)."""
    if cfg.family == "mamba_hybrid":
        return T.zamba_decode(cfg, params, tokens, state)
    if cfg.family == "rwkv":
        return T.rwkv_decode(cfg, params, tokens, state)
    if cfg.family == "encdec":
        return T.encdec_decode(cfg, params, tokens, state)
    return T.decoder_decode(cfg, params, tokens, state)


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


# -------------------------------------------------------------- accounting

def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of num_experts routed)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    tree = abstract_params(cfg)
    import numpy as np
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = jax.tree_util.keystr(path)
        if any(t in name for t in ("w_gate", "w_up", "w_down")):
            routed += int(np.prod(leaf.shape))
    active_routed = routed * cfg.top_k // cfg.num_experts
    return total - routed + active_routed
