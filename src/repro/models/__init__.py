"""Generator architectures (the LLM slot CFT-RAG augments)."""
from .lm import (abstract_params, active_param_count, decode_step, forward,
                 greedy_token, init_decode_state, init_params, loss_fn,
                 param_count, prefill)

__all__ = ["abstract_params", "active_param_count", "decode_step", "forward",
           "greedy_token", "init_decode_state", "init_params", "loss_fn",
           "param_count", "prefill"]
