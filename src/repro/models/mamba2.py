"""Mamba2 (SSD) block — zamba2's backbone layer.

Structure per Mamba2: in_proj -> [z | x | B | C | dt]; causal depthwise
conv over (x,B,C); dt = softplus(dt + bias); per-head scalar decay
g = dt * (-exp(A_log)); SSD recurrence via the shared linear_scan kernel
(inclusive: y_t = C_t . h_t) with k=B_t, v=dt*x_t; skip D*x; gated RMSNorm;
out_proj.  ngroups=1 (B/C shared across heads), as in zamba2.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.linear_scan.ops import linear_scan
from ..kernels.linear_scan.ref import linear_scan_chunked, linear_scan_ref
from .layers import Params, dense, dense_init, rmsnorm, rmsnorm_init


def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),            # A = -exp(a_log)
        "dt_bias": jnp.full((h,), -1.0, jnp.float32),     # softplus bias
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 window: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (K, C).
    window: (B, K-1, C) carried context for decode (None -> zero history)."""
    k = w.shape[0]
    if window is None:
        window = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([window, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def _ssd_inputs(cfg: ModelConfig, p: Params, x: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = dense(p["in_proj"], x)                          # (B, L, ...)
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt_raw


def _ssd_core(cfg, p, xbc_conv, dt_raw):
    """Split conv output, build SSD tensors (q,k,v,g per head)."""
    b, l, _ = xbc_conv.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    xs, bmat, cmat = jnp.split(xbc_conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(p["a_log"])                               # (H,)
    g = (dt * a)[..., None]                                # (B,L,H,1) log decay
    xh = xs.reshape(b, l, h, hp)
    v = (xh.astype(jnp.float32) * dt[..., None]).astype(xs.dtype)
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, l, h, n))
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, l, h, n))
    gq = jnp.broadcast_to(g, (b, l, h, n))
    to_bhl = lambda t: t.transpose(0, 2, 1, 3)             # (B,H,L,*)
    return to_bhl(q), to_bhl(k), to_bhl(v), to_bhl(gq), xh, dt


def mamba2_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                   use_kernel: bool = False, collect: bool = False):
    """Full-sequence forward. x: (B, L, d_model).
    collect=True also returns the decode cache (conv window + final state)."""
    z, xbc_in, dt_raw = _ssd_inputs(cfg, p, x)
    xbc = _causal_conv(xbc_in, p["conv_w"], p["conv_b"])
    q, k, v, g, xh, _ = _ssd_core(cfg, p, xbc, dt_raw)
    scan = linear_scan if use_kernel else linear_scan_chunked
    kw = dict(inclusive=True)
    if use_kernel:
        kw["interpret"] = jax.default_backend() != "tpu"
    y, s_fin = scan(q, k, v, g, None, **kw)                # (B,H,L,P)
    y = y.transpose(0, 2, 1, 3)                            # (B,L,H,P)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(p["out_proj"], y)
    if collect:
        # s_fin from the chunked scan is (B,H,Dk,Dv) = (B,H,N,P)
        window = xbc_in[:, -(cfg.ssm_conv - 1):]
        return out, {"conv": window, "ssm": s_fin}
    return out


# ------------------------------------------------------------------ decode

def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                  cache: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step. x: (B, 1, d_model)."""
    z, xbc, dt_raw = _ssd_inputs(cfg, p, x)
    conv_in = xbc
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], window=cache["conv"])
    new_conv = jnp.concatenate([cache["conv"], conv_in], axis=1)[:, 1:]
    q, k, v, g, xh, _ = _ssd_core(cfg, p, xbc, dt_raw)
    # one-step recurrence: S' = exp(g) S + k (x) v ; y = q . S'
    s = cache["ssm"]                                       # (B,H,N,P)
    gi = g[:, :, 0].astype(jnp.float32)                    # (B,H,N)
    ki = k[:, :, 0].astype(jnp.float32)
    qi = q[:, :, 0].astype(jnp.float32)
    vi = v[:, :, 0].astype(jnp.float32)                    # (B,H,P)
    s_new = jnp.exp(gi)[..., None] * s + ki[..., None] * vi[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", qi, s_new)             # (B,H,P)
    y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return dense(p["out_proj"], y), {"conv": new_conv, "ssm": s_new}
