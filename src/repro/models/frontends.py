"""Modality frontend STUBS (per assignment brief).

``[audio]`` / ``[vlm]`` architectures specify the transformer BACKBONE only;
the conv/ViT frontends are stubs — ``launch.specs.input_specs`` provides
precomputed frame/patch embeddings of the right shape, and synthetic
embeddings are generated here for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def stub_patches(cfg: ModelConfig, key, batch: int) -> jax.Array:
    """Precomputed ViT patch embeddings (B, P, frontend_dim)."""
    return jax.random.normal(key, (batch, cfg.num_patches, cfg.frontend_dim),
                             jnp.float32) * 0.02


def stub_frames(cfg: ModelConfig, key, batch: int) -> jax.Array:
    """Precomputed audio conv-frontend frames (B, T, d_model)."""
    return jax.random.normal(key, (batch, cfg.num_patches, cfg.d_model),
                             jnp.float32) * 0.02
