"""RWKV6 "Finch" block — attention-free, data-dependent decay.

Time-mix: static per-channel token-shift mixes for r/k/v/g + the Finch
hallmark, a *data-dependent* decay w produced by a low-rank MLP of the
token-shifted input: w = -exp(w0 + tanh(xw @ A) @ B) (log-decay <= 0 by
construction).  The WKV recurrence runs through the shared linear_scan
kernel in EXCLUSIVE mode (out_t = r_t . S_{t-1}) plus the u-bonus term for
the current token.  Per-head GroupNorm, SiLU(g) gate, out-proj.

Channel-mix: token-shifted squared-ReLU FFN with sigmoid receptance gate.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.linear_scan.ops import linear_scan
from ..kernels.linear_scan.ref import linear_scan_chunked, linear_scan_ref
from .layers import Params, dense, dense_init, groupnorm

_LORA = 64        # decay low-rank width


def init_rwkv6_time(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_a": (jax.random.normal(ks[5], (d, _LORA), jnp.float32) * 0.01
                ).astype(dtype),
        "w_b": (jax.random.normal(ks[6], (_LORA, d), jnp.float32) * 0.01
                ).astype(dtype),
        "u": (jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1
              ).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
        "wo": dense_init(jax.random.fold_in(key, 99), d, d, dtype),
    }


def init_rwkv6_channel(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32).astype(dtype),
        "wk": dense_init(ks[1], d, f, dtype),
        "wv": dense_init(ks[2], f, d, dtype),
        "wr": dense_init(jax.random.fold_in(key, 7), d, d, dtype),
    }


def _shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """Token shift: x_{t-1}, with ``last`` as the t=0 predecessor (B,1,D)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _heads(x, h):
    b, l, d = x.shape
    return x.reshape(b, l, h, d // h).transpose(0, 2, 1, 3)   # (B,H,L,hd)


def rwkv6_time_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                   last_x: jax.Array, state: jax.Array,
                   use_kernel: bool = False
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,L,D); last_x: (B,1,D); state: (B,H,hd,hd) WKV state.
    Returns (out, new_last_x, new_state)."""
    b, l, d = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    xp = _shift(x, last_x)
    mu = p["mu"]
    mix = lambda i: x + (xp - x) * mu[i]
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))

    r = _heads(dense(p["wr"], xr), h)
    k = _heads(dense(p["wk"], xk), h)
    v = _heads(dense(p["wv"], xv), h)
    g = dense(p["wg"], xg)

    # Finch data-dependent decay (log-space, <= 0)
    lora = jnp.tanh(xw @ p["w_a"]) @ p["w_b"]
    w = -jnp.exp(p["w0"] + lora.astype(jnp.float32))          # (B,L,D)
    gk = _heads(w.astype(x.dtype), h).astype(jnp.float32)     # (B,H,L,hd)

    scan = linear_scan if use_kernel else linear_scan_chunked
    kw = dict(inclusive=False)
    if use_kernel:
        kw["interpret"] = jax.default_backend() != "tpu"
    wkv, new_state = scan(r, k, v, gk, state, **kw)           # (B,H,L,hd)
    # u-bonus: current token's contribution weighted by u instead of decay
    bonus = jnp.einsum("bhld,bhld->bhl", r.astype(jnp.float32),
                       k.astype(jnp.float32) * p["u"][None, :, None, :])
    wkv = wkv.astype(jnp.float32) + bonus[..., None] * v.astype(jnp.float32)

    out = wkv.transpose(0, 2, 1, 3).reshape(b, l, d).astype(x.dtype)
    out = groupnorm(out, h, p["gn_scale"], p["gn_bias"])
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], out), x[:, -1:], new_state


def rwkv6_channel_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                      last_x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xp = _shift(x, last_x)
    mu = p["mu"]
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    kk = dense(p["wk"], xk)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(dense(p["wr"], xr).astype(jnp.float32)).astype(x.dtype)
    return r * dense(p["wv"], kk), x[:, -1:]
