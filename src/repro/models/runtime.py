"""Ambient distribution context for model code.

Drivers (dryrun / train / serve) set the mesh + axis roles once; model
modules that need explicit shard_map regions (the MoE expert-parallel
block) read it here.  When unset (CPU tests, single device), models take
their plain single-device paths.
"""
from __future__ import annotations

from typing import Optional, Tuple

_MESH = None
_DP_AXES: Tuple[str, ...] = ("data",)
_TP_AXIS: str = "model"


def set_mesh(mesh, dp_axes: Tuple[str, ...] = ("data",),
             tp_axis: str = "model") -> None:
    global _MESH, _DP_AXES, _TP_AXIS
    _MESH = mesh
    _DP_AXES = tuple(dp_axes)
    _TP_AXIS = tp_axis


def clear_mesh() -> None:
    global _MESH
    _MESH = None


def get_mesh():
    return _MESH


def dp_axes() -> Tuple[str, ...]:
    return _DP_AXES


def tp_axis() -> str:
    return _TP_AXIS


def constrain_batch(x):
    """Pin dim-0 (batch) to the data axes at layer boundaries.

    GSPMD occasionally drifts into batch replication inside scanned layer
    bodies (observed on rwkv/zamba: every device computing all 16 samples);
    a with_sharding_constraint at the residual stream stops the drift."""
    if _MESH is None:
        return x
    import jax
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(_DP_AXES, *(None,) * (x.ndim - 1)))


def constrain_seq(x):
    """Sequence parallelism: (B, L, D) -> batch over data, SEQ over model.

    For prefill, head-count TP fragments (no assigned arch has q/kv heads
    divisible by 16), and GSPMD then all-reduces full score tensors.  With
    the sequence dim sharded, scores stay seq-sharded and only the (small)
    kv chunks are gathered.  No-op when seq doesn't divide the model axis."""
    if _MESH is None or x.ndim < 3:
        return x
    if x.shape[1] % _MESH.shape[_TP_AXIS] != 0:
        return constrain_batch(x)
    import jax
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(_DP_AXES, _TP_AXIS, *(None,) * (x.ndim - 2)))
