"""Attention: GQA projections + three interchangeable inner implementations.

impl="reference"  — full (B,H,Lq,Lkv) score materialization (oracle, tests)
impl="blocked"    — jnp online-softmax over kv chunks (flash semantics,
                    compact HLO: what the dry-run lowers and what XLA:TPU
                    fuses well; differentiable via scan)
impl="flash"      — the Pallas kernel (TPU; interpret=True elsewhere)

GQA is computed grouped — q reshaped to (B, Hkv, G, L, D) — so kv is never
materialized per q-head.  Decode attends through repro.kernels.decode_attention
(or its ref), with uniform cache length per batch and flash-decoding LSE
output for sequence-sharded caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.decode_attention.ops import decode_attention
from ..kernels.decode_attention.ref import decode_attention_ref
from ..kernels.flash_attention.ops import flash_attention
from .layers import Params, apply_rope, dense, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype, bias=False),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, n_heads, -1).transpose(0, 2, 1, 3)   # (B,H,L,D)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, l, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * d)


# --------------------------------------------------------- inner attention

def _reference_attn(q, k, v, causal: bool, q_offset: int, scale: float):
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, lq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(lq)[:, None] + q_offset
        ki = jnp.arange(lkv)[None, :]
        s = jnp.where(ki <= qi, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, lq, d).astype(q.dtype)


def _blocked_attn(q, k, v, causal: bool, q_offset: int, scale: float,
                  chunk: int):
    """Online-softmax over kv chunks: flash semantics in pure jnp."""
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    g = hq // hkv
    pad = (-lkv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    steps = (lkv + pad) // chunk
    kc = k.reshape(b, hkv, steps, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, steps, chunk, d).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(b, hkv, g, lq, d).astype(jnp.float32)
    qi = jnp.arange(lq)[:, None] + q_offset                  # (Lq, 1)

    def step(carry, inp):
        m, l, acc = carry
        ic, kci, vci = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                       kci.astype(jnp.float32)) * scale
        ki = ic * chunk + jnp.arange(chunk)                  # (C,)
        if causal:
            valid = (ki[None, :] <= qi) & (ki[None, :] < lkv)  # (Lq, C)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        else:
            valid = ki < lkv                                 # (C,)
            s = jnp.where(valid[None, None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vci.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(steps), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, lq, d).astype(q.dtype)


def _inner_attention(cfg: ModelConfig, q, k, v, causal: bool, q_offset: int):
    scale = cfg.resolved_head_dim ** -0.5
    if cfg.attn_impl == "reference":
        return _reference_attn(q, k, v, causal, q_offset, scale)
    if cfg.attn_impl == "flash":
        interpret = jax.default_backend() != "tpu"
        return flash_attention(q, k, v, causal, scale, interpret)
    return _blocked_attn(q, k, v, causal, q_offset, scale, cfg.attn_chunk)


# ------------------------------------------------------------ public entry

def attend(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
           causal: bool = True,
           kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
           rope: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, L, D)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(p["wq"], x), cfg.n_heads)
    if kv_override is None:
        k = _split_heads(dense(p["wk"], x), cfg.n_kv_heads)
        v = _split_heads(dense(p["wv"], x), cfg.n_kv_heads)
    else:
        k, v = kv_override
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    out = _inner_attention(cfg, q, k, v, causal, q_offset=0)
    return dense(p["wo"], _merge_heads(out))


def prefill_kv(cfg: ModelConfig, p: Params, x: jax.Array,
               positions: jax.Array, cache_size: int,
               rope: bool = True) -> Dict[str, jax.Array]:
    """Projected+rotated kv for the cache, padded to cache_size."""
    k = _split_heads(dense(p["wk"], x), cfg.n_kv_heads)
    v = _split_heads(dense(p["wv"], x), cfg.n_kv_heads)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    pad = cache_size - k.shape[2]
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": k, "v": v}


def decode_attend(cfg: ModelConfig, p: Params, x: jax.Array,
                  cache: Dict[str, jax.Array], cache_len: jax.Array,
                  rope: bool = True, update_cache: bool = True,
                  use_kernel: bool = False
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode step. x: (B, 1, D); cache k/v: (B, Hkv, S, hd);
    cache_len: scalar int32 (uniform valid prefix)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q = _split_heads(dense(p["wq"], x), cfg.n_heads)         # (B,Hq,1,hd)
    k_new = _split_heads(dense(p["wk"], x), cfg.n_kv_heads)  # (B,Hkv,1,hd)
    v_new = _split_heads(dense(p["wv"], x), cfg.n_kv_heads)
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, cache_len, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, cache_len, axis=2)
    lens = jnp.full((b,), cache_len + 1, jnp.int32)

    qd = q[:, :, 0]                                          # (B,Hq,hd)
    if use_kernel:
        out = decode_attention(qd, k, v, lens,
                               interpret=jax.default_backend() != "tpu")
    else:
        out = decode_attention_ref(qd, k, v, lens)
    out = dense(p["wo"], out.reshape(b, 1, -1))
    new_cache = {"k": k, "v": v} if update_cache else cache
    return out, new_cache
