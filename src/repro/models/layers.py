"""Shared layers: norms, RoPE, linear/embedding initializers.

Parameters are plain dict pytrees (no flax): explicit, shardable, and
stackable for scan-over-layers.  Initializers return (shape, dtype) trees
through ``jax.eval_shape``-compatible functions so the dry-run can build
abstract parameters without ever allocating 123B weights on this host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ----------------------------------------------------------------- initers

def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * d ** -0.5).astype(dtype)


# ------------------------------------------------------------------- norms

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


def groupnorm(x: jax.Array, num_groups: int, scale: jax.Array,
              bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head group norm (RWKV6 output norm). x: (..., H*D)."""
    shape = x.shape
    xf = x.astype(jnp.float32).reshape(*shape[:-1], num_groups, -1)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return y.astype(x.dtype) * scale + bias


# -------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, L, D); positions: (B, L) absolute token positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,L,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- activations

def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)
