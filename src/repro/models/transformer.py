"""Model assembly for all assigned families.

Families:
  dense / vlm     — decoder-only GQA transformer (+ optional early-fusion
                    patch embeddings, frontend STUB)
  moe             — dense attention + (interleaved) MoE FFN
  mamba_hybrid    — zamba2: mamba2 backbone, weight-SHARED attention block
                    every ``attn_every`` layers (one param set, many caches)
  rwkv            — RWKV6 time-mix + channel-mix
  encdec          — whisper: bidirectional encoder (stub audio frames) +
                    causal decoder with cross-attention

All stacks scan over layers (stacked params) so 88-layer models lower as a
single-layer HLO body — this is what keeps 80 dry-run compiles feasible and
is also the production choice (compile time, code size on device).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import mamba2 as m2
from . import moe as moe_mod
from . import runtime
from . import rwkv6 as r6
from .layers import (Params, dense, dense_init, embed_init, gelu, layernorm,
                     layernorm_init, rmsnorm, rmsnorm_init, swiglu)


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


# =====================================================================
# shared layer pieces
# =====================================================================

def _init_mlp(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {"gate": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "up": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "down": dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype)}


def _mlp(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["down"], swiglu(dense(p["gate"], x), dense(p["up"], x)))


def _init_dense_layer(cfg: ModelConfig, dtype, use_moe: bool):
    def init(key):
        ks = jax.random.split(key, 3)
        p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
             "ln2": rmsnorm_init(cfg.d_model, dtype),
             "attn": attn.init_attention(ks[0], cfg, dtype)}
        if use_moe:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = _init_mlp(ks[2], cfg, dtype)
        return p
    return init


def _dense_layer_fwd(cfg: ModelConfig, p: Params, x, positions):
    h = attn.attend(cfg, p["attn"], rmsnorm(p["ln1"], x), positions)
    x = x + h
    y = rmsnorm(p["ln2"], x)
    y = moe_mod.moe_apply(cfg, p["moe"], y) if "moe" in p else _mlp(p["mlp"], y)
    return x + y


def _dense_layer_decode(cfg: ModelConfig, p: Params, x, cache, cache_len):
    h, new_cache = attn.decode_attend(cfg, p["attn"], rmsnorm(p["ln1"], x),
                                      cache, cache_len)
    x = x + h
    y = rmsnorm(p["ln2"], x)
    y = moe_mod.moe_apply(cfg, p["moe"], y) if "moe" in p else _mlp(p["mlp"], y)
    return x + y, new_cache


def _dense_layer_prefill(cfg: ModelConfig, p: Params, x, positions,
                         cache_size: int):
    xin = rmsnorm(p["ln1"], x)
    cache = attn.prefill_kv(cfg, p["attn"], xin, positions, cache_size)
    h = attn.attend(cfg, p["attn"], xin, positions)
    x = x + h
    y = rmsnorm(p["ln2"], x)
    y = moe_mod.moe_apply(cfg, p["moe"], y) if "moe" in p else _mlp(p["mlp"], y)
    return x + y, cache


# =====================================================================
# decoder-only (dense / vlm / moe)
# =====================================================================

def _moe_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(#scan steps, layers per step). moe_every=2 scans (dense, moe) pairs."""
    if cfg.family == "moe" and cfg.moe_every == 2:
        return cfg.n_layers // 2, 2
    return cfg.n_layers, 1


def init_decoder_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
                 "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab, dtype)

    steps, per = _moe_layout(cfg)
    if cfg.family == "moe":
        if per == 2:   # interleaved: scan body = dense layer + moe layer
            p["layers"] = _stack_init(
                ks[2], steps,
                lambda k: {"dense": _init_dense_layer(cfg, dtype, False)(
                               jax.random.fold_in(k, 0)),
                           "moe": _init_dense_layer(cfg, dtype, True)(
                               jax.random.fold_in(k, 1))})
        else:
            p["layers"] = _stack_init(ks[2], steps,
                                      _init_dense_layer(cfg, dtype, True))
    else:
        p["layers"] = _stack_init(ks[2], steps,
                                  _init_dense_layer(cfg, dtype, False))
    if cfg.frontend == "vit" and cfg.num_patches:
        p["patch_proj"] = dense_init(ks[3], cfg.frontend_dim, cfg.d_model,
                                     dtype)
    return p


def _embed_inputs(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  patches: Optional[jax.Array]) -> jax.Array:
    x = params["embed"][tokens]
    if patches is not None and "patch_proj" in params:
        pe = dense(params["patch_proj"], patches.astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)          # early fusion: prepend
    return x


def _mask_pad_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Vocab is padded to a 128-multiple for TP sharding; mask the pad."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < cfg.vocab, logits, -1e30)


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        out = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        out = x.astype(jnp.float32) @ params["lm_head"]["w"].astype(jnp.float32)
    return _mask_pad_vocab(cfg, out)


def decoder_forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
                    patches: Optional[jax.Array] = None) -> jax.Array:
    """Train/eval forward -> logits (B, L, V)."""
    x = _embed_inputs(cfg, params, tokens, patches)
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))

    steps, per = _moe_layout(cfg)

    def body(xc, lp):
        xc = runtime.constrain_batch(xc)
        if per == 2:
            xc = _dense_layer_fwd(cfg, lp["dense"], xc, positions)
            xc = _dense_layer_fwd(cfg, lp["moe"], xc, positions)
        else:
            xc = _dense_layer_fwd(cfg, lp, xc, positions)
        return xc, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    else:
        for i in range(steps):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x, _ = body(x, lp)
    x = rmsnorm(params["final_norm"], x)
    return _logits(cfg, params, x)


def decoder_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
                    cache_size: int, patches: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prefill: logits of last position + per-layer kv caches (stacked)."""
    x = _embed_inputs(cfg, params, tokens, patches)
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    steps, per = _moe_layout(cfg)
    # sequence parallelism for non-MoE prefill (MoE dispatch shard_maps over
    # the batch layout; see runtime.constrain_seq docstring)
    seq_par = cfg.family != "moe"

    def body(xc, lp):
        xc = (runtime.constrain_seq(xc) if seq_par
              else runtime.constrain_batch(xc))
        if per == 2:
            xc, c1 = _dense_layer_prefill(cfg, lp["dense"], xc, positions,
                                          cache_size)
            xc, c2 = _dense_layer_prefill(cfg, lp["moe"], xc, positions,
                                          cache_size)
            return xc, {"dense": c1, "moe": c2}
        xc, c = _dense_layer_prefill(cfg, lp, xc, positions, cache_size)
        return xc, c

    if cfg.scan_layers:
        x, caches = jax.lax.scan(_remat(cfg, body), x, params["layers"])
    else:
        cs = []
        for i in range(steps):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x, c = body(x, lp)
            cs.append(c)
        caches = jax.tree.map(lambda *t: jnp.stack(t), *cs)
    x = rmsnorm(params["final_norm"], x[:, -1:])
    state = {"cache": caches, "len": jnp.int32(l)}
    return _logits(cfg, params, x), state


def decoder_decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   state: Dict[str, Any]
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. tokens: (B, 1)."""
    x = params["embed"][tokens]
    cache_len = state["len"]
    steps, per = _moe_layout(cfg)

    def body(xc, inp):
        lp, cache = inp
        if per == 2:
            xc, c1 = _dense_layer_decode(cfg, lp["dense"], xc,
                                         cache["dense"], cache_len)
            xc, c2 = _dense_layer_decode(cfg, lp["moe"], xc,
                                         cache["moe"], cache_len)
            return xc, {"dense": c1, "moe": c2}
        xc, c = _dense_layer_decode(cfg, lp, xc, cache, cache_len)
        return xc, c

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, (params["layers"], state["cache"]))
    else:
        cs = []
        for i in range(steps):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            cache = jax.tree.map(lambda t: t[i], state["cache"])
            x, c = body(x, (lp, cache))
            cs.append(c)
        caches = jax.tree.map(lambda *t: jnp.stack(t), *cs)
    x = rmsnorm(params["final_norm"], x)
    return _logits(cfg, params, x), {"cache": caches, "len": cache_len + 1}


# =====================================================================
# zamba2: mamba backbone + shared attention block
# =====================================================================

def _zamba_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - groups * cfg.attn_every
    return groups, cfg.attn_every, tail


def init_zamba_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    groups, per, tail = _zamba_layout(cfg)
    shared = {"ln1": rmsnorm_init(cfg.d_model, dtype),
              "attn": attn.init_attention(ks[0], cfg, dtype),
              "ln2": rmsnorm_init(cfg.d_model, dtype),
              "mlp": _init_mlp(ks[1], cfg, dtype)}
    mamba_init = lambda k: {"ln": rmsnorm_init(cfg.d_model, dtype),
                            "mamba": m2.init_mamba2(k, cfg, dtype)}
    p = {"embed": embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
         "final_norm": rmsnorm_init(cfg.d_model, dtype),
         "lm_head": dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dtype),
         "shared": shared,
         "groups": _stack_init(ks[4], groups,
                               lambda k: _stack_init(k, per, mamba_init))}
    if tail:
        p["tail"] = _stack_init(ks[5], tail, mamba_init)
    return p


def _mamba_block(cfg, lp, x):
    return x + m2.mamba2_forward(cfg, lp["mamba"], rmsnorm(lp["ln"], x))


def _shared_attn_block(cfg, sp, x, positions):
    x = x + attn.attend(cfg, sp["attn"], rmsnorm(sp["ln1"], x), positions)
    return x + _mlp(sp["mlp"], rmsnorm(sp["ln2"], x))


def zamba_forward(cfg: ModelConfig, params: Params, tokens: jax.Array
                  ) -> jax.Array:
    x = params["embed"][tokens]
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    groups, per, tail = _zamba_layout(cfg)
    shared = params["shared"]

    def group_body(xc, gp):
        xc = runtime.constrain_batch(xc)
        def mamba_body(xi, lp):
            return _mamba_block(cfg, lp, runtime.constrain_batch(xi)), None
        xc, _ = jax.lax.scan(mamba_body, xc, gp)
        xc = _shared_attn_block(cfg, shared, xc, positions)
        return xc, None

    x, _ = jax.lax.scan(_remat(cfg, group_body), x, params["groups"])
    if tail:
        def mamba_body(xi, lp):
            return _mamba_block(cfg, lp, runtime.constrain_batch(xi)), None
        x, _ = jax.lax.scan(_remat(cfg, mamba_body), x, params["tail"])
    x = rmsnorm(params["final_norm"], x)
    return _logits(cfg, params, x)


def zamba_init_state(cfg: ModelConfig, batch: int, cache_size: int,
                     dtype) -> Dict[str, Any]:
    groups, per, tail = _zamba_layout(cfg)
    hd = cfg.resolved_head_dim
    mk_mamba = lambda n: jax.tree.map(
        lambda t: jnp.broadcast_to(t, (n,) + t.shape),
        m2.mamba2_init_cache(cfg, batch, dtype))
    attn_cache = {
        "k": jnp.zeros((groups, batch, cfg.n_kv_heads, cache_size, hd), dtype),
        "v": jnp.zeros((groups, batch, cfg.n_kv_heads, cache_size, hd), dtype),
    }
    st = {"groups_mamba": jax.tree.map(
              lambda t: jnp.broadcast_to(t, (groups,) + t.shape),
              mk_mamba(per)),
          "attn": attn_cache, "len": jnp.int32(0)}
    if tail:
        st["tail_mamba"] = mk_mamba(tail)
    return st


def zamba_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  cache_size: int) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence hybrid prefill: chunked-scan mamba blocks with state
    collection + shared-attention kv capture (replaces the sequential
    token-by-token fallback, which cost 32768 serial steps)."""
    x = params["embed"][tokens]
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    groups, per, tail = _zamba_layout(cfg)
    shared = params["shared"]

    def mamba_step(xi, lp):
        h, cache = m2.mamba2_forward(cfg, lp["mamba"],
                                     rmsnorm(lp["ln"], xi), collect=True)
        return xi + h, cache

    def group_body(xc, gp):
        xc = runtime.constrain_batch(xc)
        xc, mcaches = jax.lax.scan(mamba_step, xc, gp)
        xin = rmsnorm(shared["ln1"], xc)
        kv = attn.prefill_kv(cfg, shared["attn"], xin, positions, cache_size)
        xc = xc + attn.attend(cfg, shared["attn"], xin, positions)
        xc = xc + _mlp(shared["mlp"], rmsnorm(shared["ln2"], xc))
        return xc, (mcaches, kv)

    x, (gm, attn_c) = jax.lax.scan(_remat(cfg, group_body), x,
                                   params["groups"])
    state = {"groups_mamba": gm, "attn": attn_c, "len": jnp.int32(l)}
    if tail:
        x, tm = jax.lax.scan(mamba_step, x, params["tail"])
        state["tail_mamba"] = tm
    x = rmsnorm(params["final_norm"], x[:, -1:])
    return _logits(cfg, params, x), state


def zamba_decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 state: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    x = params["embed"][tokens]
    cache_len = state["len"]
    groups, per, tail = _zamba_layout(cfg)
    shared = params["shared"]

    def group_body(xc, inp):
        gp, mcache, acache = inp

        def mamba_step(xi, minp):
            lp, mc = minp
            h, nc = m2.mamba2_decode(cfg, lp["mamba"],
                                     rmsnorm(lp["ln"], xi), mc)
            return xi + h, nc
        xc, new_mcache = jax.lax.scan(mamba_step, xc, (gp, mcache))
        h, new_acache = attn.decode_attend(
            cfg, shared["attn"], rmsnorm(shared["ln1"], xc), acache, cache_len)
        xc = xc + h
        xc = xc + _mlp(shared["mlp"], rmsnorm(shared["ln2"], xc))
        return xc, (new_mcache, new_acache)

    x, (new_gm, new_attn) = jax.lax.scan(
        group_body, x,
        (params["groups"], state["groups_mamba"], state["attn"]))
    new_state = {"groups_mamba": new_gm, "attn": new_attn,
                 "len": cache_len + 1}
    if tail:
        def mamba_step(xi, minp):
            lp, mc = minp
            h, nc = m2.mamba2_decode(cfg, lp["mamba"],
                                     rmsnorm(lp["ln"], xi), mc)
            return xi + h, nc
        x, new_tail = jax.lax.scan(mamba_step, x,
                                   (params["tail"], state["tail_mamba"]))
        new_state["tail_mamba"] = new_tail
    x = rmsnorm(params["final_norm"], x)
    return _logits(cfg, params, x), new_state


# =====================================================================
# rwkv6
# =====================================================================

def init_rwkv_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layer_init = lambda k: {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "time": r6.init_rwkv6_time(jax.random.fold_in(k, 0), cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "chan": r6.init_rwkv6_channel(jax.random.fold_in(k, 1), cfg, dtype),
    }
    return {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
            "ln_in": layernorm_init(cfg.d_model, dtype),
            "final_norm": layernorm_init(cfg.d_model, dtype),
            "lm_head": dense_init(ks[1], cfg.d_model, cfg.padded_vocab, dtype),
            "layers": _stack_init(ks[2], cfg.n_layers, layer_init)}


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    l = cfg.n_layers
    return {
        "time_x": jnp.zeros((l, batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((l, batch, cfg.n_heads, hd, hd), jnp.float32),
        "chan_x": jnp.zeros((l, batch, 1, cfg.d_model), dtype),
        "len": jnp.int32(0),
    }


def rwkv_forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 state: Optional[Dict[str, Any]] = None, collect: bool = False):
    """Full-sequence forward; optionally threads/returns recurrent state."""
    x = layernorm(params["ln_in"], params["embed"][tokens])
    b = x.shape[0]
    if state is None:
        state = rwkv_init_state(cfg, b, x.dtype)

    def body(xc, inp):
        xc = runtime.constrain_batch(xc)
        lp, tx, wkv, cx = inp
        h, ntx, nwkv = r6.rwkv6_time_mix(cfg, lp["time"],
                                         layernorm(lp["ln1"], xc), tx, wkv)
        xc = xc + h
        h, ncx = r6.rwkv6_channel_mix(cfg, lp["chan"],
                                      layernorm(lp["ln2"], xc), cx)
        return xc + h, (ntx, nwkv, ncx)

    x, (ntx, nwkv, ncx) = jax.lax.scan(
        _remat(cfg, body), x,
        (params["layers"], state["time_x"], state["wkv"], state["chan_x"]))
    x = layernorm(params["final_norm"], x)
    logits = _logits(cfg, params, x)
    if collect:
        new_state = {"time_x": ntx, "wkv": nwkv, "chan_x": ncx,
                     "len": state["len"] + tokens.shape[1]}
        return logits, new_state
    return logits


def rwkv_decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
                state: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    logits, new_state = rwkv_forward(cfg, params, tokens, state, collect=True)
    return logits, new_state


# =====================================================================
# whisper (enc-dec)
# =====================================================================

def _sinusoidal(l: int, d: int) -> jax.Array:
    pos = jnp.arange(l, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    freq = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encdec_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    enc_init = lambda k: {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "attn": attn.init_attention(jax.random.fold_in(k, 0), cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": {"up": dense_init(jax.random.fold_in(k, 1), cfg.d_model,
                                 cfg.d_ff, dtype, bias=True),
                "down": dense_init(jax.random.fold_in(k, 2), cfg.d_ff,
                                   cfg.d_model, dtype, bias=True)}}
    dec_init = lambda k: {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "self": attn.init_attention(jax.random.fold_in(k, 0), cfg, dtype),
        "ln_x": layernorm_init(cfg.d_model, dtype),
        "cross": attn.init_attention(jax.random.fold_in(k, 1), cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": {"up": dense_init(jax.random.fold_in(k, 2), cfg.d_model,
                                 cfg.d_ff, dtype, bias=True),
                "down": dense_init(jax.random.fold_in(k, 3), cfg.d_ff,
                                   cfg.d_model, dtype, bias=True)}}
    return {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
            "enc_layers": _stack_init(ks[1], cfg.enc_layers, enc_init),
            "enc_norm": layernorm_init(cfg.d_model, dtype),
            "dec_layers": _stack_init(ks[2], cfg.dec_layers, dec_init),
            "dec_norm": layernorm_init(cfg.d_model, dtype)}


def _ff(p, x):
    return dense(p["down"], gelu(dense(p["up"], x)))


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, T, D) stubbed conv-frontend output."""
    b, t, d = frames.shape
    dtype = params["enc_norm"]["scale"].dtype    # model compute dtype
    frames = frames.astype(dtype)
    x = frames + _sinusoidal(t, d).astype(dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(xc, lp):
        xc = runtime.constrain_batch(xc)
        h = attn.attend(cfg, lp["attn"], layernorm(lp["ln1"], xc), positions,
                        causal=False, rope=False)
        xc = xc + h
        return xc + _ff(lp["mlp"], layernorm(lp["ln2"], xc)), None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_layers"])
    return layernorm(params["enc_norm"], x)


def encdec_forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   frames: jax.Array) -> jax.Array:
    enc = encode(cfg, params, frames)
    b, l = tokens.shape
    x = params["embed"][tokens] + _sinusoidal(l, cfg.d_model).astype(
        params["embed"].dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))

    def body(xc, lp):
        xc = runtime.constrain_seq(xc)
        h = attn.attend(cfg, lp["self"], layernorm(lp["ln1"], xc), positions,
                        causal=True, rope=False)
        xc = xc + h
        # cross attention: kv from encoder output
        kx = layernorm(lp["ln_x"], xc)
        k = dense(lp["cross"]["wk"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        v = dense(lp["cross"]["wv"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        h = attn.attend(cfg, lp["cross"], kx, positions, causal=False,
                        kv_override=(k, v), rope=False)
        xc = xc + h
        return xc + _ff(lp["mlp"], layernorm(lp["ln2"], xc)), None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x)
    return _mask_pad_vocab(
        cfg, x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32))


def encdec_init_state(cfg: ModelConfig, params: Params, frames: jax.Array,
                      cache_size: int) -> Dict[str, Any]:
    """Precompute encoder output + cross-kv; empty self-attn caches."""
    enc = encode(cfg, params, frames)
    b = enc.shape[0]
    hd = cfg.resolved_head_dim

    def cross_kv(lp):
        k = dense(lp["cross"]["wk"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        v = dense(lp["cross"]["wv"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        return {"k": k, "v": v}

    cross = jax.tree.map(lambda *t: jnp.stack(t),
                         *[cross_kv(jax.tree.map(lambda q: q[i],
                                                 params["dec_layers"]))
                           for i in range(cfg.dec_layers)])
    selfc = {"k": jnp.zeros((cfg.dec_layers, b, cfg.n_kv_heads, cache_size,
                             hd), enc.dtype),
             "v": jnp.zeros((cfg.dec_layers, b, cfg.n_kv_heads, cache_size,
                             hd), enc.dtype)}
    return {"cross": cross, "self": selfc, "len": jnp.int32(0)}


def encdec_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   frames: jax.Array, cache_size: int
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the decoder over the whole prompt, capturing self-attn kv."""
    state = encdec_init_state(cfg, params, frames, cache_size)
    enc = encode(cfg, params, frames)
    b, l = tokens.shape
    x = params["embed"][tokens] + _sinusoidal(l, cfg.d_model).astype(
        params["embed"].dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))

    def body(xc, lp):
        xc = runtime.constrain_seq(xc)
        xin = layernorm(lp["ln1"], xc)
        cache = attn.prefill_kv(cfg, lp["self"], xin, positions, cache_size,
                                rope=False)
        h = attn.attend(cfg, lp["self"], xin, positions, causal=True,
                        rope=False)
        xc = xc + h
        kx = layernorm(lp["ln_x"], xc)
        k = dense(lp["cross"]["wk"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        v = dense(lp["cross"]["wv"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, -1).transpose(0, 2, 1, 3)
        h = attn.attend(cfg, lp["cross"], kx, positions, causal=False,
                        kv_override=(k, v), rope=False)
        xc = xc + h
        return xc + _ff(lp["mlp"], layernorm(lp["ln2"], xc)), cache

    x, selfc = jax.lax.scan(_remat(cfg, body), x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x[:, -1:])
    logits = _mask_pad_vocab(
        cfg, x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32))
    return logits, {"cross": state["cross"], "self": selfc,
                    "len": jnp.int32(l)}


def encdec_decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  state: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    b = tokens.shape[0]
    cache_len = state["len"]
    # sinusoidal position embedding at the (traced) decode position
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    freq = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = cache_len.astype(jnp.float32) * freq
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = params["embed"][tokens] + pos_emb.astype(params["embed"].dtype)[None]

    def body(xc, inp):
        lp, sc, xc_kv = inp
        h, nsc = attn.decode_attend(cfg, lp["self"],
                                    layernorm(lp["ln1"], xc), sc, cache_len,
                                    rope=False)
        xc = xc + h
        kx = layernorm(lp["ln_x"], xc)
        enc_len = jnp.full((b,), xc_kv["k"].shape[2], jnp.int32)
        from ..kernels.decode_attention.ref import decode_attention_ref
        q = dense(lp["cross"]["wq"], kx).reshape(b, cfg.n_heads, -1)
        o = decode_attention_ref(q, xc_kv["k"], xc_kv["v"], enc_len)
        xc = xc + dense(lp["cross"]["wo"], o.reshape(b, 1, -1))
        return xc + _ff(lp["mlp"], layernorm(lp["ln2"], xc)), nsc

    x, nself = jax.lax.scan(body, x,
                            (params["dec_layers"], state["self"],
                             state["cross"]))
    x = layernorm(params["dec_norm"], x)
    logits = _mask_pad_vocab(
        cfg, x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32))
    return logits, {"cross": state["cross"], "self": nself,
                    "len": cache_len + 1}
