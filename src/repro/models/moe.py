"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch is the sort/ragged formulation (not the (T,E,C) one-hot einsum,
which is O(T^2 k) memory at pod batch sizes): assignments are sorted by
expert, each expert's first C tokens are scattered into an (E, C, D) buffer
(token-order priority, overflow dropped — standard capacity dropping), the
expert SwiGLU runs as one batched einsum over E, and results gather back
weighted by router probabilities.  Experts shard over the "model" mesh axis
(EP); the sort/scatter lowers to all_to_all under GSPMD.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map as _shard_map
from ..configs.base import ModelConfig
from .layers import Params, dense_init, swiglu


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                   * scale).astype(jnp.float32),       # router kept f32
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if cfg.shared_expert:
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(sks[0], d, f, dtype),
            "up": dense_init(sks[1], d, f, dtype),
            "down": dense_init(sks[2], f, d, dtype),
        }
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)      # round up to a multiple of 4


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """x: (B, L, D) -> (B, L, D).  Routed (+ shared) expert output.

    With an ambient mesh (runtime.set_mesh) this takes the explicit
    shard_map expert-parallel path; otherwise the single-device path."""
    from . import runtime
    if runtime.get_mesh() is not None:
        return moe_apply_sharded(cfg, p, x, runtime.get_mesh(),
                                 runtime.dp_axes(), runtime.tp_axis())
    return _moe_apply_local(cfg, p, x)


def _moe_apply_local(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, l, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * l
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- cumsum-ranked dispatch (sort-free) -----------------------------
    # position_in_expert via exclusive cumsum of assignment one-hots.
    # A global argsort here costs thousands of collective-permutes under
    # GSPMD; the cumsum ranks with one small prefix-scan instead.
    flat_e = top_e.reshape(t * k)                            # (Tk,) token-major
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (Tk, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot            # exclusive
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]

    cap = _capacity(t, cfg)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, slot].set(xt[flat_tok], mode="drop")  # (E, C, D)

    # ---- expert computation (one batched einsum per matrix) ------------
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = swiglu(h_gate, h_up)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, C, D)

    # ---- combine --------------------------------------------------------
    y_flat = y_buf.at[flat_e, slot].get(mode="fill",
                                        fill_value=0)        # (Tk, D)
    y_flat = jnp.where(keep[:, None], y_flat, 0).reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", y_flat.astype(jnp.float32),
                     top_p).astype(x.dtype)

    if "shared" in p:
        s = p["shared"]
        shared = swiglu(xt @ s["gate"]["w"], xt @ s["up"]["w"]) @ s["down"]["w"]
        out = out + shared
    return out.reshape(b, l, d)


def moe_apply_sharded(cfg: ModelConfig, p: Params, x: jax.Array, mesh,
                      dp_axes, tp_axis: str) -> jax.Array:
    """Expert-parallel MoE as an explicit shard_map region.

    Plain GSPMD lowering of token dispatch (global gathers/cumsum over all
    tokens) replicates activations across the mesh and drags the whole
    layer's layouts with it (observed: 10x flops + 500 GiB collectives per
    step on the 256-chip dry-run).  Here instead:

      * routing + capacity ranking are LOCAL to each data shard (zero comm);
      * expert weights stay (E over tp) x (D over dp=FSDP); the dp shards
        all_gather their weight slice (the FSDP gather GSPMD would emit
        anyway) and each tp shard computes only its own E/tp experts;
      * each tp shard combines its experts' outputs for local tokens; one
        psum over tp completes the token outputs (bytes: T_local x D —
        thousands of times smaller than the auto-partitioned lowering);
      * the shared expert (llama4) runs megatron-style on the same psum.
    """
    import functools
    from jax.sharding import PartitionSpec as P

    e, k, d, f = cfg.num_experts, cfg.top_k, cfg.d_model, cfg.d_ff
    tp = mesh.shape[tp_axis]
    e_per = e // tp
    has_shared = "shared" in p

    in_specs = [P(dp_axes, None, None),                 # x
                P(),                                    # router (replicated)
                P(tp_axis, dp_axes, None),              # w_gate (E, D, F)
                P(tp_axis, dp_axes, None),              # w_up
                P(tp_axis, None, dp_axes)]              # w_down (E, F, D)
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if has_shared:
        in_specs += [P(dp_axes, tp_axis), P(dp_axes, tp_axis),
                     P(tp_axis, dp_axes)]
        args += [p["shared"]["gate"]["w"], p["shared"]["up"]["w"],
                 p["shared"]["down"]["w"]]

    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    t_global = x.shape[0] * x.shape[1]
    # decode / tiny-batch: moving the FSDP-gathered expert weights costs
    # GB/step while all tokens fit in MB — route tokens instead (replicate
    # tokens, partial contractions against the *resident* weight shards,
    # psum).  Measured on llama4 decode_32k: 99 GiB -> ~0.2 GiB per step.
    if t_global * max(k, 1) <= 4096:
        return _moe_small_batch(cfg, p, x, mesh, dp_axes, tp_axis, dp_size)

    def inner(x_l, router, wg, wu, wd, *shared_w):
        b_l, l_l, _ = x_l.shape
        t = b_l * l_l
        xt = x_l.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(
            jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

        # local cumsum ranking + capacity (per data shard)
        flat_e = top_e.reshape(t * k)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
        cap = _capacity(t, cfg)
        keep = pos < cap
        slot = jnp.where(keep, pos, cap)

        # build ONLY the owned expert slice: a replicated full buffer would
        # need its (E, C, D) cotangent all-reduced over tp in the backward
        # pass (observed 60 GiB/step); the owned slice keeps bwd local and
        # the d_xt psum is just (T_local, D).
        my0 = jax.lax.axis_index(tp_axis) * e_per
        owned = (flat_e >= my0) & (flat_e < my0 + e_per) & keep
        rel = jnp.clip(flat_e - my0, 0, e_per - 1)
        my_buf = jnp.zeros((e_per, cap, d), x.dtype)
        my_buf = my_buf.at[jnp.where(owned, rel, e_per), slot].set(
            xt[flat_tok], mode="drop")

        # FSDP weight gather (dp axis)
        wg_full = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
        wu_full = jax.lax.all_gather(wu, dp_axes, axis=1, tiled=True)
        wd_full = jax.lax.all_gather(wd, dp_axes, axis=2, tiled=True)

        h = swiglu(jnp.einsum("ecd,edf->ecf", my_buf, wg_full),
                   jnp.einsum("ecd,edf->ecf", my_buf, wu_full))
        y_my = jnp.einsum("ecf,efd->ecd", h, wd_full)   # (E/tp, C, D)

        # local combine of owned experts' outputs
        vals = y_my.at[rel, slot].get(mode="fill", fill_value=0)
        vals = jnp.where(owned[:, None], vals, 0).reshape(t, k, d)
        y = jnp.einsum("tkd,tk->td", vals.astype(jnp.float32), top_p)

        if shared_w:
            sg, su, sd = shared_w
            sg = jax.lax.all_gather(sg, dp_axes, axis=0, tiled=True)
            su = jax.lax.all_gather(su, dp_axes, axis=0, tiled=True)
            sd = jax.lax.all_gather(sd, dp_axes, axis=1, tiled=True)
            hs = swiglu(xt @ sg, xt @ su)                # F/tp local
            y = y + (hs @ sd).astype(jnp.float32)        # partial over tp

        y = jax.lax.psum(y.astype(jnp.float32), tp_axis)
        return y.astype(x.dtype).reshape(b_l, l_l, d)

    fn = _shard_map(inner, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=P(dp_axes, None, None))
    return fn(*args)


def _moe_small_batch(cfg: ModelConfig, p: Params, x: jax.Array, mesh,
                     dp_axes, tp_axis: str, dp_size: int) -> jax.Array:
    """Token-routed MoE for decode-scale batches: weights never move.

    Tokens are all_gathered over dp (MBs); every (dp, tp) cell computes the
    partial expert contraction against its RESIDENT weight shard
    (E/tp experts x D/dp rows); psum over dp completes the contraction,
    psum over tp combines expert outputs; a final dp all_gather reassembles
    the D dimension."""
    import functools
    from jax.sharding import PartitionSpec as P

    e, k, d, f = cfg.num_experts, cfg.top_k, cfg.d_model, cfg.d_ff
    tp = mesh.shape[tp_axis]
    e_per = e // tp
    d_per = d // dp_size
    has_shared = "shared" in p

    in_specs = [P(dp_axes, None, None), P(),
                P(tp_axis, dp_axes, None), P(tp_axis, dp_axes, None),
                P(tp_axis, None, dp_axes)]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if has_shared:
        in_specs += [P(dp_axes, tp_axis), P(dp_axes, tp_axis),
                     P(tp_axis, dp_axes)]
        args += [p["shared"]["gate"]["w"], p["shared"]["up"]["w"],
                 p["shared"]["down"]["w"]]

    def inner(x_l, router, wg, wu, wd, *shared_w):
        b_l, l_l, _ = x_l.shape
        t_loc = b_l * l_l
        xt = jax.lax.all_gather(x_l.reshape(t_loc, d), dp_axes, axis=0,
                                tiled=True)               # (T, D) replicated
        t = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(
            jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

        flat_e = top_e.reshape(t * k)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                  flat_e[:, None], axis=1)[:, 0]
        cap = _capacity(t, cfg)
        keep = pos < cap
        slot = jnp.where(keep, pos, cap)
        my0 = jax.lax.axis_index(tp_axis) * e_per
        owned = (flat_e >= my0) & (flat_e < my0 + e_per) & keep
        rel = jnp.clip(flat_e - my0, 0, e_per - 1)
        my_buf = jnp.zeros((e_per, cap, d), x.dtype)
        my_buf = my_buf.at[jnp.where(owned, rel, e_per), slot].set(
            xt[flat_tok].astype(x.dtype), mode="drop")

        # partial contraction over the local D/dp slice — weights resident
        dp_idx = jax.lax.axis_index(dp_axes)              # linear over dp
        d_lo = dp_idx * d_per
        buf_slice = jax.lax.dynamic_slice_in_dim(my_buf, d_lo, d_per, axis=2)
        gate = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf_slice.astype(jnp.float32),
                       wg.astype(jnp.float32)), dp_axes)
        up = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf_slice.astype(jnp.float32),
                       wu.astype(jnp.float32)), dp_axes)
        h = swiglu(gate, up)
        y_p = jnp.einsum("ecf,efd->ecd", h, wd.astype(jnp.float32))
        # (E/tp, cap, D/dp): output D-slice per dp shard

        vals = y_p.at[rel, slot].get(mode="fill", fill_value=0)
        vals = jnp.where(owned[:, None], vals, 0).reshape(t, k, d_per)
        y = jnp.einsum("tkd,tk->td", vals, top_p)          # (T, D/dp)

        if shared_w:
            sg, su, sd = shared_w                          # (D/dp, F/tp)...
            x_slice = jax.lax.dynamic_slice_in_dim(xt, d_lo, d_per, axis=1)
            hs_g = jax.lax.psum(x_slice.astype(jnp.float32)
                                @ sg.astype(jnp.float32), dp_axes)
            hs_u = jax.lax.psum(x_slice.astype(jnp.float32)
                                @ su.astype(jnp.float32), dp_axes)
            hs = swiglu(hs_g, hs_u)                        # (T, F/tp)
            y = y + hs @ sd.astype(jnp.float32)            # (T, D/dp) partial
        y = jax.lax.psum(y, tp_axis)                       # (T, D/dp) exact
        y_full = jax.lax.all_gather(y, dp_axes, axis=1, tiled=True)  # (T, D)
        mine = jax.lax.dynamic_slice_in_dim(
            y_full, dp_idx * t_loc, t_loc, axis=0)
        return mine.astype(x.dtype).reshape(b_l, l_l, d)

    fn = _shard_map(inner, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=P(dp_axes, None, None))
    return fn(*args)


def aux_load_balance_loss(cfg: ModelConfig, x: jax.Array, p: Params
                          ) -> jax.Array:
    """Switch-style load-balance auxiliary (mean prob x mean assignment)."""
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.num_experts), axis=0)
    return cfg.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
