"""Hash pipeline shared by host (numpy) build and device (jnp) lookup.

Everything on-device is 32-bit (TPUs have no native int64 vector lanes).
Entity strings are hashed on host (FNV-1a 64 folded to 32); from that single
uint32 the device derives fingerprint and both candidate buckets, exactly as
the paper's Eq. (1):   i1 = h(x),  i2 = i1 XOR h(f(x)).

The same bit-level functions run under numpy and jax.numpy so the host-built
tables and the device lookup can never disagree.
"""
from __future__ import annotations

import numpy as np

FP_BITS = 12                       # paper: 12-bit fingerprints
FP_MASK = (1 << FP_BITS) - 1
EMPTY_FP = 0                       # slot sentinel; real fps are remapped off 0

_GOLDEN = 0x9E3779B9               # 32-bit golden-ratio constant


def fnv1a_64(s: str) -> int:
    """Host-side 64-bit FNV-1a over UTF-8 bytes, folded to 32 bits."""
    h = 0xCBF29CE484222325
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return (h ^ (h >> 32)) & 0xFFFFFFFF


def entity_hash(s: str) -> np.uint32:
    return np.uint32(fnv1a_64(s))


def hash_entities(names) -> np.ndarray:
    """Batched FNV-1a: sequential over byte position, vectorized over
    names — bit-identical to ``fnv1a_64`` per string (the bulk index/bank
    builds hash every entity in one shot through here)."""
    names = list(names)
    if not names:
        return np.zeros(0, dtype=np.uint32)
    bs = [n.encode("utf-8") for n in names]
    lens = np.asarray([len(b) for b in bs], dtype=np.int64)
    offsets = np.zeros(len(bs) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    flat = np.frombuffer(b"".join(bs), dtype=np.uint8).astype(np.uint64)
    h = np.full(len(bs), 0xCBF29CE484222325, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for j in range(int(lens.max()) if lens.size else 0):
            idx = np.minimum(offsets[:-1] + j, max(flat.size - 1, 0))
            step = (h ^ flat[idx]) * np.uint64(0x100000001B3)
            h = np.where(j < lens, step, h)
        return ((h ^ (h >> np.uint64(32)))
                & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _mix(h, xp):
    """splitmix32 finalizer — works for numpy and jnp uint32 arrays."""
    if xp is np:   # numpy warns on (intentional) wrapping scalar multiplies
        h = np.asarray(h, dtype=np.uint32)
        with np.errstate(over="ignore"):
            h = (h ^ (h >> np.uint32(16))) * np.uint32(0x7FEB352D)
            h = (h ^ (h >> np.uint32(15))) * np.uint32(0x846CA68B)
            return h ^ (h >> np.uint32(16))
    h = h.astype(xp.uint32)
    h = (h ^ (h >> xp.uint32(16))) * xp.uint32(0x7FEB352D)
    h = (h ^ (h >> xp.uint32(15))) * xp.uint32(0x846CA68B)
    return h ^ (h >> xp.uint32(16))


def fingerprint(h, xp=np):
    """12-bit fingerprint from the entity hash; 0 is reserved for 'empty'."""
    fp = _mix(h ^ xp.uint32(_GOLDEN), xp) & xp.uint32(FP_MASK)
    return xp.where(fp == xp.uint32(EMPTY_FP), xp.uint32(1), fp).astype(xp.uint32)


def bucket_i1(h, num_buckets: int, xp=np):
    """Primary bucket index. num_buckets must be a power of two."""
    return (_mix(h, xp) & xp.uint32(num_buckets - 1)).astype(xp.uint32)


def alt_bucket(i, fp, num_buckets: int, xp=np):
    """i2 = i XOR h(fp)  (also maps i2 -> i1: involution, as in Fan et al.)."""
    return ((i.astype(xp.uint32) ^ _mix(fp.astype(xp.uint32), xp))
            & xp.uint32(num_buckets - 1)).astype(xp.uint32)


def candidate_buckets(h, num_buckets: int, xp=np):
    """(fp, i1, i2) for a batch of entity hashes."""
    fp = fingerprint(h, xp)
    i1 = bucket_i1(h, num_buckets, xp)
    i2 = alt_bucket(i1, fp, num_buckets, xp)
    return fp, i1, i2


# --- masked (per-element bucket count) variants ------------------------------
#
# The ragged bucket arena gives every tree its own power-of-two bucket count,
# so batched hash arithmetic carries a *vector* of bucket masks (nb_t - 1)
# instead of one scalar NB.  Bit-identical to the scalar forms when every
# element's mask equals ``num_buckets - 1``.

def bucket_i1_masked(h, mask, xp=np):
    """Primary bucket index with a per-element mask ``nb - 1`` (uint32)."""
    return (_mix(h, xp) & mask.astype(xp.uint32)).astype(xp.uint32)


def alt_bucket_masked(i, fp, mask, xp=np):
    """Per-element-mask form of :func:`alt_bucket` (same involution)."""
    return ((i.astype(xp.uint32) ^ _mix(fp.astype(xp.uint32), xp))
            & mask.astype(xp.uint32)).astype(xp.uint32)


def candidate_buckets_masked(h, mask, xp=np):
    """(fp, i1, i2) with a per-element bucket mask ``nb - 1``."""
    fp = fingerprint(h, xp)
    i1 = bucket_i1_masked(h, mask, xp)
    i2 = alt_bucket_masked(i1, fp, mask, xp)
    return fp, i1, i2


# --- Bloom-filter hashing (baselines) ---------------------------------------

def bloom_bit_positions(h, m_bits: int, k: int, xp=np):
    """k bit positions via double hashing h1 + j*h2 (Kirsch-Mitzenmacher)."""
    h1 = _mix(h, xp)
    h2 = _mix(h ^ xp.uint32(0xDEADBEEF), xp) | xp.uint32(1)
    js = xp.arange(k, dtype=xp.uint32)
    if hasattr(h1, "ndim") and getattr(h1, "ndim", 0) > 0:
        pos = h1[..., None] + js * h2[..., None]
    else:
        pos = h1 + js * h2
    return (pos % xp.uint32(m_bits)).astype(xp.uint32)
