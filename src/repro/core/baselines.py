"""Baseline retrievers from the paper's §4.1.

* ``NaiveTRAG``     — BFS over every tree per query entity (no filtering).
* ``BloomTRAG``     — a Bloom filter at every node summarizing its subtree's
                      entity set; BFS prunes children whose filter says absent.
* ``BloomTRAG2``    — improved: skip Bloom checks at nodes just above the
                      leaf level (direct compare on leaf children instead).

All three are host-side reference algorithms (the paper benchmarks them as
CPU data structures); they share the EntityForest arrays with CFT-RAG so the
comparison experiment (benchmarks/bench_table1.py) is apples-to-apples.
"""
from __future__ import annotations

from collections import deque
from typing import List, Sequence, Tuple

import numpy as np

from . import hashing
from .context import EntityContext, generate_context
from .tree import EntityForest

Location = Tuple[int, int]


class NaiveTRAG:
    """Paper baseline 1: full BFS from every root for each query entity."""

    def __init__(self, forest: EntityForest):
        self.forest = forest

    def locate(self, name: str) -> List[Location]:
        f = self.forest
        target = f.name_to_id.get(name, -1)
        out: List[Location] = []
        for root in f.roots:
            q = deque([int(root)])
            while q:
                g = q.popleft()
                if int(f.entity_id[g]) == target:
                    out.append((int(f.tree_id[g]), g))
                lo, hi = f.child_offsets[g], f.child_offsets[g + 1]
                q.extend(int(c) for c in f.child_index[lo:hi])
        return out

    def retrieve(self, names: Sequence[str], n: int = 3) -> List[EntityContext]:
        return [generate_context(self.forest, self.forest.name_to_id.get(nm, -1),
                                 self.locate(nm), n=n) for nm in names]


class BloomTRAG:
    """Paper baseline 2: per-node subtree Bloom filters prune the BFS."""

    #: bits per node filter and number of hash probes
    M_BITS = 256
    K = 4

    def __init__(self, forest: EntityForest, m_bits: int = M_BITS, k: int = K):
        self.forest = forest
        self.m_bits = m_bits
        self.k = k
        self._words = m_bits // 64
        self._entity_hash = hashing.hash_entities(forest.entity_names)
        self.bits = self._build()

    # --------------------------------------------------------------- build
    def _entity_mask(self, eid: int) -> np.ndarray:
        """64-bit-word bitmask for one entity's k bloom positions."""
        pos = hashing.bloom_bit_positions(self._entity_hash[eid],
                                          self.m_bits, self.k)
        mask = np.zeros(self._words, dtype=np.uint64)
        for p in np.atleast_1d(pos):
            mask[int(p) // 64] |= np.uint64(1) << np.uint64(int(p) % 64)
        return mask

    def _build(self) -> np.ndarray:
        f = self.forest
        n = f.num_nodes
        bits = np.zeros((n, self._words), dtype=np.uint64)
        # bottom-up: process nodes in reverse BFS order (children first)
        order: List[int] = []
        q = deque(int(r) for r in f.roots)
        while q:
            g = q.popleft()
            order.append(g)
            lo, hi = f.child_offsets[g], f.child_offsets[g + 1]
            q.extend(int(c) for c in f.child_index[lo:hi])
        for g in reversed(order):
            bits[g] |= self._entity_mask(int(f.entity_id[g]))
            lo, hi = f.child_offsets[g], f.child_offsets[g + 1]
            for c in f.child_index[lo:hi]:
                bits[g] |= bits[c]
        return bits

    # --------------------------------------------------------------- query
    def _may_contain(self, node: int, mask: np.ndarray) -> bool:
        return bool(np.all((self.bits[node] & mask) == mask))

    def locate(self, name: str) -> List[Location]:
        f = self.forest
        target = f.name_to_id.get(name, -1)
        if target < 0:
            return []
        mask = self._entity_mask(target)
        out: List[Location] = []
        for root in f.roots:
            root = int(root)
            if not self._may_contain(root, mask):
                continue
            q = deque([root])
            while q:
                g = q.popleft()
                if int(f.entity_id[g]) == target:
                    out.append((int(f.tree_id[g]), g))
                lo, hi = f.child_offsets[g], f.child_offsets[g + 1]
                for c in f.child_index[lo:hi]:
                    if self._may_contain(int(c), mask):
                        q.append(int(c))
        return out

    def retrieve(self, names: Sequence[str], n: int = 3) -> List[EntityContext]:
        return [generate_context(self.forest, self.forest.name_to_id.get(nm, -1),
                                 self.locate(nm), n=n) for nm in names]


class BloomTRAG2(BloomTRAG):
    """Paper baseline 3: as BloomTRAG, but nodes whose children are leaves
    skip the children's Bloom checks — a direct entity compare on a leaf is
    cheaper than a filter probe."""

    def __init__(self, forest: EntityForest, m_bits: int = BloomTRAG.M_BITS,
                 k: int = BloomTRAG.K):
        super().__init__(forest, m_bits, k)
        counts = np.diff(forest.child_offsets)
        self._is_leaf = counts == 0

    def locate(self, name: str) -> List[Location]:
        f = self.forest
        target = f.name_to_id.get(name, -1)
        if target < 0:
            return []
        mask = self._entity_mask(target)
        out: List[Location] = []
        for root in f.roots:
            root = int(root)
            if not self._may_contain(root, mask):
                continue
            q = deque([root])
            while q:
                g = q.popleft()
                if int(f.entity_id[g]) == target:
                    out.append((int(f.tree_id[g]), g))
                lo, hi = f.child_offsets[g], f.child_offsets[g + 1]
                for c in f.child_index[lo:hi]:
                    c = int(c)
                    if self._is_leaf[c]:
                        # skip the Bloom probe just above the leaf level:
                        # compare directly, never enqueue (leaves end paths)
                        if int(f.entity_id[c]) == target:
                            out.append((int(f.tree_id[c]), c))
                    elif self._may_contain(c, mask):
                        q.append(c)
        return out
