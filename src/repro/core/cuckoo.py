"""Improved Cuckoo Filter — the paper's core data structure.

Host side (numpy): partial-key cuckoo insertion with random-kick eviction
(Algorithm 1), deletion (Algorithm 2), load-factor-triggered power-of-two
expansion — the offline build path, exactly as the paper keeps filter
construction outside the query hot loop.

Device side: the tables are dense arrays (fingerprints / temperature / head
pointers per bucket slot) shipped to the accelerator; batched lookup lives in
``lookup_batch`` (pure jnp reference) and ``repro.kernels.cuckoo_lookup``
(Pallas TPU kernel with identical semantics).

Each bucket slot stores, per the paper (§3.1): the entity's 12-bit
fingerprint, its temperature, and the head pointer of its block linked list.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from . import hashing
from .blocklist import BlockListArena, BlockListBuilder, CSRArena, build_csr
from .tree import EntityForest

NULL = -1
DEFAULT_SLOTS = 4                  # paper: 4 fingerprints per bucket
DEFAULT_MAX_KICKS = 500
DEFAULT_LOAD_THRESHOLD = 0.95      # expand beyond this


def bulk_place(fingerprints: np.ndarray, temperature: np.ndarray,
               heads: np.ndarray, entity_ids: np.ndarray,
               stored_hash: np.ndarray, fp: np.ndarray, b1: np.ndarray,
               b2: np.ndarray, new_heads: np.ndarray, new_eids: np.ndarray,
               new_hashes: np.ndarray, nb: int, rng,
               max_rounds: int = 48,
               new_temps: Optional[np.ndarray] = None,
               row_base: Optional[np.ndarray] = None,
               row_mask: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, ...]:
    """Vectorized cuckoo placement into flat ``(num_rows, S)`` tables.

    Rows may be a single filter's buckets, a whole uniform filter bank
    flattened to ``tree * NB + bucket``, or a ragged bucket arena — the
    routine only sees row indices.  A victim's alternate bucket is computed
    within its own filter's row range: for the uniform layouts ``nb``
    (per-filter bucket count) locates the range as ``(row // nb) * nb``;
    for a ragged arena the caller passes ``row_base``/``row_mask`` — per
    arena-row segment start and bucket mask ``nb_t - 1`` — and ``nb`` is
    ignored for rehoming.

    Each round: items grouped by candidate bucket claim that bucket's free
    slots by within-group rank (one fancy-indexed write for all of them);
    round 0 survivors retry their second choice; later survivors run a
    vectorized eviction — one leader per bucket swaps with a random victim
    slot, the victim re-enters the pool at its partner bucket (temperature
    rides along) and non-leaders flip to their other bucket.  Returns
    ``(heads, eids, hashes, temps)`` of the items still homeless after
    ``max_rounds`` — the scalar-fallback remainder, ~empty below the
    expansion load threshold.  ``new_temps`` seeds the incoming items'
    temperatures (restage path: live slots keep their heat); default 0.
    """
    pool_fp = np.asarray(fp, np.uint32).copy()
    pool_head = np.asarray(new_heads, np.int32).copy()
    pool_eid = np.asarray(new_eids, np.int32).copy()
    pool_hash = np.asarray(new_hashes, np.uint32).copy()
    pool_temp = (np.zeros(pool_fp.shape[0], np.int32) if new_temps is None
                 else np.asarray(new_temps, np.int32).copy())
    bucket = np.asarray(b1, np.int64).copy()
    other = np.asarray(b2, np.int64).copy()
    slots = fingerprints.shape[1]

    for rnd in range(max_rounds):
        if pool_fp.size == 0:
            break
        # ---- empty-slot pass at each item's current candidate bucket
        occupied = fingerprints != hashing.EMPTY_FP            # (rows, S)
        # k-th free slot of each row: stable argsort floats empties first
        free_pos = np.argsort(occupied, axis=1, kind="stable")
        free_cnt = (~occupied).sum(axis=1)
        order = np.argsort(bucket, kind="stable")
        bs = bucket[order]
        starts = np.flatnonzero(np.r_[True, bs[1:] != bs[:-1]])
        run_len = np.diff(np.append(starts, bs.size))
        rank = np.arange(bs.size) - np.repeat(starts, run_len)
        fits = rank < free_cnt[bs]
        rows = bs[fits]
        ss = free_pos[rows, rank[fits]]
        sel = order[fits]
        fingerprints[rows, ss] = pool_fp[sel]
        temperature[rows, ss] = pool_temp[sel]
        heads[rows, ss] = pool_head[sel]
        entity_ids[rows, ss] = pool_eid[sel]
        stored_hash[rows, ss] = pool_hash[sel]
        keep = order[~fits]
        pool_fp, pool_head = pool_fp[keep], pool_head[keep]
        pool_eid, pool_hash = pool_eid[keep], pool_hash[keep]
        pool_temp = pool_temp[keep]
        bucket, other = bucket[keep], other[keep]
        if pool_fp.size == 0:
            break
        if rnd == 0:                   # try every item's second choice once
            bucket, other = other, bucket
            continue
        # ---- vectorized eviction (survivor buckets are provably full)
        order = np.argsort(bucket, kind="stable")
        bs = bucket[order]
        is_lead = np.r_[True, bs[1:] != bs[:-1]]
        lead = order[is_lead]
        lb = bucket[lead]
        s = rng.integers(0, slots, size=lb.size)
        v = (fingerprints[lb, s].copy(), temperature[lb, s].copy(),
             heads[lb, s].copy(), entity_ids[lb, s].copy(),
             stored_hash[lb, s].copy())
        fingerprints[lb, s] = pool_fp[lead]
        temperature[lb, s] = pool_temp[lead]
        heads[lb, s] = pool_head[lead]
        entity_ids[lb, s] = pool_eid[lead]
        stored_hash[lb, s] = pool_hash[lead]
        if row_base is None:
            base = (lb // nb) * nb
            v_other = base + hashing.alt_bucket(
                (lb - base).astype(np.uint32), v[0], nb).astype(np.int64)
        else:
            base = row_base[lb]
            v_other = base + hashing.alt_bucket_masked(
                (lb - base).astype(np.uint32), v[0],
                row_mask[lb]).astype(np.int64)
        waiters = order[~is_lead]
        pool_fp = np.concatenate([pool_fp[waiters], v[0]])
        pool_temp = np.concatenate([pool_temp[waiters], v[1]])
        pool_head = np.concatenate([pool_head[waiters], v[2]])
        pool_eid = np.concatenate([pool_eid[waiters], v[3]])
        pool_hash = np.concatenate([pool_hash[waiters], v[4]])
        bucket, other = (np.concatenate([other[waiters], v_other]),
                         np.concatenate([bucket[waiters], lb]))
    return pool_head, pool_eid, pool_hash, pool_temp


@dataclasses.dataclass
class CuckooTables:
    """Device-ready views of the filter (plain arrays, jit-friendly)."""
    fingerprints: np.ndarray       # (NB, S) uint32 — 0 = empty
    temperature: np.ndarray        # (NB, S) int32
    heads: np.ndarray              # (NB, S) int32 — blocklist head / entity id
    entity_ids: np.ndarray         # (NB, S) int32 — for CSR mode & tests


class CuckooFilter:
    """Improved cuckoo filter with temperature + per-entity address lists."""

    def __init__(self, num_buckets: int = 1024, slots: int = DEFAULT_SLOTS,
                 max_kicks: int = DEFAULT_MAX_KICKS,
                 load_threshold: float = DEFAULT_LOAD_THRESHOLD,
                 seed: int = 0x5EED):
        assert num_buckets & (num_buckets - 1) == 0, "power-of-two buckets"
        self.num_buckets = num_buckets
        self.slots = slots
        self.max_kicks = max_kicks
        self.load_threshold = load_threshold
        self._rng = np.random.default_rng(seed)
        self._alloc(num_buckets)
        self.num_items = 0
        self.num_expansions = 0
        self.probes = 0              # slot comparisons (Figure 5 metric)
        self._touched: set = set()   # buckets hit since last sort

    # ------------------------------------------------------------- plumbing
    def _alloc(self, nb: int) -> None:
        s = self.slots
        self.fingerprints = np.full((nb, s), hashing.EMPTY_FP, dtype=np.uint32)
        self.temperature = np.zeros((nb, s), dtype=np.int32)
        self.heads = np.full((nb, s), NULL, dtype=np.int32)
        self.entity_ids = np.full((nb, s), NULL, dtype=np.int32)
        # host-only: original entity hash per slot, needed for expansion rehash
        self.stored_hash = np.zeros((nb, s), dtype=np.uint32)
        self.num_buckets = nb

    @property
    def load_factor(self) -> float:
        return self.num_items / (self.num_buckets * self.slots)

    def tables(self) -> CuckooTables:
        return CuckooTables(self.fingerprints.copy(), self.temperature.copy(),
                            self.heads.copy(), self.entity_ids.copy())

    # ------------------------------------------------------------ insertion
    def insert(self, h: int, head: int, entity_id: int) -> bool:
        """Algorithm 1 (+ auto-expansion). h is the 32-bit entity hash."""
        if self.load_factor >= self.load_threshold:
            self.expand()
        if not self._insert_once(np.uint32(h), head, entity_id):
            # the kick chain placed the new item but left one victim homeless
            # (stored in self._homeless); expansion rehashes + re-homes it.
            self.expand()          # paper: expansion on insertion failure
        return True

    def _insert_once(self, h: np.uint32, head: int, entity_id: int) -> bool:
        nb = self.num_buckets
        fp = hashing.fingerprint(np.uint32(h))
        i1 = int(hashing.bucket_i1(np.uint32(h), nb))
        i2 = int(hashing.alt_bucket(np.uint32(i1), fp, nb))
        for i in (i1, i2):
            s = self._empty_slot(i)
            if s is not None:
                self._write(i, s, fp, 0, head, entity_id, h)
                self.num_items += 1
                return True
        # eviction loop
        i = int(self._rng.choice((i1, i2)))
        cur = (np.uint32(fp), np.int32(0), np.int32(head),
               np.int32(entity_id), np.uint32(h))
        for _ in range(self.max_kicks):
            s = int(self._rng.integers(self.slots))
            victim = (self.fingerprints[i, s], self.temperature[i, s],
                      self.heads[i, s], self.entity_ids[i, s],
                      self.stored_hash[i, s])
            self._write(i, s, *self._unpack(cur))
            cur = victim
            i = int(hashing.alt_bucket(np.uint32(i), cur[0], self.num_buckets))
            s2 = self._empty_slot(i)
            if s2 is not None:
                self._write(i, s2, *self._unpack(cur))
                self.num_items += 1
                return True
        # undo is unnecessary: displaced chain still stores every element,
        # `cur` is the one item left homeless — reinsert it after expansion.
        self._homeless = cur
        return False

    def insert_many(self, hashes: Sequence[int], heads: Sequence[int],
                    entity_ids: Sequence[int]) -> None:
        """Vectorized bulk build: batched hash/fingerprint/bucket compute,
        vectorized empty-slot placement via ``bulk_place``, then the scalar
        eviction path only for the small remainder.  Same membership and
        payload semantics as calling :meth:`insert` per item."""
        hashes = np.asarray(hashes, dtype=np.uint32)
        new_heads = np.asarray(heads, dtype=np.int32)
        new_eids = np.asarray(entity_ids, dtype=np.int32)
        n = int(hashes.shape[0])
        if n == 0:
            return
        # pre-expand so the final load factor stays under the threshold,
        # matching where sequential insertion would have ended up
        while ((self.num_items + n)
               / (self.num_buckets * self.slots) >= self.load_threshold):
            self.expand()
        fp = hashing.fingerprint(hashes)
        i1 = hashing.bucket_i1(hashes, self.num_buckets)
        i2 = hashing.alt_bucket(i1, fp, self.num_buckets)
        r_head, r_eid, r_hash, r_temp = bulk_place(
            self.fingerprints, self.temperature, self.heads,
            self.entity_ids, self.stored_hash, fp, i1.astype(np.int64),
            i2.astype(np.int64), new_heads, new_eids, hashes,
            nb=self.num_buckets, rng=self._rng)
        self.num_items += n - r_head.size
        for j in range(r_head.size):   # rare remainder — scalar kick chains
            self.insert(int(r_hash[j]), int(r_head[j]), int(r_eid[j]))
            if r_temp[j]:              # displaced survivors keep their heat
                self._set_temp_of(np.uint32(r_hash[j]), int(r_temp[j]))

    @staticmethod
    def _unpack(item):
        fp, t, head, eid, h = item
        return np.uint32(fp), int(t), int(head), int(eid), np.uint32(h)

    def _write(self, i: int, s: int, fp: np.uint32, temp: int, head: int,
               entity_id: int, h: np.uint32) -> None:
        self.fingerprints[i, s] = fp
        self.temperature[i, s] = temp
        self.heads[i, s] = head
        self.entity_ids[i, s] = entity_id
        self.stored_hash[i, s] = h

    def _empty_slot(self, i: int) -> Optional[int]:
        empty = np.nonzero(self.fingerprints[i] == hashing.EMPTY_FP)[0]
        return int(empty[0]) if empty.size else None

    # ------------------------------------------------------------- expansion
    def expand(self) -> None:
        """Double the bucket count and rehash every element (paper §1)."""
        old = (self.fingerprints, self.temperature, self.heads,
               self.entity_ids, self.stored_hash)
        homeless = getattr(self, "_homeless", None)
        self._homeless = None
        self._alloc(self.num_buckets * 2)
        self.num_items = 0
        self.num_expansions += 1
        fps, temps, heads, eids, hs = old
        occ = np.nonzero(fps != hashing.EMPTY_FP)
        for i, s in zip(*occ):
            ok = self._insert_once(hs[i, s], int(heads[i, s]), int(eids[i, s]))
            if ok:   # preserve temperature through migration
                self._set_temp_of(hs[i, s], int(temps[i, s]))
            else:
                self.expand()      # extremely unlikely at 0.5 load
        if homeless is not None:
            fp, t, head, eid, h = homeless
            self._insert_once(np.uint32(h), int(head), int(eid))
            self._set_temp_of(np.uint32(h), int(t))

    def _set_temp_of(self, h: np.uint32, temp: int) -> None:
        hit = self._find(h)
        if hit is not None:
            self.temperature[hit] = temp

    # ------------------------------------------------------ lookup / delete
    def _find(self, h: np.uint32) -> Optional[Tuple[int, int]]:
        nb = self.num_buckets
        fp = hashing.fingerprint(np.uint32(h))
        i1 = int(hashing.bucket_i1(np.uint32(h), nb))
        i2 = int(hashing.alt_bucket(np.uint32(i1), fp, nb))
        for i in (i1, i2):
            for s in range(self.slots):       # linear scan, paper semantics
                self.probes += 1
                if self.fingerprints[i, s] == fp:
                    self._touched.add(i)
                    return (i, s)
        return None

    def lookup(self, h: int, bump: bool = True) -> Tuple[bool, int]:
        """Sequential host lookup (reference; Algorithm 3 head). Returns
        (hit, head_ptr) and bumps temperature on hit."""
        hit = self._find(np.uint32(h))
        if hit is None:
            return False, NULL
        if bump:
            self.temperature[hit] += 1
        return True, int(self.heads[hit])

    def lookup_entry(self, h: int, bump: bool = True
                     ) -> Tuple[bool, int, int]:
        """Like :meth:`lookup` but also returns the slot's entity-id payload
        — the CSR retrieval path must use this rather than re-resolving the
        query name, so filter hits and arena hits stay consistent."""
        hit = self._find(np.uint32(h))
        if hit is None:
            return False, NULL, NULL
        if bump:
            self.temperature[hit] += 1
        return True, int(self.heads[hit]), int(self.entity_ids[hit])

    def contains(self, h: int) -> bool:
        return self._find(np.uint32(h)) is not None

    def delete(self, h: int) -> bool:
        """Algorithm 2 — remove fingerprint + its slot payload."""
        hit = self._find(np.uint32(h))
        if hit is None:
            return False
        i, s = hit
        self._write(i, s, np.uint32(hashing.EMPTY_FP), 0, NULL, NULL,
                    np.uint32(0))
        self.num_items -= 1
        return True

    # ---------------------------------------------------- temperature sort
    def sort_buckets(self, touched_only: bool = True) -> None:
        """Reorder bucket slots by descending temperature (paper §3.1
        'adaptive sorting' — done when the bucket is idle); empty slots
        sink to the end.  ``touched_only`` sorts just the buckets hit since
        the previous sort (the paper's 'if it is free' condition in
        practice: untouched buckets cannot have changed order)."""
        if touched_only and self._touched is not None:
            rows = np.fromiter(self._touched, dtype=np.int64,
                               count=len(self._touched))
            if rows.size == 0:
                return
        else:
            rows = slice(None)
        key = np.where(self.fingerprints[rows] == hashing.EMPTY_FP,
                       np.int64(-2**62),
                       self.temperature[rows].astype(np.int64))
        order = np.argsort(-key, axis=1, kind="stable")
        for arr in (self.fingerprints, self.temperature, self.heads,
                    self.entity_ids, self.stored_hash):
            arr[rows] = np.take_along_axis(arr[rows], order, axis=1)
        self._touched = set()


# ---------------------------------------------------------------- assembly

@dataclasses.dataclass
class CFTIndex:
    """Complete CFT-RAG retrieval index: filter + address arena + forest."""
    filter: CuckooFilter
    arena: BlockListArena          # faithful layout
    csr: CSRArena                  # optimized layout
    forest: EntityForest
    entity_hashes: np.ndarray      # (num_entities,) uint32, by entity id


def build_index(forest: EntityForest, num_buckets: int = 1024,
                slots: int = DEFAULT_SLOTS, block_cap: int = 4,
                seed: int = 0x5EED) -> CFTIndex:
    """Find all locations of each entity in the forest, store their addresses
    as block linked lists, and insert fingerprints+heads into the filter."""
    builder = BlockListBuilder(block_cap=block_cap)
    heads = [builder.add_entity(locs) for locs in forest.entity_locations]
    arena = builder.build()
    csr = build_csr(forest.entity_locations)
    hashes = hashing.hash_entities(forest.entity_names)
    filt = CuckooFilter(num_buckets=num_buckets, slots=slots, seed=seed)
    filt.insert_many(hashes, heads, np.arange(len(heads), dtype=np.int32))
    return CFTIndex(filter=filt, arena=arena, csr=csr, forest=forest,
                    entity_hashes=hashes)
