"""Ragged filter-bank arena — T per-tree cuckoo filters in one flat table.

The paper's headline claim ("hundreds of times faster than naive Tree-RAG
when the number of trees is large") needs the many-tree regime: one cuckoo
filter *per tree*.  Real entity forests are skewed — one hospital tree can
hold 16x the entities of its neighbours — so padding every tree to the
hottest tree's bucket count (the old dense ``(T, NB, S)`` layout) wastes
device bytes and turns any expansion into a whole-bank restage.  The bank
therefore stores a **ragged bucket arena**: each tree ``t`` owns an
independent power-of-two bucket count ``tree_nb[t]``, its buckets live as
the contiguous arena segment ``[bucket_offsets[t], bucket_offsets[t+1])``
of one flat ``(total_buckets, S)`` table, and a routed lookup probes rows
``bucket_offsets[t] + (i & (tree_nb[t] - 1))``.  Device bytes are
``sum(tree_nb)`` instead of ``T * max(tree_nb)``, and growing one hot tree
restages only that tree's segment (``repro.core.maintenance``).

Build path: instead of a per-entity Python insert loop, the bank is built in
one vectorized pass over *all* trees at once.  Hash, fingerprint and both
candidate buckets are computed for every (tree, entity) item in a single
numpy batch with per-item bucket masks, empty slots are claimed by grouped
rank assignment (``repro.core.cuckoo.bulk_place``), and only the tiny
two-choice remainder walks the scalar eviction chain.  If any kick chain
exhausts, only the failing tree doubles its bucket count and the bank
rebuilds — the vectorized pass makes that cheap.

Slot payloads are *bank CSR rows*: each (tree, entity) pair that occurs in
the forest owns one row of ``csr_offsets``/``csr_nodes`` listing the node
ids of that entity within that tree.  A routed lookup therefore yields only
locations inside the queried tree — no cross-tree leakage by construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing
from .cuckoo import (DEFAULT_LOAD_THRESHOLD, DEFAULT_MAX_KICKS,
                     DEFAULT_SLOTS, NULL, bulk_place)
from .tree import EntityForest

DEFAULT_LOAD_TARGET = 0.85         # size nb_t so per-tree load stays under this
EMPTY_TREE_NB = 1                  # buckets for a tree holding zero entities


@dataclasses.dataclass
class FilterBank:
    """T per-tree cuckoo filters as one ragged arena + the CSR location
    arena.  ``fingerprints``/``temperature``/``heads``/``entity_ids``/
    ``stored_hash`` are flat ``(total_buckets, S)``; tree ``t`` owns arena
    rows ``[bucket_offsets[t], bucket_offsets[t+1])`` with its own
    power-of-two ``tree_nb[t]``."""
    num_trees: int
    tree_nb: np.ndarray            # (T,) int32 — per-tree buckets, powers of 2
    bucket_offsets: np.ndarray     # (T + 1,) int64 — arena segment starts
    slots: int
    fingerprints: np.ndarray       # (A, S) uint32 — 0 = empty
    temperature: np.ndarray        # (A, S) int32
    heads: np.ndarray              # (A, S) int32 — bank CSR row id
    entity_ids: np.ndarray         # (A, S) int32 — global entity id
    stored_hash: np.ndarray        # (A, S) uint32 — host-only (restage)
    csr_offsets: np.ndarray        # (R + 1,) int32
    csr_nodes: np.ndarray          # (L,) int32 — global node ids per row
    row_tree: np.ndarray           # (R,) int32
    row_entity: np.ndarray         # (R,) int32
    num_items: np.ndarray          # (T,) int32
    build_stats: Dict[str, int]

    # --------------------------------------------------------------- sizes
    @property
    def num_rows(self) -> int:
        return int(self.row_tree.shape[0])

    @property
    def total_buckets(self) -> int:
        """Arena rows == sum(tree_nb) — the quantity device bytes scale
        with (the dense layout paid T * max(tree_nb))."""
        return int(self.fingerprints.shape[0])

    @property
    def num_buckets(self) -> int:
        """Uniform per-tree bucket count.  Only defined while every tree
        shares one nb (a forced-uniform build, or a balanced forest before
        any tree-local expansion); a ragged bank raises."""
        nb = int(self.tree_nb[0])
        if np.any(self.tree_nb != nb):
            raise ValueError(
                f"bank is ragged (tree_nb in [{int(self.tree_nb.min())}, "
                f"{int(self.tree_nb.max())}]): no uniform num_buckets")
        return nb

    @property
    def load_factors(self) -> np.ndarray:
        return self.num_items / (self.tree_nb.astype(np.float64)
                                 * self.slots)

    def segment(self, tree: int) -> Tuple[int, int]:
        """Arena row range [lo, hi) owned by ``tree``."""
        return (int(self.bucket_offsets[tree]),
                int(self.bucket_offsets[tree + 1]))

    def arena_base_mask(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-arena-row (segment start, bucket mask) — the rehoming tables
        ``bulk_place`` uses to keep a victim's kick inside its own tree."""
        base = np.repeat(self.bucket_offsets[:-1].astype(np.int64),
                         self.tree_nb)
        mask = np.repeat((self.tree_nb - 1).astype(np.uint32), self.tree_nb)
        return base, mask

    # ---------------------------------------------------------- host path
    def _find(self, tree: int, h: np.uint32) -> Optional[Tuple[int, int]]:
        nb = int(self.tree_nb[tree])
        lo = int(self.bucket_offsets[tree])
        fp = hashing.fingerprint(np.uint32(h))
        i1 = int(hashing.bucket_i1(np.uint32(h), nb))
        i2 = int(hashing.alt_bucket(np.uint32(i1), fp, nb))
        for i in (i1, i2):
            for s in range(self.slots):
                if self.fingerprints[lo + i, s] == fp:
                    return (i, s)
        return None

    def lookup(self, tree: int, h: int, bump: bool = False
               ) -> Tuple[bool, int, int]:
        """Sequential reference lookup: (hit, csr_row, entity_id)."""
        loc = self._find(tree, np.uint32(h))
        if loc is None:
            return False, NULL, NULL
        i, s = loc
        r = int(self.bucket_offsets[tree]) + i
        if bump:
            self.temperature[r, s] += 1
        return (True, int(self.heads[r, s]), int(self.entity_ids[r, s]))

    def contains(self, tree: int, h: int) -> bool:
        return self._find(tree, np.uint32(h)) is not None

    def find_exact(self, tree_ids: np.ndarray, hs: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized exact-hash slot search (host maintenance path).

        Unlike :meth:`lookup`, matches on the stored 32-bit hash rather
        than the 12-bit fingerprint, so a colliding neighbour can never
        shadow the queried entity.  Returns flat arena-row and slot
        indices, both -1 where the (tree, hash) is not stored.
        """
        tree_ids = np.asarray(tree_ids, np.int64)
        hq = np.asarray(hs, np.uint32)
        s = self.slots
        mask = (self.tree_nb[tree_ids] - 1).astype(np.uint32)
        fp = hashing.fingerprint(hq)
        i1 = hashing.bucket_i1_masked(hq, mask).astype(np.int64)
        i2 = hashing.alt_bucket_masked(i1.astype(np.uint32), fp,
                                       mask).astype(np.int64)
        base = self.bucket_offsets[tree_ids].astype(np.int64)
        cand = np.stack([base + i1, base + i2], axis=1)        # (k, 2)
        match = (self.stored_hash[cand] == hq[:, None, None]) & \
                (self.fingerprints[cand] != hashing.EMPTY_FP)  # (k, 2, S)
        flat = match.reshape(match.shape[0], -1)
        found = flat.any(axis=1)
        first = flat.argmax(axis=1)
        which, slot = first // s, first % s
        row = np.where(found, np.take_along_axis(
            cand, which[:, None], axis=1)[:, 0], -1)
        return row.astype(np.int64), np.where(found, slot, -1).astype(
            np.int64)

    def walk_row(self, row: int) -> List[int]:
        """Node ids of one (tree, entity) CSR row."""
        lo, hi = int(self.csr_offsets[row]), int(self.csr_offsets[row + 1])
        return [int(n) for n in self.csr_nodes[lo:hi]]

    def locate(self, tree: int, name: str) -> List[int]:
        """Routed host locate: node ids of ``name`` within ``tree``."""
        hit, row, _ = self.lookup(tree, int(hashing.entity_hash(name)))
        return self.walk_row(row) if hit and row >= 0 else []

    # -------------------------------------------------------------- device
    def tables(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Device-ready flat-arena (fingerprints, temperature, heads)."""
        return (self.fingerprints.copy(), self.temperature.copy(),
                self.heads.copy())

    def dense_tables(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dense ``(T, NB, S)`` views of the arena — the layout the
        vmapped-over-trees paths consume.  Only defined for a uniform bank
        (raises on ragged); zero-copy reshape of the contiguous arena."""
        shape = (self.num_trees, self.num_buckets, self.slots)
        return (self.fingerprints.reshape(shape),
                self.temperature.reshape(shape),
                self.heads.reshape(shape))

    def absorb_temperature(self, device_state) -> int:
        """Write device-side temperature back into the host bank.

        ``device_state`` is a ``CFTDeviceState`` (or any object with a
        ``temperature`` attribute) or a bare ``(A, S)`` arena array.
        Returns the number of new bumps absorbed (sum of positive per-slot
        deltas) — the signal the maintenance sort trigger integrates.
        """
        temp = getattr(device_state, "temperature", device_state)
        temp = np.asarray(temp, dtype=np.int32)
        if temp.shape != self.temperature.shape:
            raise ValueError(f"temperature shape {temp.shape} != bank "
                             f"{self.temperature.shape} (stale layout?)")
        bumps = int(np.maximum(temp - self.temperature, 0).sum())
        self.temperature[...] = temp
        return bumps

    # ------------------------------------------------------------ sharding
    def shard(self, num_shards: Optional[int] = None,
              tree_starts: Optional[Sequence[int]] = None) -> "ShardedBank":
        """Partition the bank into contiguous tree ranges, one self-contained
        sub-bank per shard (mesh device).

        Each sub-bank relabels its trees to ``0..Td-1``, carves out its
        contiguous arena segment block and a local CSR arena holding only
        its own (tree, entity) rows, so the full :class:`MaintenanceEngine`
        machinery (insert/delete/expand/compact) runs per shard without
        touching any other shard's tables.  Slot placement, per-tree nb and
        slot ordering are *sliced*, not rebuilt, so a freshly sharded bank
        answers bit-identically to the original.
        """
        if tree_starts is None:
            if num_shards is None:
                raise ValueError("need num_shards or tree_starts")
            tree_starts = plan_partition(self.num_items, num_shards)
        starts = np.asarray(tree_starts, np.int64)
        if starts[0] != 0 or starts[-1] != self.num_trees or \
                np.any(np.diff(starts) < 1):
            raise ValueError(f"bad tree partition {starts.tolist()} for "
                             f"T={self.num_trees}")
        off = self.csr_offsets.astype(np.int64)
        boff = self.bucket_offsets.astype(np.int64)
        # carry only rows a filter slot still references: a maintained bank
        # may hold tombstoned CSR rows, and the per-shard engines rebuild
        # liveness from slots — a dangling row would resurrect on restage
        occ_slots = self.fingerprints != hashing.EMPTY_FP
        live = np.zeros(max(self.num_rows, 1), bool)
        live[self.heads[occ_slots]] = True
        banks: List[FilterBank] = []
        for d in range(starts.size - 1):
            lo, hi = int(starts[d]), int(starts[d + 1])
            alo, ahi = int(boff[lo]), int(boff[hi])
            rows = np.flatnonzero((self.row_tree >= lo)
                                  & (self.row_tree < hi)
                                  & live[:self.num_rows])
            inv = np.full(max(self.num_rows, 1), NULL, np.int32)
            inv[rows] = np.arange(rows.size, dtype=np.int32)
            lens = off[rows + 1] - off[rows]
            loc_off = np.zeros(rows.size + 1, dtype=np.int32)
            np.cumsum(lens, out=loc_off[1:])
            total = int(lens.sum())
            idx = (np.arange(total, dtype=np.int64)
                   + np.repeat(off[rows] - loc_off[:-1], lens))
            fps = self.fingerprints[alo:ahi].copy()
            occ = fps != hashing.EMPTY_FP
            heads = np.where(occ, inv[self.heads[alo:ahi]],
                             NULL).astype(np.int32)
            banks.append(FilterBank(
                num_trees=hi - lo,
                tree_nb=self.tree_nb[lo:hi].copy(),
                bucket_offsets=boff[lo:hi + 1] - alo,
                slots=self.slots, fingerprints=fps,
                temperature=self.temperature[alo:ahi].copy(), heads=heads,
                entity_ids=self.entity_ids[alo:ahi].copy(),
                stored_hash=self.stored_hash[alo:ahi].copy(),
                csr_offsets=loc_off,
                csr_nodes=(self.csr_nodes[idx].astype(np.int32) if total
                           else np.zeros(0, np.int32)),
                row_tree=(self.row_tree[rows] - lo).astype(np.int32),
                row_entity=self.row_entity[rows].copy(),
                num_items=self.num_items[lo:hi].copy(),
                build_stats=dict(self.build_stats)))
        return ShardedBank(tree_starts=starts.astype(np.int32), banks=banks)

    def sort_buckets(self) -> None:
        """Host-side idle-time adaptive sort over the whole arena: reorder
        every bucket's slots by descending temperature, empties last — the
        same stable ordering as the device-side ``sort_buckets_arena``, so
        host tables and a freshly restaged device state agree slot-for-slot.
        """
        key = np.where(self.fingerprints == hashing.EMPTY_FP,
                       np.int64(-2 ** 62),
                       self.temperature.astype(np.int64))
        order = np.argsort(-key, axis=1, kind="stable")
        for arr in (self.fingerprints, self.temperature, self.heads,
                    self.entity_ids, self.stored_hash):
            arr[...] = np.take_along_axis(arr, order, axis=1)


# ---------------------------------------------------------------- tenants

_ARENA_TABLES = ("fingerprints", "temperature", "heads", "entity_ids",
                 "stored_hash")


def _blank_tables(rows: int, slots: int) -> Dict[str, np.ndarray]:
    """Empty arena-table segment (misses on every probe)."""
    return dict(
        fingerprints=np.full((rows, slots), hashing.EMPTY_FP, np.uint32),
        temperature=np.zeros((rows, slots), np.int32),
        heads=np.full((rows, slots), NULL, np.int32),
        entity_ids=np.full((rows, slots), NULL, np.int32),
        stored_hash=np.zeros((rows, slots), np.uint32))


def _extract_tree_range(bank: FilterBank, lo: int, hi: int
                        ) -> Dict[str, np.ndarray]:
    """Copy of the arena-table rows owned by trees ``[lo, hi)``."""
    alo, ahi = int(bank.bucket_offsets[lo]), int(bank.bucket_offsets[hi])
    return {n: getattr(bank, n)[alo:ahi].copy() for n in _ARENA_TABLES}


def _replace_tree_range(bank: FilterBank, lo: int, hi: int,
                        tree_nb: np.ndarray, num_items: np.ndarray,
                        tables: Dict[str, np.ndarray]) -> None:
    """Replace trees ``[lo, hi)``'s arena segments and layout in place.

    The same splice shape as ``MaintenanceEngine._restage_tree`` but over
    a tree *range*: tables outside the range keep their bytes, CSR rows
    are never renumbered (cold heads stay valid), ``bucket_offsets``
    recomputes from the new per-tree counts."""
    alo, ahi = int(bank.bucket_offsets[lo]), int(bank.bucket_offsets[hi])
    for name in _ARENA_TABLES:
        old = getattr(bank, name)
        setattr(bank, name, np.concatenate([old[:alo], tables[name],
                                            old[ahi:]]))
    bank.tree_nb[lo:hi] = np.asarray(tree_nb, np.int32)
    off = np.zeros(bank.num_trees + 1, np.int64)
    np.cumsum(bank.tree_nb.astype(np.int64), out=off[1:])
    bank.bucket_offsets = off
    bank.num_items[lo:hi] = np.asarray(num_items, np.int32)


@dataclasses.dataclass
class ColdTenant:
    """Host-resident copy of one evicted tenant's bank content.

    ``tables`` hold the five arena tables of the tenant's tree range in
    global tree order (for a sharded bank: shard-local head payloads,
    concatenated across owning shards).  The CSR rows the heads reference
    stay in the live bank — tombstone compaction is pinned off while any
    tenant is cold — so a reload is a pure segment splice, bit-exact."""
    name: str
    lo: int                        # global tree range [lo, hi)
    hi: int
    tree_nb: np.ndarray            # (hi - lo,) int32
    num_items: np.ndarray          # (hi - lo,) int32
    tables: Dict[str, np.ndarray]  # five (sum(tree_nb), S) arena tables

    @property
    def arena_rows(self) -> int:
        return int(self.tree_nb.sum())


class TenantRegistry:
    """Tenant → contiguous tree-range map over one bank — the thin layer
    that generalizes the ragged arena (``bucket_offsets`` CSR) to a
    multi-tenant forest.

    Ranges must be disjoint; every fault-tolerance primitive upstream
    (admission quotas, per-tenant breakers, cold eviction) keys on the
    names registered here.  The registry owns the cold store: ``evict``
    copies a tenant's arena segments to host and blanks them in the live
    bank (its queries then miss — graceful degradation under arena
    memory pressure), ``reload``/``onboard`` splice content back.  Works
    identically over a replicated :class:`FilterBank` and a
    :class:`ShardedBank` (per owning shard, local coordinates)."""

    def __init__(self, ranges):
        items = (list(ranges.items()) if isinstance(ranges, dict)
                 else [(n, (lo, hi)) for n, lo, hi in ranges])
        items.sort(key=lambda kv: kv[1][0])
        prev = 0
        for name, (lo, hi) in items:
            if not 0 <= lo < hi:
                raise ValueError(f"tenant {name!r}: bad range [{lo}, {hi})")
            if lo < prev:
                raise ValueError(f"tenant {name!r} range [{lo}, {hi}) "
                                 "overlaps its predecessor")
            prev = hi
        self._ranges = {n: (int(lo), int(hi)) for n, (lo, hi) in items}
        self._starts = np.asarray([lo for lo, _ in self._ranges.values()],
                                  np.int64)
        self._names = list(self._ranges)
        self._cold: Dict[str, ColdTenant] = {}
        self._offboarded: set = set()

    # ------------------------------------------------------------- lookup
    @property
    def names(self) -> List[str]:
        return list(self._names)

    def trees(self, name: str) -> Tuple[int, int]:
        return self._ranges[name]

    def tenant_of(self, tree: int) -> Optional[str]:
        """Owning tenant of a global tree id, or None for unowned trees."""
        i = int(np.searchsorted(self._starts, int(tree), side="right")) - 1
        if i < 0:
            return None
        name = self._names[i]
        lo, hi = self._ranges[name]
        return name if lo <= int(tree) < hi else None

    def tenant_of_batch(self, tree_ids) -> Optional[str]:
        """Single owning tenant of a query batch; raises on a batch that
        straddles tenants (isolation would be unattributable)."""
        owners = {self.tenant_of(int(t)) for t in np.asarray(
            tree_ids, np.int64).ravel()}
        if len(owners) > 1:
            raise ValueError(f"batch spans tenants {sorted(map(str, owners))}")
        return next(iter(owners)) if owners else None

    # -------------------------------------------------------------- state
    def resident(self, name: str) -> bool:
        self.trees(name)               # raises on unknown tenant
        return name not in self._cold and name not in self._offboarded

    def cold(self, name: str) -> Optional[ColdTenant]:
        return self._cold.get(name)

    @property
    def any_cold(self) -> bool:
        return bool(self._cold)

    # ------------------------------------------------------------ surgery
    def _shard_pieces(self, bank, lo: int, hi: int):
        """(sub-bank, local lo, local hi) per owning shard, tree order."""
        if isinstance(bank, ShardedBank):
            out = []
            for d, b in enumerate(bank.banks):
                slo = int(bank.tree_starts[d])
                shi = int(bank.tree_starts[d + 1])
                a, z = max(lo, slo), min(hi, shi)
                if a < z:
                    out.append((b, a - slo, z - slo))
            return out
        return [(bank, lo, hi)]

    def evict(self, bank, name: str) -> ColdTenant:
        """Copy ``name``'s tree-range content to host and blank it in the
        live bank (each tree becomes an empty ``EMPTY_TREE_NB`` segment;
        its queries miss, its CSR rows are untouched).  The caller
        restages the device state and must pin compaction off while any
        tenant is cold."""
        if not self.resident(name):
            raise ValueError(f"tenant {name!r} is not resident")
        lo, hi = self.trees(name)
        pieces = self._shard_pieces(bank, lo, hi)
        tree_nb, num_items, tabs = [], [], []
        for b, llo, lhi in pieces:
            tree_nb.append(b.tree_nb[llo:lhi].copy())
            num_items.append(b.num_items[llo:lhi].copy())
            tabs.append(_extract_tree_range(b, llo, lhi))
        cold = ColdTenant(
            name=name, lo=lo, hi=hi,
            tree_nb=np.concatenate(tree_nb),
            num_items=np.concatenate(num_items),
            tables={n: np.concatenate([t[n] for t in tabs])
                    for n in _ARENA_TABLES})
        for b, llo, lhi in pieces:
            n = lhi - llo
            _replace_tree_range(
                b, llo, lhi,
                np.full(n, EMPTY_TREE_NB, np.int32), np.zeros(n, np.int32),
                _blank_tables(n * EMPTY_TREE_NB, b.slots))
        self._cold[name] = cold
        return cold

    def reload(self, bank, name: str,
               cold: Optional[ColdTenant] = None) -> None:
        """Splice an evicted (or externally restored) tenant's content
        back into its tree range — the exact inverse of :meth:`evict`."""
        cold = cold if cold is not None else self._cold.get(name)
        if cold is None:
            raise ValueError(f"tenant {name!r} has no cold copy")
        if (cold.lo, cold.hi) != self.trees(name):
            raise ValueError(
                f"cold copy of {name!r} covers trees [{cold.lo}, "
                f"{cold.hi}) but the registry maps {self.trees(name)}")
        row_off = np.zeros(cold.hi - cold.lo + 1, np.int64)
        np.cumsum(cold.tree_nb.astype(np.int64), out=row_off[1:])
        t0 = 0
        for b, llo, lhi in self._shard_pieces(bank, cold.lo, cold.hi):
            n = lhi - llo
            a, z = int(row_off[t0]), int(row_off[t0 + n])
            _replace_tree_range(
                b, llo, lhi, cold.tree_nb[t0:t0 + n],
                cold.num_items[t0:t0 + n],
                {k: v[a:z] for k, v in cold.tables.items()})
            t0 += n
        self._cold.pop(name, None)
        self._offboarded.discard(name)

    def offboard(self, bank, name: str) -> ColdTenant:
        """Evict and drop residency permanently: the tree range stays
        allocated (tree ids never shift under other tenants) but empty;
        the returned cold copy is the caller's to snapshot or discard."""
        cold = self.evict(bank, name)
        del self._cold[name]
        self._offboarded.add(name)
        return cold

    def onboard(self, bank, name: str, cold: ColdTenant) -> None:
        """Bring a tenant live into its (currently empty) tree range from
        a cold copy — e.g. one restored via ``core.snapshot``.  Only legal
        while the tenant is offboarded (or was never made resident after
        an offboard); a resident tenant must be evicted first."""
        if self.resident(name):
            raise ValueError(f"tenant {name!r} is already resident")
        self._offboarded.add(name)      # reload() clears both flags
        self._cold.pop(name, None)
        self.reload(bank, name, cold)


# --------------------------------------------------------------- sharding

def plan_partition(weights: np.ndarray, num_shards: int) -> np.ndarray:
    """Contiguous tree ranges balanced by per-tree weight (row counts).

    Returns ``starts`` of shape ``(num_shards + 1,)``: shard ``d`` owns
    global trees ``[starts[d], starts[d+1])``.  Boundaries sit at the
    quantiles of the cumulative weight, clamped so every shard owns at
    least one tree (requires ``T >= num_shards``).
    """
    w = np.asarray(weights, np.float64).ravel()
    t, d = w.size, int(num_shards)
    if d < 1:
        raise ValueError("num_shards must be >= 1")
    if t < d:
        raise ValueError(f"cannot spread {t} trees over {d} shards")
    if w.sum() <= 0:
        w = np.ones(t)
    cum = np.cumsum(w)
    starts = np.zeros(d + 1, np.int64)
    starts[d] = t
    for k in range(1, d):
        # side="right": a boundary exactly on the quantile closes the range
        # *after* that tree (equal weights then split perfectly evenly)
        b = int(np.searchsorted(cum, cum[-1] * k / d, side="right"))
        starts[k] = min(max(b, starts[k - 1] + 1), t - (d - k))
    return starts.astype(np.int32)


@dataclasses.dataclass
class ShardedBank:
    """Tree-range partitioned :class:`FilterBank` — the host mirror of the
    device-side bank-axis sharding in ``repro.core.distributed``.

    Shard ``d`` owns global trees ``[tree_starts[d], tree_starts[d+1])`` as
    a self-contained sub-bank (local tree ids, local bucket arena, local
    CSR arena), so every maintenance operation — insert, delete, compact,
    *expand* — is tree-local inside its owning shard: one hot tree
    outgrowing its buckets restages only its own arena segment while every
    other segment (same shard or not) stays byte-identical.  Per-tree
    ``tree_nb`` may therefore diverge freely; the packed device layout pads
    each shard's arena to the largest shard's row count and routes
    candidate-bucket arithmetic through the per-tree offsets/mask tables.

    Row numbering: the *merged* numbering (shard-major, ``shard_row_base``
    offsets) is canonical for a sharded bank — it is what the packed device
    ``heads`` payloads carry and what :meth:`walk_row` resolves.
    """
    tree_starts: np.ndarray        # (D + 1,) int32
    banks: List[FilterBank]

    # --------------------------------------------------------------- sizes
    @property
    def num_shards(self) -> int:
        return len(self.banks)

    @property
    def num_trees(self) -> int:
        return int(self.tree_starts[-1])

    @property
    def slots(self) -> int:
        return self.banks[0].slots

    @property
    def arena_rows_per_shard(self) -> int:
        """Padded per-shard arena row count of the packed device layout."""
        return max(b.total_buckets for b in self.banks)

    @property
    def total_buckets(self) -> int:
        """True (unpadded) arena rows across all shards."""
        return sum(b.total_buckets for b in self.banks)

    @property
    def num_items(self) -> np.ndarray:
        return np.concatenate([b.num_items for b in self.banks])

    @property
    def num_rows(self) -> int:
        return int(sum(b.num_rows for b in self.banks))

    # ------------------------------------------------------------- routing
    def tree_shard_map(self) -> np.ndarray:
        """(T,) int32: owning shard of every global tree."""
        return np.repeat(np.arange(self.num_shards, dtype=np.int32),
                         np.diff(self.tree_starts))

    def tree_local_map(self) -> np.ndarray:
        """(T,) int32: local tree index within the owning shard."""
        t = np.arange(self.num_trees, dtype=np.int32)
        return t - self.tree_starts[self.tree_shard_map()]

    def tree_arena_offsets(self) -> np.ndarray:
        """(T,) int64: each tree's segment start *within its owning
        shard's block* — the generalization of the old per-shard NB table
        to a per-tree offsets table (the probe adds ``h & (nb_t - 1)``)."""
        return np.concatenate(
            [b.bucket_offsets[:-1].astype(np.int64) for b in self.banks])

    def tree_nb_map(self) -> np.ndarray:
        """(T,) int32: per-tree bucket count in global tree order."""
        return np.concatenate([b.tree_nb for b in self.banks]).astype(
            np.int32)

    def owner(self, tree: int) -> Tuple[int, int]:
        """Global tree -> (shard, local tree)."""
        if not 0 <= tree < self.num_trees:
            raise ValueError(f"tree {tree} out of range "
                             f"[0, {self.num_trees})")
        d = int(np.searchsorted(self.tree_starts, tree, side="right")) - 1
        return d, tree - int(self.tree_starts[d])

    def shard_row_base(self) -> np.ndarray:
        """(D + 1,) merged-row offsets: shard d's local row r is merged row
        ``base[d] + r`` — the numbering the packed device heads carry."""
        base = np.zeros(self.num_shards + 1, np.int64)
        np.cumsum([b.num_rows for b in self.banks], out=base[1:])
        return base

    # ----------------------------------------------------------- host path
    def lookup(self, tree: int, h: int, bump: bool = False
               ) -> Tuple[bool, int, int]:
        """Routed host lookup; the returned row id is *merged* numbering."""
        d, lt = self.owner(tree)
        hit, row, eid = self.banks[d].lookup(lt, h, bump=bump)
        if hit and row >= 0:
            row += int(self.shard_row_base()[d])
        return hit, row, eid

    def contains(self, tree: int, h: int) -> bool:
        d, lt = self.owner(tree)
        return self.banks[d].contains(lt, h)

    def locate(self, tree: int, name: str) -> List[int]:
        d, lt = self.owner(tree)
        return self.banks[d].locate(lt, name)

    def walk_row(self, row: int) -> List[int]:
        """Node ids of one merged-numbering (tree, entity) row."""
        base = self.shard_row_base()
        d = int(np.searchsorted(base, row, side="right")) - 1
        return self.banks[d].walk_row(int(row - base[d]))

    # -------------------------------------------------------------- device
    def packed_tables(self, arena_rows: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Device-ready packed (fingerprints, temperature, heads).

        Shape ``(D * Apad, S)`` with ``Apad = arena_rows_per_shard``:
        shard d's arena occupies rows ``[d*Apad, d*Apad + A_d)``; padding
        rows hold empty fingerprints (never match).  Head payloads are
        merged row ids (``shard_row_base`` offsets applied).

        ``arena_rows`` raises ``Apad`` above the tight minimum — the
        in-place splice commit cannot shrink a live state's padding, so
        equivalence checks against such a state repack at its block size.
        """
        d, ap, s = self.num_shards, self.arena_rows_per_shard, self.slots
        ap = max(ap, int(arena_rows or 0))
        fps = np.full((d * ap, s), hashing.EMPTY_FP, np.uint32)
        temp = np.zeros((d * ap, s), np.int32)
        heads = np.full((d * ap, s), NULL, np.int32)
        base = self.shard_row_base()
        for k, b in enumerate(self.banks):
            blk = slice(k * ap, k * ap + b.total_buckets)
            fps[blk] = b.fingerprints
            temp[blk] = b.temperature
            occ = b.fingerprints != hashing.EMPTY_FP
            heads[blk] = np.where(occ, b.heads + np.int32(base[k]), NULL)
        return fps, temp, heads

    def merged_tables(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Replicated-reference arena ``(A, S)`` tables in global tree
        order with merged-row head payloads — the tables
        ``lookup_batch_ragged`` probes (with :meth:`merged_layout`) to
        produce the sharded path's exact results.  Well-defined for any
        per-tree nb (the dense uniform-NB restriction is gone)."""
        base = self.shard_row_base()
        fps = np.concatenate([b.fingerprints for b in self.banks], axis=0)
        temp = np.concatenate([b.temperature for b in self.banks], axis=0)
        heads = np.concatenate(
            [np.where(b.fingerprints != hashing.EMPTY_FP,
                      b.heads + np.int32(base[k]), NULL)
             for k, b in enumerate(self.banks)], axis=0)
        return fps, temp, heads

    def merged_layout(self) -> Tuple[np.ndarray, np.ndarray]:
        """(bucket_offsets (T+1,), tree_nb (T,)) of the merged arena."""
        nb = self.tree_nb_map()
        off = np.zeros(self.num_trees + 1, np.int64)
        np.cumsum(nb, out=off[1:])
        return off, nb

    def merged_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenated CSR arena in merged-row order (device staging)."""
        offsets = [np.zeros(1, np.int32)]
        nodes = []
        shift = 0
        for b in self.banks:
            offsets.append(b.csr_offsets[1:].astype(np.int32) + shift)
            nodes.append(b.csr_nodes)
            shift += int(b.csr_offsets[-1])
        return (np.concatenate(offsets),
                np.concatenate(nodes) if nodes else np.zeros(0, np.int32))

    # --------------------------------------------- temperature feedback
    def temperature_blocks(self, packed) -> List[np.ndarray]:
        """Slice a packed ``(D*Apad, S)`` device temperature into per-shard
        owner blocks ``(A_d, S)`` — padding rows are excluded, so each
        slot's bumps are harvested exactly once, against the owning shard's
        baseline only.  The device ``Apad`` may exceed the host's tight
        minimum (the in-place splice commit never shrinks a live state's
        padding after a tree shrink); any block size that still fits every
        shard's arena slices identically."""
        temp = np.asarray(getattr(packed, "temperature", packed), np.int32)
        d, ap = self.num_shards, self.arena_rows_per_shard
        ok = (temp.ndim == 2 and temp.shape[1] == self.slots
              and temp.shape[0] % d == 0 and temp.shape[0] // d >= ap)
        if not ok:
            raise ValueError(f"packed temperature shape {temp.shape} "
                             f"incompatible with {d} shards of >= {ap} "
                             f"arena rows (stale sharded layout?)")
        ap = temp.shape[0] // d
        return [temp[k * ap:k * ap + b.total_buckets]
                for k, b in enumerate(self.banks)]

    def absorb_temperature(self, device_state) -> int:
        """Write a packed sharded device temperature back into the host
        sub-banks; returns total new bumps (sum of positive deltas against
        each owning shard's own baseline — never double-counted across
        shards or padding)."""
        return sum(b.absorb_temperature(blk) for b, blk in
                   zip(self.banks, self.temperature_blocks(device_state)))

    def sort_buckets(self) -> None:
        for b in self.banks:
            b.sort_buckets()


# ------------------------------------------------- device-side splice ops
#
# The donated-buffer update ops of the double-buffered restage: a
# maintenance cycle that touched K arena rows commits as one in-place
# scatter of K staged rows (plus, after a tree resize, one segment splice)
# instead of re-staging the whole arena.  Donation makes the scatter
# in-place on backends that support it (TPU/GPU); elsewhere XLA falls back
# to a copy — semantics are identical either way, but the *old* buffers
# are invalidated, so callers must drop the pre-commit state.

@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def splice_arena_rows(fps, temp, heads, rows, vf, vt, vh, vkeep):
    """In-place donated scatter of staged rows into the live ``(A, S)``
    arena tables: ``rows`` is sentinel-padded (sentinel = A, out of
    bounds, dropped), the value tables carry the new row contents.  O(K)
    device work, O(K) host→device bytes.

    Temperature **max-merges** on slots whose key the plan leaves in
    place (``vkeep``): a bump that landed between ``plan_restage()`` and
    commit (serving continues on the old state through the prepare
    phase) lives only on device, so overwriting with the staged value
    would silently drop it.  Where the key moved (delete, eviction, sort)
    the slot's identity changed and the staged value wins — a bump for a
    departed key does not leak onto its successor.  ``vkeep`` is the
    *plan-time* mask ``staged fp == shadow fp`` — device fingerprints
    are immutable between commits, so the shadow is the live content;
    comparing against the donated ``fps`` here instead would race the
    in-place fps scatter (no data dependency orders them)."""
    live_t = jnp.where(vkeep, temp[rows], 0)
    return (fps.at[rows].set(vf, mode="drop"),
            temp.at[rows].set(jnp.maximum(vt, live_t), mode="drop"),
            heads.at[rows].set(vh, mode="drop"))


def pad_csr(offsets: np.ndarray, nodes: np.ndarray, chunk: int = 256
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad the replicated CSR staging arrays to a pow2-chunked capacity.

    The CSR arena grows with every insert batch; staged tight, each
    growth changes the device state's array shapes and forces the jitted
    retrieval step to recompile at *every* batch geometry — hundreds of
    milliseconds on the serve path per churn window.  Padding to the next
    power of two (floored at ``chunk`` entries) keeps the shapes constant
    until the arena actually doubles, so recompiles amortize like vector
    growth.  The pad tail is inert: ``offsets`` repeats the terminal
    offset (every pad row is empty) and ``nodes`` pads with zeros that no
    live row can address.  Every staging site (fresh stage and restage
    plan alike) must pad through here so splice-committed and
    from-scratch states stay byte-identical."""
    off = np.asarray(offsets, np.int32)
    nd = np.asarray(nodes, np.int32)
    if nd.size == 0:
        nd = np.zeros(1, np.int32)
    cap = lambda n: max(chunk, int(2 ** np.ceil(np.log2(n))))  # noqa: E731
    po = np.full(cap(off.size), off[-1], np.int32)
    po[:off.size] = off
    pn = np.zeros(cap(nd.size), np.int32)
    pn[:nd.size] = nd
    return po, pn


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def splice_arena_segment(fps, temp, heads, seg_f, seg_t, seg_h,
                         lo: int, hi: int):
    """Device-side segment splice: replace arena rows ``[lo, hi)`` with
    the staged segment (possibly of a different length — ``expand_tree``
    doubles it, ``shrink_tree`` halves it), leaving every other row's
    bytes untouched.  Only the new segment crosses the host→device link;
    the surrounding rows move at device bandwidth.  ``lo``/``hi`` are
    static (a resize changes the output shape — which is also why these
    buffers are not donated), so commits recompile per geometry — tree
    resizes are rare by design."""
    cat = lambda a, s: jnp.concatenate([a[:lo], s, a[hi:]])   # noqa: E731
    return cat(fps, seg_f), cat(temp, seg_t), cat(heads, seg_h)


# ------------------------------------------------------------------- build

def _bank_rows(forest: EntityForest):
    """Enumerate (tree, entity) rows and their node lists — fully
    vectorized: one lexsort of the forest's flat node arrays replaces the
    per-entity Python grouping loop.  Rows come out entity-major, trees
    ascending within an entity, node ids ascending within a row (the same
    order the host-side ``entity_locations`` walk produces)."""
    entity_hashes = hashing.hash_entities(forest.entity_names)
    n = forest.num_nodes
    if n == 0:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros(1, np.int32), np.zeros(0, np.int32), entity_hashes)
    ent = forest.entity_id.astype(np.int64)
    tre = forest.tree_id.astype(np.int64)
    nodes = np.arange(n, dtype=np.int64)
    order = np.lexsort((nodes, tre, ent))      # by entity, tree, node
    e_s, t_s, n_s = ent[order], tre[order], nodes[order]
    new_row = np.r_[True, (e_s[1:] != e_s[:-1]) | (t_s[1:] != t_s[:-1])]
    row_tree = t_s[new_row].astype(np.int32)
    row_entity = e_s[new_row].astype(np.int32)
    counts = np.bincount(np.cumsum(new_row) - 1, minlength=row_tree.size)
    offsets = np.zeros(row_tree.size + 1, dtype=np.int32)
    np.cumsum(counts, out=offsets[1:])
    return row_tree, row_entity, offsets, n_s.astype(np.int32), entity_hashes


def estimate_fpr(load, slots: int,
                 fp_bits: int = hashing.FP_BITS):
    """Empirical false-positive-rate estimate of a cuckoo-filter tree at
    the given load factor(s) — the observability half of the ROADMAP's
    self-tuning-bank item (the exemplar filters in SNIPPETS.md estimate
    FPR online from load and fingerprint bits the same way).

    A missing key probes its two candidate buckets, ~``2·slots·load``
    occupied slots, each holding a fingerprint uniform over the
    ``2^fp_bits - 1`` usable values (0 is the empty sentinel —
    ``hashing.fingerprint`` remaps real fingerprints off it), so

        FPR ≈ 1 - (1 - 1/(2^fp_bits - 1))^(2·slots·load)

    Accepts a scalar or an array of per-tree loads; returns the same
    shape as a float / float64 array.
    """
    p = 1.0 / ((1 << fp_bits) - 1)
    occupied = 2.0 * slots * np.asarray(load, np.float64)
    est = 1.0 - np.power(1.0 - p, occupied)
    return float(est) if est.ndim == 0 else est


def _pick_num_buckets(max_per_tree: int, slots: int,
                      load_target: float) -> int:
    need = max(1, int(np.ceil(max_per_tree / (slots * load_target))))
    nb = 4
    while nb < need:
        nb *= 2
    return nb


def _pick_tree_buckets(per_tree: np.ndarray, slots: int,
                       load_target: float) -> np.ndarray:
    """Vectorized per-tree bucket pick: the smallest power of two (>= 4)
    keeping that tree under ``load_target``; an *empty* tree gets the
    minimum ``EMPTY_TREE_NB`` instead of inheriting a shared NB — the
    ragged layout's fix for empty-tree over-allocation."""
    need = np.maximum(1, np.ceil(per_tree / (slots * load_target)))
    nb = np.maximum(4, 2 ** np.ceil(np.log2(need))).astype(np.int64)
    return np.where(per_tree > 0, nb, EMPTY_TREE_NB).astype(np.int64)


def _scalar_insert(fps: np.ndarray, temps: np.ndarray, heads: np.ndarray,
                   eids: np.ndarray, hs: np.ndarray, base: int, nb: int,
                   slots: int, h: int, row: int, eid: int, rng,
                   max_kicks: int, temp: int = 0) -> bool:
    """Scalar cuckoo insert into flat bank tables, confined to one tree's
    arena segment [base, base + nb).  Temperature rides along the kick
    chain so displaced hot slots keep their heat (matters for live
    maintenance; a fresh build passes all-zero temps)."""
    h = np.uint32(h)
    fp = hashing.fingerprint(h)
    i1 = int(hashing.bucket_i1(h, nb))
    i2 = int(hashing.alt_bucket(np.uint32(i1), fp, nb))
    for i in (base + i1, base + i2):
        empty = np.nonzero(fps[i] == hashing.EMPTY_FP)[0]
        if empty.size:
            s = int(empty[0])
            fps[i, s], heads[i, s], eids[i, s], hs[i, s] = fp, row, eid, h
            temps[i, s] = temp
            return True
    i = base + int(rng.choice((i1, i2)))
    cur = (np.uint32(fp), np.int32(temp), np.int32(row), np.int32(eid),
           np.uint32(h))
    for _ in range(max_kicks):
        s = int(rng.integers(slots))
        victim = (fps[i, s], temps[i, s], heads[i, s], eids[i, s], hs[i, s])
        fps[i, s], temps[i, s], heads[i, s], eids[i, s], hs[i, s] = cur
        cur = victim
        local = int(hashing.alt_bucket(np.uint32(i - base), cur[0], nb))
        i = base + local
        empty = np.nonzero(fps[i] == hashing.EMPTY_FP)[0]
        if empty.size:
            s = int(empty[0])
            fps[i, s], temps[i, s], heads[i, s], eids[i, s], hs[i, s] = cur
            return True
    return False


def build_bank_from_rows(num_trees: int, row_tree: np.ndarray,
                         row_entity: np.ndarray, row_hash: np.ndarray,
                         csr_offsets: np.ndarray, csr_nodes: np.ndarray,
                         num_buckets=None,
                         slots: int = DEFAULT_SLOTS, seed: int = 0x5EED,
                         bulk: bool = True,
                         max_kicks: int = DEFAULT_MAX_KICKS,
                         load_target: float = DEFAULT_LOAD_TARGET,
                         row_temp: Optional[np.ndarray] = None
                         ) -> FilterBank:
    """Build a bank directly from explicit (tree, entity) rows.

    The shared core of :func:`build_bank` (which derives rows from a
    forest), the maintenance engine's restage paths (which re-home the live
    rows of a mutated bank, ``row_temp`` carrying their temperatures), and
    the churn-equivalence tests (from-scratch reference for an
    incrementally maintained bank).

    ``num_buckets``: ``None`` picks per-tree ragged bucket counts
    (``_pick_tree_buckets``); an int forces that uniform NB on every tree
    (the dense-equivalent layout — kick-chain failure then doubles every
    tree, preserving uniformity); an array pins per-tree counts exactly
    (failure doubles only the failing tree).
    """
    T = max(1, int(num_trees))
    row_tree = np.asarray(row_tree, np.int32)
    row_entity = np.asarray(row_entity, np.int32)
    item_hash = np.asarray(row_hash, np.uint32)
    m = row_tree.shape[0]
    item_row = np.arange(m, dtype=np.int32)
    item_temp = (np.zeros(m, np.int32) if row_temp is None
                 else np.asarray(row_temp, np.int32))

    per_tree = np.bincount(row_tree, minlength=T) if m else \
        np.zeros(T, np.int64)
    uniform = num_buckets is not None and np.ndim(num_buckets) == 0
    if num_buckets is None:
        tree_nb = _pick_tree_buckets(per_tree, slots, load_target)
    elif uniform:
        tree_nb = np.full(T, int(num_buckets), np.int64)
    else:
        tree_nb = np.asarray(num_buckets, np.int64).copy()
    assert (tree_nb & (tree_nb - 1) == 0).all() and (tree_nb > 0).all(), \
        "power-of-two buckets per tree"

    rebuilds = -1
    while True:
        rebuilds += 1
        offsets = np.zeros(T + 1, np.int64)
        np.cumsum(tree_nb, out=offsets[1:])
        a = int(offsets[-1])
        rng = np.random.default_rng(seed)
        fps = np.full((a, slots), hashing.EMPTY_FP, dtype=np.uint32)
        temps = np.zeros((a, slots), dtype=np.int32)
        heads = np.full((a, slots), NULL, dtype=np.int32)
        eids = np.full((a, slots), NULL, dtype=np.int32)
        hs = np.zeros((a, slots), dtype=np.uint32)
        stats = {"items": int(m), "bulk_placed": 0, "evicted": 0,
                 "rebuilds": rebuilds}

        if bulk and m:
            item_mask = (tree_nb[row_tree] - 1).astype(np.uint32)
            fp = hashing.fingerprint(item_hash)
            i1 = hashing.bucket_i1_masked(item_hash, item_mask)
            i2 = hashing.alt_bucket_masked(i1, fp, item_mask)
            base = offsets[row_tree]
            arena_base = np.repeat(offsets[:-1], tree_nb)
            arena_mask = np.repeat((tree_nb - 1).astype(np.uint32),
                                   tree_nb)
            r_head, r_eid, r_hash, r_temp = bulk_place(
                fps, temps, heads, eids, hs, fp,
                base + i1.astype(np.int64), base + i2.astype(np.int64),
                item_row, row_entity, item_hash, nb=0, rng=rng,
                new_temps=item_temp, row_base=arena_base,
                row_mask=arena_mask)
            stats["bulk_placed"] = int(m - r_head.size)
            stats["evicted"] = int(r_head.size)
        else:
            r_head, r_eid, r_hash = item_row, row_entity, item_hash
            r_temp = item_temp

        ok = True
        for j in range(r_head.size):
            # a remainder item's tree is recoverable from its row payload
            tree = int(row_tree[int(r_head[j])])
            if not _scalar_insert(fps, temps, heads, eids, hs,
                                  int(offsets[tree]), int(tree_nb[tree]),
                                  slots, int(r_hash[j]),
                                  int(r_head[j]), int(r_eid[j]), rng,
                                  max_kicks, temp=int(r_temp[j])):
                ok = False
                # tree-local doubling: only the failing tree grows (unless
                # the caller forced a uniform layout)
                if uniform:
                    tree_nb = tree_nb * 2
                else:
                    tree_nb[tree] *= 2
                break
        if ok:
            over = per_tree >= DEFAULT_LOAD_THRESHOLD * tree_nb * slots
            if m == 0 or not over.any():
                break
            if uniform:
                tree_nb = tree_nb * 2
            else:
                tree_nb[over] *= 2

    return FilterBank(
        num_trees=T, tree_nb=tree_nb.astype(np.int32),
        bucket_offsets=offsets, slots=slots,
        fingerprints=fps, temperature=temps,
        heads=heads, entity_ids=eids, stored_hash=hs,
        csr_offsets=np.asarray(csr_offsets, np.int32),
        csr_nodes=np.asarray(csr_nodes, np.int32),
        row_tree=row_tree, row_entity=row_entity,
        num_items=np.bincount(row_tree, minlength=T).astype(np.int32),
        build_stats=stats,
    )


def build_bank(forest: EntityForest, num_buckets=None,
               slots: int = DEFAULT_SLOTS, seed: int = 0x5EED,
               bulk: bool = True, max_kicks: int = DEFAULT_MAX_KICKS,
               load_target: float = DEFAULT_LOAD_TARGET) -> FilterBank:
    """Build the bank for ``forest``.

    ``bulk=True`` (default) is the vectorized path: batched hashing +
    grouped empty-slot placement across all T trees at once, scalar kicks
    only for the remainder.  ``bulk=False`` inserts every item through the
    scalar path — kept as the equivalence/benchmark reference.
    ``num_buckets=None`` (default) sizes every tree independently (ragged
    arena); an int forces the uniform dense-equivalent layout.
    """
    row_tree, row_entity, csr_offsets, csr_nodes, entity_hashes = \
        _bank_rows(forest)
    m = row_tree.shape[0]
    item_hash = (entity_hashes[row_entity] if m
                 else np.zeros(0, np.uint32)).astype(np.uint32)
    return build_bank_from_rows(
        max(1, forest.num_trees), row_tree, row_entity, item_hash,
        csr_offsets, csr_nodes, num_buckets=num_buckets, slots=slots,
        seed=seed, bulk=bulk, max_kicks=max_kicks, load_target=load_target)
