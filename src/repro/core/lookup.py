"""Batched cuckoo-filter lookup — pure-jnp reference semantics.

This is the vectorized (TPU-adapted) form of the paper's lookup (§3.4): all
query-entity hashes are probed at once.  The Pallas kernel in
``repro.kernels.cuckoo_lookup`` implements exactly these semantics and is
validated against this function.

Slot priority matches the paper's linear bucket scan: bucket i1 slots 0..S-1,
then bucket i2 slots 0..S-1 — so after a temperature sort, hot entities
resolve at slot 0.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import hashing


class LookupResult(NamedTuple):
    hit: jax.Array        # (B,) bool
    head: jax.Array       # (B,) int32 — blocklist head / entity id (NULL=-1)
    bucket: jax.Array     # (B,) int32 — bucket of the matching slot
    slot: jax.Array       # (B,) int32 — slot within that bucket


def match_rows(fp: jax.Array, i1: jax.Array, i2: jax.Array,
               rows1: jax.Array, rows2: jax.Array,
               heads1: jax.Array, heads2: jax.Array,
               s: int) -> LookupResult:
    """Shared slot-priority match over two gathered bucket rows — the one
    place lookup semantics live; the batch/bank/sharded entry points all
    gather their candidate rows and defer to this."""
    match = jnp.concatenate([rows1 == fp[:, None],
                             rows2 == fp[:, None]], axis=1)   # (B, 2S)
    hit = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)                # first matching position
    bucket = jnp.where(first < s, i1, i2).astype(jnp.int32)
    slot = jnp.where(first < s, first, first - s).astype(jnp.int32)
    heads_cat = jnp.concatenate([heads1, heads2], axis=1)
    head = jnp.where(hit,
                     jnp.take_along_axis(heads_cat, first[:, None], axis=1)[:, 0],
                     jnp.int32(-1))
    return LookupResult(hit=hit, head=head.astype(jnp.int32),
                        bucket=bucket, slot=slot)


def lookup_batch(fingerprints: jax.Array, heads: jax.Array,
                 h: jax.Array) -> LookupResult:
    """fingerprints/heads: (NB, S); h: (B,) uint32 entity hashes."""
    nb, s = fingerprints.shape
    fp, i1, i2 = hashing.candidate_buckets(h.astype(jnp.uint32), nb, jnp)
    return match_rows(fp, i1, i2, fingerprints[i1], fingerprints[i2],
                      heads[i1], heads[i2], s)


def lookup_batch_bank(fingerprints: jax.Array, heads: jax.Array,
                      tree_ids: jax.Array, h: jax.Array) -> LookupResult:
    """Per-query tree routing over a *dense uniform* filter bank.

    fingerprints/heads: (T, NB, S); tree_ids/h: (B,).  Each query probes
    only its own tree's filter; ``bucket`` is the tree-local bucket index.
    Kept as the dense-equivalence reference for the ragged arena path
    (:func:`lookup_batch_ragged` with uniform tree_nb must agree
    bit-for-bit).
    """
    _, nb, s = fingerprints.shape
    fp, i1, i2 = hashing.candidate_buckets(h.astype(jnp.uint32), nb, jnp)
    t = tree_ids.astype(jnp.int32)
    return match_rows(fp, i1, i2, fingerprints[t, i1], fingerprints[t, i2],
                      heads[t, i1], heads[t, i2], s)


def lookup_arena(fingerprints: jax.Array, heads: jax.Array,
                 row_offsets: jax.Array, masks: jax.Array,
                 h: jax.Array) -> LookupResult:
    """Probe a flat ragged bucket arena with pre-routed per-query segments.

    fingerprints/heads: (A, S) arena tables; ``row_offsets``/``masks``:
    (B,) per-query segment start and bucket mask ``nb_t - 1``.  This is
    the layer the sharded all-to-all hands exchanged queries to (the
    receiving shard knows each query's segment, not its global tree id);
    :func:`lookup_batch_ragged` derives the per-query routing from the
    per-tree offsets table.  ``bucket`` is the tree-local bucket index, so
    results are bit-identical to probing that tree's standalone filter.
    """
    s = fingerprints.shape[-1]
    fp, i1, i2 = hashing.candidate_buckets_masked(
        h.astype(jnp.uint32), masks.astype(jnp.uint32), jnp)
    base = row_offsets.astype(jnp.int32)
    r1 = base + i1.astype(jnp.int32)
    r2 = base + i2.astype(jnp.int32)
    return match_rows(fp, i1, i2, fingerprints[r1], fingerprints[r2],
                      heads[r1], heads[r2], s)


def lookup_batch_ragged(fingerprints: jax.Array, heads: jax.Array,
                        bucket_offsets: jax.Array, tree_nb: jax.Array,
                        tree_ids: jax.Array, h: jax.Array) -> LookupResult:
    """Per-query tree routing over the ragged bucket arena.

    fingerprints/heads: (A, S); ``bucket_offsets``: (T + 1,) segment
    starts; ``tree_nb``: (T,) per-tree bucket counts; tree_ids/h: (B,).
    The probe computes ``bucket_offsets[t] + (i & (tree_nb[t] - 1))`` —
    with uniform tree_nb this is bit-identical to :func:`lookup_batch_bank`
    over the dense reshape of the same arena.
    """
    t = tree_ids.astype(jnp.int32)
    return lookup_arena(fingerprints, heads, bucket_offsets[t],
                        (tree_nb[t] - 1).astype(jnp.uint32), h)


def lookup_batch_trees(fingerprints: jax.Array, heads: jax.Array,
                       h: jax.Array) -> LookupResult:
    """Vmapped-over-trees entry point: one dense query batch per tree.

    fingerprints/heads: (T, NB, S); h: (T, B) — result fields are (T, B).
    """
    return jax.vmap(lookup_batch)(fingerprints, heads, h)


def bump_temperature(temperature: jax.Array, res: LookupResult) -> jax.Array:
    """Algorithm 3: temperature += 1 for every hit slot (scatter-add)."""
    return temperature.at[res.bucket, res.slot].add(
        res.hit.astype(temperature.dtype))


def bump_temperature_bank(temperature: jax.Array, tree_ids: jax.Array,
                          res: LookupResult) -> jax.Array:
    """Dense bank-axis variant: temperature (T, NB, S), scatter per tree."""
    return temperature.at[tree_ids.astype(jnp.int32),
                          res.bucket, res.slot].add(
        res.hit.astype(temperature.dtype))


def bump_temperature_arena(temperature: jax.Array, row_offsets: jax.Array,
                           res: LookupResult) -> jax.Array:
    """Arena variant: temperature (A, S); ``row_offsets`` (B,) per-query
    segment starts — the hit slot lives at arena row
    ``row_offsets + bucket``."""
    return temperature.at[row_offsets.astype(jnp.int32) + res.bucket,
                          res.slot].add(res.hit.astype(temperature.dtype))


def _sort_slots(fingerprints: jax.Array, temperature: jax.Array,
                *tables: jax.Array):
    """Stable per-bucket slot reorder by descending temperature, empties
    last; any number of payload tables ride along under the same order."""
    key = jnp.where(fingerprints == jnp.uint32(hashing.EMPTY_FP),
                    jnp.int64(-(2 ** 62)) if temperature.dtype == jnp.int64
                    else jnp.int32(-(2 ** 30)),
                    temperature.astype(jnp.int32))
    order = jnp.argsort(-key, axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    return (take(fingerprints), take(temperature)) + tuple(
        take(t) for t in tables)


def sort_buckets(fingerprints: jax.Array, temperature: jax.Array,
                 heads: jax.Array, entity_ids: jax.Array):
    """Reorder slots of every bucket by descending temperature (device-side
    analogue of the paper's idle-time adaptive sort); empties sink last."""
    return _sort_slots(fingerprints, temperature, heads, entity_ids)


def sort_buckets_bank(fingerprints: jax.Array, temperature: jax.Array,
                      *tables: jax.Array):
    """Dense bank-axis idle-time sort: vmap of :func:`sort_buckets` over
    the tree axis.  Tables are ``(T, NB, S)``."""
    return jax.vmap(_sort_slots)(fingerprints, temperature, *tables)


def sort_buckets_arena(fingerprints: jax.Array, temperature: jax.Array,
                       *tables: jax.Array):
    """Ragged-arena idle-time sort: one flat per-bucket slot reorder over
    the whole ``(A, S)`` arena — the segmented replacement for the vmapped
    ``sort_buckets_bank`` (a bucket sort never crosses rows, so the tree
    segmentation needs no special handling).  Hot fingerprints float to
    slot 0 of their bucket within every tree's filter at once.  Payload
    tables (heads, entity ids, ...) are variadic so both the 3-table
    device state and the 5-table host bank restage through the same
    routine."""
    return _sort_slots(fingerprints, temperature, *tables)
