"""Batched cuckoo-filter lookup — pure-jnp reference semantics.

This is the vectorized (TPU-adapted) form of the paper's lookup (§3.4): all
query-entity hashes are probed at once.  The Pallas kernel in
``repro.kernels.cuckoo_lookup`` implements exactly these semantics and is
validated against this function.

Slot priority matches the paper's linear bucket scan: bucket i1 slots 0..S-1,
then bucket i2 slots 0..S-1 — so after a temperature sort, hot entities
resolve at slot 0.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import hashing


class LookupResult(NamedTuple):
    hit: jax.Array        # (B,) bool
    head: jax.Array       # (B,) int32 — blocklist head / entity id (NULL=-1)
    bucket: jax.Array     # (B,) int32 — bucket of the matching slot
    slot: jax.Array       # (B,) int32 — slot within that bucket


def lookup_batch(fingerprints: jax.Array, heads: jax.Array,
                 h: jax.Array) -> LookupResult:
    """fingerprints/heads: (NB, S); h: (B,) uint32 entity hashes."""
    nb, s = fingerprints.shape
    fp, i1, i2 = hashing.candidate_buckets(h.astype(jnp.uint32), nb, jnp)
    rows1 = fingerprints[i1]                         # (B, S)
    rows2 = fingerprints[i2]
    match = jnp.concatenate([rows1 == fp[:, None],
                             rows2 == fp[:, None]], axis=1)   # (B, 2S)
    hit = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)                # first matching position
    bucket = jnp.where(first < s, i1, i2).astype(jnp.int32)
    slot = jnp.where(first < s, first, first - s).astype(jnp.int32)
    heads_cat = jnp.concatenate([heads[i1], heads[i2]], axis=1)
    head = jnp.where(hit,
                     jnp.take_along_axis(heads_cat, first[:, None], axis=1)[:, 0],
                     jnp.int32(-1))
    return LookupResult(hit=hit, head=head.astype(jnp.int32),
                        bucket=bucket, slot=slot)


def bump_temperature(temperature: jax.Array, res: LookupResult) -> jax.Array:
    """Algorithm 3: temperature += 1 for every hit slot (scatter-add)."""
    return temperature.at[res.bucket, res.slot].add(
        res.hit.astype(temperature.dtype))


def sort_buckets(fingerprints: jax.Array, temperature: jax.Array,
                 heads: jax.Array, entity_ids: jax.Array):
    """Reorder slots of every bucket by descending temperature (device-side
    analogue of the paper's idle-time adaptive sort); empties sink last."""
    key = jnp.where(fingerprints == jnp.uint32(hashing.EMPTY_FP),
                    jnp.int64(-(2 ** 62)) if temperature.dtype == jnp.int64
                    else jnp.int32(-(2 ** 30)),
                    temperature.astype(jnp.int32))
    order = jnp.argsort(-key, axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    return take(fingerprints), take(temperature), take(heads), take(entity_ids)
