"""CFT-RAG core: improved cuckoo filter + entity-tree retrieval."""
from .bank import (ColdTenant, FilterBank, ShardedBank, TenantRegistry,
                   build_bank, build_bank_from_rows, estimate_fpr,
                   plan_partition, splice_arena_rows, splice_arena_segment)
from .baselines import BloomTRAG, BloomTRAG2, NaiveTRAG
from .blocklist import BlockListArena, BlockListBuilder, CSRArena, build_csr
from .context import (EntityContext, context_from_arena, context_from_csr,
                      generate_context, render_context)
from .cuckoo import (CFTIndex, CuckooFilter, CuckooTables, build_index,
                     bulk_place)
from .lookup import (LookupResult, bump_temperature, bump_temperature_arena,
                     bump_temperature_bank, lookup_arena, lookup_batch,
                     lookup_batch_bank, lookup_batch_ragged,
                     lookup_batch_trees, sort_buckets, sort_buckets_arena,
                     sort_buckets_bank)
from .maintenance import (BankDelta, MaintenanceBreaker, MaintenanceEngine,
                          MaintenanceReport, PendingRestage,
                          PendingShardedRestage, ShardedMaintenanceEngine,
                          commit_restage, warm_restage)
from .snapshot import (RestoredSnapshot, SnapshotWriter,
                       apply_maint_bookkeeping, cleanup_snapshots,
                       latest_snapshot, list_snapshots, list_tenants,
                       load_tenant, merge_sharded_bank, restore_snapshot,
                       restore_state, save_snapshot, save_tenant)
from .trag import (CFTRAG, CFTDeviceState, DeviceRetrieval, build_retriever,
                   csr_window, finish_context, gather_context,
                   retrieve_device)
from .distributed import (ShardedBankState, plan_tenant_partition,
                          routing_counts, shard_bank, sharded_apply_delta,
                          sharded_lookup, sharded_lookup_bank,
                          sharded_retrieve_device, sharded_splice_segment,
                          shard_filter_tables, stage_sharded_bank)
from .tree import EntityForest, build_forest

__all__ = [
    "ColdTenant", "FilterBank", "ShardedBank", "TenantRegistry",
    "build_bank", "build_bank_from_rows",
    "estimate_fpr", "plan_partition", "splice_arena_rows",
    "splice_arena_segment",
    "BankDelta", "MaintenanceBreaker", "MaintenanceEngine",
    "MaintenanceReport",
    "PendingRestage", "PendingShardedRestage", "ShardedMaintenanceEngine",
    "commit_restage", "warm_restage",
    "RestoredSnapshot", "SnapshotWriter", "apply_maint_bookkeeping",
    "cleanup_snapshots", "latest_snapshot", "list_snapshots",
    "list_tenants", "load_tenant", "merge_sharded_bank",
    "restore_snapshot", "restore_state", "save_snapshot", "save_tenant",
    "ShardedBankState", "plan_tenant_partition", "routing_counts",
    "shard_bank",
    "sharded_apply_delta", "sharded_lookup", "sharded_lookup_bank",
    "sharded_retrieve_device", "sharded_splice_segment",
    "shard_filter_tables", "stage_sharded_bank", "gather_context",
    "csr_window", "finish_context",
    "BloomTRAG", "BloomTRAG2", "NaiveTRAG",
    "BlockListArena", "BlockListBuilder", "CSRArena", "build_csr",
    "EntityContext", "context_from_arena", "context_from_csr",
    "generate_context", "render_context",
    "CFTIndex", "CuckooFilter", "CuckooTables", "build_index", "bulk_place",
    "LookupResult", "bump_temperature", "bump_temperature_arena",
    "bump_temperature_bank", "lookup_arena", "lookup_batch",
    "lookup_batch_bank", "lookup_batch_ragged", "lookup_batch_trees",
    "sort_buckets", "sort_buckets_arena", "sort_buckets_bank",
    "CFTRAG", "CFTDeviceState", "DeviceRetrieval", "build_retriever",
    "retrieve_device",
    "EntityForest", "build_forest",
]
