"""CFT-RAG retriever — the paper's method, host and device paths.

Host path (benchmark-comparable with baselines.py): sequential filter lookup
per entity, block-linked-list walk, Algorithm-3 context generation, with
temperature bump + idle-time bucket sort between query rounds.

Device path: batched lookup over all query entities at once (jnp /
Pallas-kernel semantics) + vectorized hierarchy gather — this is what runs
inside the jitted serving step (see repro/serving/rag.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing
from .bank import FilterBank, pad_csr
from .context import (EntityContext, context_from_arena, context_from_csr,
                      gather_descendants, gather_hierarchy, render_context)
from .cuckoo import CFTIndex, build_index
from .lookup import (LookupResult, bump_temperature_arena, lookup_arena,
                     sort_buckets_arena)
from .tree import EntityForest

NULL = -1


class CFTRAG:
    """Cuckoo-Filter Tree-RAG retriever (paper §3 / §4.2)."""

    def __init__(self, index: CFTIndex, use_csr: bool = False,
                 sort_every: int = 1, n_hierarchy: int = 3):
        self.index = index
        self.use_csr = use_csr          # False = faithful block linked list
        self.sort_every = sort_every    # re-sort buckets every k rounds (0=off)
        self.n = n_hierarchy
        self._round = 0

    # ----------------------------------------------------------- host path
    def locate(self, name: str):
        """Filter lookup -> address list (the paper's accelerated locate)."""
        h = hashing.entity_hash(name)
        hit, head, eid = self.index.filter.lookup_entry(int(h))
        if not hit:
            return []
        if self.use_csr:
            # use the slot's entity-id payload, NOT a name->id re-resolve:
            # on a fingerprint collision the arena path walks the stored
            # entity's addresses, and the CSR path must agree with it
            return self.index.csr.walk(eid) if eid >= 0 else []
        return self.index.arena.walk(head)

    def retrieve(self, names: Sequence[str], n: Optional[int] = None
                 ) -> List[EntityContext]:
        n = n or self.n
        f = self.index.forest
        out = []
        for nm in names:
            eid = f.name_to_id.get(nm, -1)
            locs = self.locate(nm)
            out.append(EntityContext(entity_id=eid, locations=list(locs),
                                     up=[f.ancestors(node, n) for _, node in locs],
                                     down=[f.descendants(node, n) for _, node in locs]))
        self._round += 1
        if self.sort_every and self._round % self.sort_every == 0:
            self.index.filter.sort_buckets()   # idle-time adaptive sort
        return out

    def render(self, contexts: Sequence[EntityContext]) -> str:
        return render_context(self.index.forest, contexts)

    # --------------------------------------------------------- device path
    def device_state(self) -> "CFTDeviceState":
        return CFTDeviceState.from_index(self.index)


class DeviceRetrieval(NamedTuple):
    hit: jax.Array          # (B,) bool
    locations: jax.Array    # (B, max_locs) int32 node ids (NULL-padded)
    up: jax.Array           # (B, max_locs, n) ancestor entity ids
    down: jax.Array         # (B, max_locs, n) descendant entity ids
    temperature: jax.Array  # updated (A, S) arena table — thread into state


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CFTDeviceState:
    """All retrieval tensors living on device, usable inside jit.

    Filter tables are a flat **ragged bucket arena** ``(A, S)``: tree
    ``t`` owns arena rows ``[bucket_offsets[t], bucket_offsets[t+1])``
    with its own power-of-two ``tree_nb[t]`` bucket count.  The
    single-index state from :meth:`from_index` is simply an arena with one
    tree, while :meth:`from_bank` adopts the bank's arena directly.  Slot
    payloads index rows of ``csr_offsets`` — per-entity rows in the T == 1
    case, per-(tree, entity) rows in the bank case — so the retrieval
    arithmetic downstream of the lookup is identical for both.
    """
    fingerprints: jax.Array    # (A, S) uint32 — ragged arena
    temperature: jax.Array     # (A, S) int32
    heads: jax.Array           # (A, S) int32 — CSR row id payloads
    bucket_offsets: jax.Array  # (T + 1,) int32 — per-tree segment starts
    tree_nb: jax.Array         # (T,) int32 — per-tree bucket counts
    csr_offsets: jax.Array     # (R + 1,) int32
    csr_nodes: jax.Array       # (L,) int32 — node id per location
    parent: jax.Array          # (N,) int32
    entity_id: jax.Array       # (N,) int32
    child_offsets: jax.Array   # (N + 1,) int32
    child_index: jax.Array     # (C,) int32

    @property
    def num_trees(self) -> int:
        return int(self.bucket_offsets.shape[0]) - 1

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)

    @staticmethod
    def _forest_arrays(f: EntityForest):
        return dict(
            parent=jnp.asarray(f.parent if f.num_nodes
                               else np.zeros(1, np.int32)),
            entity_id=jnp.asarray(f.entity_id if f.num_nodes
                                  else np.zeros(1, np.int32)),
            child_offsets=jnp.asarray(f.child_offsets),
            child_index=jnp.asarray(f.child_index if f.child_index.size
                                    else np.zeros(1, np.int32)),
        )

    @classmethod
    def from_index(cls, index: CFTIndex) -> "CFTDeviceState":
        t = index.filter.tables()
        nb = index.filter.num_buckets
        # NB: the host tables must be *copied*, not wrapped — on CPU,
        # jnp.asarray zero-copies a 64-byte-aligned numpy array, and an
        # aliased buffer would let later host-side writes (inserts,
        # temperature bumps) leak into this supposedly immutable state
        return cls(
            fingerprints=jnp.array(t.fingerprints, copy=True),
            temperature=jnp.array(t.temperature, copy=True),
            # the device path uses CSR: slot payload = entity id (= row)
            heads=jnp.array(t.entity_ids, copy=True),
            bucket_offsets=jnp.asarray(np.asarray([0, nb], np.int32)),
            tree_nb=jnp.asarray(np.asarray([nb], np.int32)),
            csr_offsets=jnp.asarray(index.csr.offsets),
            csr_nodes=jnp.asarray(index.csr.addrs[:, 1]
                                  if index.csr.addrs.size else
                                  np.zeros((1,), np.int32)),
            **cls._forest_arrays(index.forest),
        )

    def with_temperature(self, temperature: jax.Array) -> "CFTDeviceState":
        """Thread an updated temperature table back into the state — the
        one sanctioned way to carry a query batch's bumps forward (callers
        previously hand-rolled ``dataclasses.replace``)."""
        return dataclasses.replace(self, temperature=temperature)

    def sort_idle(self) -> "CFTDeviceState":
        """Device-side idle-time maintenance: resort every bucket of every
        tree hot-fingerprints-first (``sort_buckets_arena`` — one flat
        per-bucket reorder over the ragged arena).  Pure-device path for
        states with no host bank mirror; when a host ``MaintenanceEngine``
        owns the tables, sort on the host and restage instead so the two
        layouts never diverge."""
        f, t, h = sort_buckets_arena(self.fingerprints, self.temperature,
                                     self.heads)
        return dataclasses.replace(self, fingerprints=f, temperature=t,
                                   heads=h)

    @classmethod
    def from_bank(cls, bank: FilterBank, forest: EntityForest
                  ) -> "CFTDeviceState":
        # pad_csr keeps the CSR shapes stable under churn so the jitted
        # retrieval step never recompiles on a restage commit
        csr_off, csr_nodes = pad_csr(bank.csr_offsets, bank.csr_nodes)
        # copy the mutable arena tables (see from_index): an aliased
        # buffer would let maintenance writes to the host bank show
        # through the serving state, breaking quarantine rollback ("keep
        # serving the last committed content")
        return cls(
            fingerprints=jnp.array(bank.fingerprints, copy=True),
            temperature=jnp.array(bank.temperature, copy=True),
            heads=jnp.array(bank.heads, copy=True),
            bucket_offsets=jnp.asarray(
                bank.bucket_offsets.astype(np.int32)),
            tree_nb=jnp.asarray(bank.tree_nb.astype(np.int32)),
            csr_offsets=jnp.asarray(csr_off),
            csr_nodes=jnp.asarray(csr_nodes),
            **cls._forest_arrays(forest),
        )


def retrieve_device(state: CFTDeviceState, query_hashes: jax.Array,
                    query_trees: Optional[jax.Array] = None,
                    max_locs: int = 4, n: int = 3,
                    lookup_fn=None, fused: bool = False) -> DeviceRetrieval:
    """Batched CFT-RAG retrieval, jit-compatible end to end.

    Queries are ``(tree_id, hash)`` pairs; ``query_trees`` defaults to all
    zeros, which on a ``T == 1`` state reproduces the single-filter
    behaviour.  The per-tree routing (arena segment start + bucket mask)
    is gathered from the state's offsets table here; ``lookup_fn(
    fingerprints, heads, row_offsets, masks, h)`` then probes the flat
    arena — defaults to the pure-jnp :func:`repro.core.lookup.
    lookup_arena`; the serving engine passes the Pallas arena kernel
    wrapper (identical signature/semantics).

    ``fused=True`` routes the whole step (probe + bump + CSR window +
    hierarchy walks) through the single-pass
    :mod:`repro.kernels.fused_retrieve` kernel instead — bit-identical
    outputs, one launch.  Mutually exclusive with ``lookup_fn`` (the fused
    kernel *is* the probe).
    """
    if fused:
        if lookup_fn is not None:
            raise ValueError("fused=True embeds the probe; lookup_fn "
                             "cannot be combined with it")
        from ..kernels.fused_retrieve import fused_retrieve_state_auto
        out = fused_retrieve_state_auto(state, query_hashes, query_trees,
                                        max_locs=max_locs, n=n)
        if out is not None:
            return out
        # resident blocks overflow the VMEM budget (huge arena on TPU):
        # fall through to the unfused oracle path
    if lookup_fn is None:
        lookup_fn = lookup_arena
    if query_trees is None:
        query_trees = jnp.zeros(query_hashes.shape, jnp.int32)
    num_trees = state.bucket_offsets.shape[0] - 1
    # out-of-range tree ids must miss, not alias to a clamped gather row
    in_range = (query_trees >= 0) & (query_trees < num_trees)
    query_trees = jnp.where(in_range, query_trees, 0).astype(jnp.int32)
    row_off = state.bucket_offsets[query_trees]
    masks = (state.tree_nb[query_trees] - 1).astype(jnp.uint32)
    res: LookupResult = lookup_fn(state.fingerprints, state.heads,
                                  row_off, masks, query_hashes)
    res = res._replace(hit=res.hit & in_range)
    temp = bump_temperature_arena(state.temperature, row_off, res)
    return gather_context(state, res, temp, max_locs=max_locs, n=n)


def gather_context(state, res: LookupResult, temperature: jax.Array,
                   max_locs: int = 4, n: int = 3) -> DeviceRetrieval:
    """CSR location gather + hierarchy windows downstream of a bank lookup.

    Shared tail of :func:`retrieve_device` and the bank-axis sharded path
    (``repro.core.distributed.sharded_retrieve_device``): ``state`` is any
    object with replicated ``csr_offsets``/``csr_nodes`` and forest arrays
    (``CFTDeviceState`` or ``ShardedBankState``), ``res.head`` indexes the
    CSR rows, and ``temperature`` (whatever layout the lookup maintains) is
    threaded through untouched.
    """
    nodes = csr_window(state.csr_offsets, state.csr_nodes,
                       res.hit, res.head, max_locs)
    return finish_context(state, res.hit, nodes, temperature,
                          max_locs=max_locs, n=n)


def csr_window(csr_offsets: jax.Array, csr_nodes: jax.Array,
               hit: jax.Array, head: jax.Array,
               max_locs: int) -> jax.Array:
    """Per-query CSR location window ``(B, max_locs)``, NULL-padded.

    Misses route to the *empty sentinel row* ``R = len(csr_offsets) - 1``:
    the terminal offset is a valid row index whose window ``[terminal,
    min(R+1, R)) = [terminal, terminal)`` is empty by construction, so a
    low-hit-rate batch gathers nothing for its misses instead of pulling
    CSR row 0's full window plus hierarchy walks and masking it after the
    fact.  Bit-identical to the old clamp-to-0 form (the window mask
    already ANDed with ``hit``); no pad row is required, so it holds for
    both ``pad_csr``-staged and raw ``from_index`` states.
    """
    r = csr_offsets.shape[0] - 1
    eid = jnp.where(hit, head, r)                            # (B,) CSR rows
    lo = csr_offsets[eid]                                    # (B,)
    count = csr_offsets[jnp.minimum(eid + 1, r)] - lo
    k = jnp.arange(max_locs, dtype=jnp.int32)                # (max_locs,)
    idx = lo[:, None] + k[None, :]
    valid = (k[None, :] < count[:, None]) & hit[:, None]
    safe = jnp.clip(idx, 0, csr_nodes.shape[0] - 1)
    return jnp.where(valid, csr_nodes[safe], NULL)           # (B, max_locs)


def finish_context(state, hit: jax.Array, nodes: jax.Array,
                   temperature: jax.Array, max_locs: int = 4,
                   n: int = 3) -> DeviceRetrieval:
    """Hierarchy windows for an already-gathered location window — the
    forest-walk tail shared by :func:`gather_context` and the sharded
    owner-fused path (which routes ``(hit, locations)`` back through the
    all-to-all and walks the replicated forest locally)."""
    flat = nodes.reshape(-1)
    up = gather_hierarchy(state.parent, state.entity_id,
                          jnp.maximum(flat, 0), n)
    up = jnp.where(flat[:, None] == NULL, NULL, up)
    down = gather_descendants(state.child_offsets, state.child_index,
                              state.entity_id, jnp.maximum(flat, 0), n)
    down = jnp.where(flat[:, None] == NULL, NULL, down)
    B = hit.shape[0]
    return DeviceRetrieval(
        hit=hit, locations=nodes,
        up=up.reshape(B, max_locs, n), down=down.reshape(B, max_locs, n),
        temperature=temperature)


def build_retriever(trees, num_buckets: int = 1024, **kw) -> CFTRAG:
    """Convenience: edge lists -> forest -> index -> retriever."""
    from .tree import build_forest
    forest = build_forest(trees)
    index = build_index(forest, num_buckets=num_buckets)
    return CFTRAG(index, **kw)
