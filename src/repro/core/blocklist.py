"""Block linked list arena — the paper's per-entity address store.

Every entity occurs at several (tree_id, node_id) locations in the forest.
CFT-RAG stores these addresses in a *block linked list*: fixed-capacity blocks
chained by `next` pointers, head pointer kept in the cuckoo bucket slot.

TPU adaptation (see DESIGN.md §3): pointers become indices into flat arrays so
the whole arena is a set of dense device tensors. Two layouts are provided:

* ``BlockListArena`` — faithful: blocks of ``block_cap`` addresses + next
  index, traversed with ``jax.lax.while_loop`` (or host-side generator).
* ``CSRArena`` — beyond-paper optimized: per-entity contiguous spans
  (offsets + counts), one dynamic slice per entity, no chain walk.

Both store identical information; tests assert they enumerate the same
address sets.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np

Address = Tuple[int, int]          # (tree_id, node_id)
NULL = -1


@dataclasses.dataclass
class BlockListArena:
    """Flat arena of fixed-size blocks. Host-built, device-ready arrays."""
    block_cap: int
    addrs: np.ndarray      # (num_blocks, block_cap, 2) int32, padded with NULL
    counts: np.ndarray     # (num_blocks,) int32 — valid addrs in each block
    next: np.ndarray       # (num_blocks,) int32 — next block or NULL

    @property
    def num_blocks(self) -> int:
        return int(self.addrs.shape[0])

    def walk(self, head: int) -> List[Address]:
        """Host-side traversal (reference semantics for tests)."""
        out: List[Address] = []
        b = head
        while b != NULL:
            n = int(self.counts[b])
            out.extend((int(t), int(nd)) for t, nd in self.addrs[b, :n])
            b = int(self.next[b])
        return out


class BlockListBuilder:
    def __init__(self, block_cap: int = 4):
        self.block_cap = block_cap
        self._addrs: List[np.ndarray] = []
        self._counts: List[int] = []
        self._next: List[int] = []

    def add_entity(self, addresses: Sequence[Address]) -> int:
        """Append one entity's address list; returns its head block index."""
        if not addresses:
            return NULL
        cap = self.block_cap
        head = len(self._counts)
        chunks = [addresses[i:i + cap] for i in range(0, len(addresses), cap)]
        for ci, chunk in enumerate(chunks):
            block = np.full((cap, 2), NULL, dtype=np.int32)
            block[: len(chunk)] = np.asarray(chunk, dtype=np.int32)
            self._addrs.append(block)
            self._counts.append(len(chunk))
            nxt = head + ci + 1 if ci + 1 < len(chunks) else NULL
            self._next.append(nxt)
        return head

    def build(self) -> BlockListArena:
        if self._counts:
            addrs = np.stack(self._addrs).astype(np.int32)
        else:
            addrs = np.zeros((0, self.block_cap, 2), dtype=np.int32)
        return BlockListArena(
            block_cap=self.block_cap,
            addrs=addrs,
            counts=np.asarray(self._counts, dtype=np.int32),
            next=np.asarray(self._next, dtype=np.int32),
        )


@dataclasses.dataclass
class CSRArena:
    """Contiguous per-entity address spans (optimized layout)."""
    offsets: np.ndarray    # (num_entities + 1,) int32
    addrs: np.ndarray      # (total_locations, 2) int32

    def span(self, entity_id: int) -> Tuple[int, int]:
        return int(self.offsets[entity_id]), int(self.offsets[entity_id + 1])

    def walk(self, entity_id: int) -> List[Address]:
        lo, hi = self.span(entity_id)
        return [(int(t), int(n)) for t, n in self.addrs[lo:hi]]


def build_csr(address_lists: Iterable[Sequence[Address]]) -> CSRArena:
    lists = [np.asarray(a, dtype=np.int32).reshape(-1, 2) for a in address_lists]
    counts = np.asarray([len(a) for a in lists], dtype=np.int32)
    offsets = np.zeros(len(lists) + 1, dtype=np.int32)
    np.cumsum(counts, out=offsets[1:])
    addrs = (np.concatenate(lists, axis=0) if lists
             else np.zeros((0, 2), dtype=np.int32))
    return CSRArena(offsets=offsets, addrs=addrs.astype(np.int32))
