"""Entity forest — the hierarchical knowledge structure of Tree-RAG.

All trees live in one flat node arena (device-friendly): parent pointers,
children CSR, per-node entity ids.  An entity (global vocabulary id) may
occur at many nodes across trees; ``entity_locations`` enumerates them and is
what the cuckoo filter's block linked lists index.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Sequence, Tuple

import numpy as np

NULL = -1
Edge = Tuple[str, str]             # (parent_name, child_name)


@dataclasses.dataclass
class EntityForest:
    parent: np.ndarray             # (N,) int32 — global node index or NULL
    entity_id: np.ndarray          # (N,) int32 — global entity vocabulary id
    tree_id: np.ndarray            # (N,) int32
    depth: np.ndarray              # (N,) int32 — 0 at roots
    child_offsets: np.ndarray      # (N + 1,) int32 — CSR into child_index
    child_index: np.ndarray        # (total_children,) int32
    roots: np.ndarray              # (num_roots,) int32 global node indices
    entity_names: List[str]
    name_to_id: Dict[str, int]
    entity_locations: List[List[Tuple[int, int]]]  # per entity: [(tree, node)]
    num_trees: int

    # ------------------------------------------------------------------ sizes
    @property
    def num_nodes(self) -> int:
        return int(self.parent.shape[0])

    @property
    def num_entities(self) -> int:
        return len(self.entity_names)

    # ------------------------------------------------------- host traversals
    def children(self, node: int) -> np.ndarray:
        return self.child_index[self.child_offsets[node]:self.child_offsets[node + 1]]

    def ancestors(self, node: int, n: int) -> List[int]:
        """Up to n entity ids walking parent pointers upward (nearest first)."""
        out: List[int] = []
        p = int(self.parent[node])
        while p != NULL and len(out) < n:
            out.append(int(self.entity_id[p]))
            p = int(self.parent[p])
        return out

    def descendants(self, node: int, n: int) -> List[int]:
        """First n entity ids BFS-down from node (level order)."""
        out: List[int] = []
        q = deque(int(c) for c in self.children(node))
        while q and len(out) < n:
            c = q.popleft()
            out.append(int(self.entity_id[c]))
            q.extend(int(g) for g in self.children(c))
        return out

    def subtree_entities(self, node: int) -> set:
        """Entity-id set of node's subtree (incl. itself) — for Bloom builds."""
        seen = set()
        q = deque([node])
        while q:
            c = q.popleft()
            seen.add(int(self.entity_id[c]))
            q.extend(int(g) for g in self.children(c))
        return seen

    # ---------------------------------------------------------------- device
    def device_arrays(self):
        """Arrays to ship to the accelerator for vectorized context gather."""
        return dict(parent=self.parent, entity_id=self.entity_id,
                    child_offsets=self.child_offsets, child_index=self.child_index)


def build_forest(trees: Sequence[Sequence[Edge]]) -> EntityForest:
    """Build the flat forest from per-tree parent->child edge lists.

    A node is created per distinct entity name within each tree; names are
    shared across trees through the global entity vocabulary.
    """
    name_to_id: Dict[str, int] = {}
    entity_names: List[str] = []

    def eid(name: str) -> int:
        if name not in name_to_id:
            name_to_id[name] = len(entity_names)
            entity_names.append(name)
        return name_to_id[name]

    parent: List[int] = []
    entity_id: List[int] = []
    tree_id: List[int] = []
    roots: List[int] = []
    children_acc: List[List[int]] = []

    for t, edges in enumerate(trees):
        local: Dict[str, int] = {}          # name -> global node idx (this tree)
        has_parent: Dict[int, bool] = {}

        def node_of(name: str) -> int:
            if name not in local:
                g = len(parent)
                local[name] = g
                parent.append(NULL)
                entity_id.append(eid(name))
                tree_id.append(t)
                children_acc.append([])
                has_parent[g] = False
            return local[name]

        def is_ancestor(a: int, b: int) -> bool:
            """Would attaching b under a create a cycle? (is b above a?)"""
            g = a
            while g != NULL:
                if g == b:
                    return True
                g = parent[g]
            return False

        for pname, cname in edges:
            p = node_of(pname)
            c = node_of(cname)
            # first parent wins; never create a cycle within the tree
            if parent[c] == NULL and p != c and not is_ancestor(p, c):
                parent[c] = p
                children_acc[p].append(c)
                has_parent[c] = True
        for g in local.values():
            if not has_parent.get(g, False):
                roots.append(g)

    n = len(parent)
    parent_a = np.asarray(parent, dtype=np.int32) if n else np.zeros(0, np.int32)
    entity_a = np.asarray(entity_id, dtype=np.int32) if n else np.zeros(0, np.int32)
    tree_a = np.asarray(tree_id, dtype=np.int32) if n else np.zeros(0, np.int32)

    counts = np.asarray([len(c) for c in children_acc], dtype=np.int32)
    child_offsets = np.zeros(n + 1, dtype=np.int32)
    if n:
        np.cumsum(counts, out=child_offsets[1:])
    child_index = (np.concatenate([np.asarray(c, np.int32) for c in children_acc])
                   if any(children_acc) else np.zeros(0, np.int32))

    # depth by BFS from roots
    depth = np.zeros(n, dtype=np.int32)
    q = deque(roots)
    while q:
        g = q.popleft()
        lo, hi = child_offsets[g], child_offsets[g + 1]
        for c in child_index[lo:hi]:
            depth[c] = depth[g] + 1
            q.append(int(c))

    # per-entity locations
    locations: List[List[Tuple[int, int]]] = [[] for _ in entity_names]
    for g in range(n):
        locations[entity_a[g]].append((int(tree_a[g]), g))

    return EntityForest(
        parent=parent_a, entity_id=entity_a, tree_id=tree_a, depth=depth,
        child_offsets=child_offsets, child_index=child_index,
        roots=np.asarray(roots, dtype=np.int32),
        entity_names=entity_names, name_to_id=name_to_id,
        entity_locations=locations, num_trees=len(trees),
    )
