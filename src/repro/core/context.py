"""Context Generation (paper Algorithm 3).

Given a query entity's address list (from its block linked list), walk every
(tree, node) location, collect the first ``n`` upward (ancestors, nearest
first) and downward (BFS level order) hierarchical-relationship nodes, and
render them through the prompt template the paper describes ("the upward
hierarchical relationship of entity A are: B, C and D").

Host path (strings, feeds the serving prompt) and a vectorized device path
(entity-id tensors, feeds tokenized prompts inside a jitted serving step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocklist import BlockListArena, CSRArena, NULL
from .tree import EntityForest


@dataclasses.dataclass
class EntityContext:
    entity_id: int
    locations: List[Tuple[int, int]]           # (tree, node)
    up: List[List[int]]                        # per location: ancestor eids
    down: List[List[int]]                      # per location: descendant eids

    def pairs(self) -> List[Tuple[int, int]]:
        """(h_i, h'_i) pairs per Algorithm 3's context set."""
        out = []
        for u, d in zip(self.up, self.down):
            for i in range(max(len(u), len(d))):
                out.append((u[i] if i < len(u) else NULL,
                            d[i] if i < len(d) else NULL))
        return out


def generate_context(forest: EntityForest, entity_id: int,
                     locations: Iterable[Tuple[int, int]],
                     n: int = 3) -> EntityContext:
    locs = list(locations)
    up = [forest.ancestors(node, n) for _, node in locs]
    down = [forest.descendants(node, n) for _, node in locs]
    return EntityContext(entity_id=entity_id, locations=locs, up=up, down=down)


def context_from_arena(forest: EntityForest, arena: BlockListArena,
                       entity_id: int, head: int, n: int = 3) -> EntityContext:
    """Faithful path: walk the block linked list from its head pointer."""
    return generate_context(forest, entity_id, arena.walk(head), n=n)


def context_from_csr(forest: EntityForest, csr: CSRArena,
                     entity_id: int, n: int = 3) -> EntityContext:
    """Optimized path: one contiguous span per entity."""
    return generate_context(forest, entity_id, csr.walk(entity_id), n=n)


def render_context(forest: EntityForest, ctxs: Sequence[EntityContext]) -> str:
    """Paper §3.4 prompt template."""
    lines: List[str] = []
    for c in ctxs:
        name = forest.entity_names[c.entity_id]
        for (tree, _node), u, d in zip(c.locations, c.up, c.down):
            if u:
                ups = ", ".join(forest.entity_names[e] for e in u)
                lines.append(
                    f"In tree {tree}, the upward hierarchical relationship "
                    f"of {name} are: {ups}.")
            if d:
                downs = ", ".join(forest.entity_names[e] for e in d)
                lines.append(
                    f"In tree {tree}, the downward hierarchical relationship "
                    f"of {name} are: {downs}.")
    return "\n".join(lines)


# ------------------------------------------------------------------ device

def gather_hierarchy(parent: jax.Array, entity_id: jax.Array,
                     nodes: jax.Array, n: int) -> jax.Array:
    """Vectorized n-level ancestor gather: for each node index in ``nodes``
    return (len(nodes), n) ancestor entity ids (NULL-padded).  Runs inside the
    jitted serving step — parent-pointer chase becomes n dependent gathers."""
    def step(cur, _):
        p = jnp.where(cur == NULL, NULL, parent[jnp.maximum(cur, 0)])
        eid = jnp.where(p == NULL, NULL, entity_id[jnp.maximum(p, 0)])
        return p, eid
    _, eids = jax.lax.scan(step, nodes.astype(jnp.int32), None, length=n)
    return jnp.swapaxes(eids, 0, 1)            # (B, n)


def gather_descendants(child_offsets: jax.Array, child_index: jax.Array,
                       entity_id: jax.Array, nodes: jax.Array,
                       n: int) -> jax.Array:
    """First-n BFS-down entity ids per node, fully vectorized with a bounded
    frontier ring buffer of size n (level order, NULL-padded)."""
    B = nodes.shape[0]

    def per_node(node):
        buf = jnp.full((n,), NULL, dtype=jnp.int32)   # pending frontier
        out = jnp.full((n,), NULL, dtype=jnp.int32)

        def push_children(state, src):
            buf, w = state
            lo = child_offsets[jnp.maximum(src, 0)]
            hi = child_offsets[jnp.maximum(src, 0) + 1]
            def body(k, st):
                buf, w = st
                idx = lo + k
                valid = (src != NULL) & (idx < hi) & (w < n)
                c = jnp.where(valid, child_index[jnp.minimum(idx, child_index.shape[0] - 1)], NULL)
                buf = jnp.where(valid, buf.at[jnp.minimum(w, n - 1)].set(c), buf)
                return buf, jnp.where(valid, w + 1, w)
            return jax.lax.fori_loop(0, n, body, (buf, w))

        buf, w = push_children((buf, jnp.int32(0)), node)

        def step(i, st):
            buf, w, out = st
            cur = buf[jnp.minimum(i, n - 1)]
            valid = (i < w) & (cur != NULL)
            out = jnp.where(valid, out.at[i].set(entity_id[jnp.maximum(cur, 0)]), out)
            buf, w = jax.lax.cond(
                valid, lambda: push_children((buf, w), cur), lambda: (buf, w))
            return buf, w, out

        _, _, out = jax.lax.fori_loop(0, n, step, (buf, w, out))
        return out

    return jax.vmap(per_node)(nodes.astype(jnp.int32)) if B else \
        jnp.zeros((0, n), dtype=jnp.int32)
